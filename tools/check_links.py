"""CI docs link checker: fail on broken RELATIVE links in docs/ and README.

Scans markdown files for inline links/images ``[text](target)`` and verifies
every relative target resolves to an existing file or directory in the repo.
Skipped targets (unverifiable offline): absolute URLs (``scheme://``),
``mailto:``, pure in-page anchors (``#...``), and paths that resolve OUTSIDE
the repository root (e.g. the README's ``../../actions/...`` CI badge, which
is a GitHub web path, not a file). A ``path#anchor`` target is checked for
the file part only.

    python tools/check_links.py [files/dirs ...]   # default: README.md docs/

Exit 0 = all resolvable; exit 1 = broken links, each printed as
``file:line: target``.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Iterator, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# inline markdown link/image: [text](target) / ![alt](target); target ends at
# the first unnested ')' — good enough for the plain paths used in this repo
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^(```|~~~)")


def iter_links(path: str) -> Iterator[Tuple[int, str]]:
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                yield lineno, m.group(1)


def check_file(path: str) -> List[str]:
    broken = []
    base = os.path.dirname(os.path.abspath(path))
    for lineno, target in iter_links(path):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.realpath(os.path.join(base, rel))
        if not (resolved == REPO or resolved.startswith(REPO + os.sep)):
            continue                       # outside the repo: unverifiable
        if not os.path.exists(resolved):
            broken.append(f"{os.path.relpath(path, REPO)}:{lineno}: {target}")
    return broken


def collect(paths: List[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".md"))
        elif os.path.exists(p):
            out.append(p)
        else:
            print(f"error: no such file or directory: {p}", file=sys.stderr)
            raise SystemExit(2)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail (exit 1) on broken relative markdown links")
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(REPO, "README.md"),
                             os.path.join(REPO, "docs")])
    args = ap.parse_args(argv)
    files = collect(args.paths)
    broken = [b for f in files for b in check_file(f)]
    if broken:
        print(f"BROKEN LINKS ({len(broken)}):", file=sys.stderr)
        for b in broken:
            print(f"  {b}", file=sys.stderr)
        return 1
    print(f"link check: {len(files)} file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
