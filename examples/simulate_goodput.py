"""Cluster-scale goodput evaluation (paper Fig. 9) via the calibrated
discrete-event simulator: FlowPrefill vs DistServe / DistServe-CP2K / CP8K /
layer-level on the QwenTrace-statistics trace (Llama3-8B on A800).

    PYTHONPATH=src python examples/simulate_goodput.py [--model llama3-8b]
"""
import argparse

from repro.core.metrics import max_goodput
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [0.25, 0.5, 1, 2, 4, 6, 8, 12, 16]
SYSTEMS = ["distserve", "distserve-cp8k", "distserve-cp2k", "layer-level",
           "flowprefill"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama3-8b")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    print(f"== goodput sweep ({args.model}, QwenTrace stats) ==")
    print(f"{'system':>16s} | " + " ".join(f"{r:>5}" for r in RATES) +
          " | goodput")
    goodputs = {}
    for system in SYSTEMS:
        atts = []
        for rate in RATES:
            reqs = generate(TraceConfig(rate=rate, duration=args.duration,
                                        seed=args.seed, model=args.model))
            atts.append(simulate(system, reqs, model=args.model).attainment)
        g = max_goodput(RATES, atts)
        goodputs[system] = g
        print(f"{system:>16s} | " +
              " ".join(f"{a:5.2f}" for a in atts) + f" | {g:5.2f} req/s")
    fp = goodputs["flowprefill"]
    print("\nFlowPrefill goodput ratios "
          "(paper: 4.7-5.6x vs DistServe, <=2.0x vs CP2K, <=4.5x vs CP8K):")
    for system in SYSTEMS[:-1]:
        if goodputs[system] > 0:
            print(f"  vs {system:>16s}: {fp/goodputs[system]:.1f}x")


if __name__ == "__main__":
    main()
