"""Quickstart: FlowPrefill's operator-level preemption on a tiny model (CPU).

Reproduces the paper's Fig. 8 walk-through with real jitted execution:
request A (long, relaxed SLO) starts prefilling; request B (short, strict SLO)
arrives mid-flight; the event-driven scheduler preempts A at an operator
boundary, serves B, then resumes A — and A's result is bit-identical to an
uninterrupted run.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_tiny_config
from repro.core import Request, SchedulerCore, TTFTPredictor
from repro.models import init_params
from repro.models.segments import SegmentedPrefill
from repro.serving.prefill_instance import PrefillInstance

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ, LONG, SHORT = 4096, 4096, 128


def main():
    print("== FlowPrefill quickstart (operator-level preemption) ==")
    params = init_params(CFG, jax.random.PRNGKey(0))
    ex = SegmentedPrefill(params, CFG, max_seq=MAX_SEQ, granularity="op",
                          chunk_tokens=512)

    # offline TTFT profile -> polynomial predictor (paper §6.4)
    xs, ys = [], []
    for n in (128, 512, 1024, 2048, 4096):
        toks = jnp.zeros((1, n), jnp.int32)
        ex.run_all(ex.start(toks))                     # warm compile
        t0 = time.monotonic()
        ex.run_all(ex.start(toks))
        xs.append(n)
        ys.append(time.monotonic() - t0)
        print(f"  profile: {n:5d} tokens -> {ys[-1]*1e3:7.1f} ms")
    pred = TTFTPredictor.fit(xs, ys)

    core = SchedulerCore(predictor=pred, policy="s-edf",
                         enable_batching=False)
    inst = PrefillInstance(params, CFG, core, max_seq=MAX_SEQ, executor=ex)
    rng = np.random.default_rng(0)
    try:
        A = Request(num_tokens=LONG, slo=60.0, task_type="file",
                    arrival=time.monotonic())
        inst.submit_request(A, rng.integers(0, CFG.vocab_size, LONG))
        time.sleep(0.3)
        B = Request(num_tokens=SHORT, slo=1.0, task_type="text",
                    arrival=time.monotonic())
        inst.submit_request(B, rng.integers(0, CFG.vocab_size, SHORT))
        print(f"\n  A (file, {LONG} tok, SLO 60s) submitted; "
              f"B (text, {SHORT} tok, SLO 1s) arrives 0.3s later")
        assert inst.drain(120.0)
        print(f"  B TTFT = {B.ttft:.3f}s  (SLO met: {B.slo_met})")
        print(f"  A TTFT = {A.ttft:.3f}s  (SLO met: {A.slo_met})")
        print(f"  preemption blocking time = "
              f"{inst.blocking_stats.mean*1e3:.1f} ms "
              f"(max {inst.blocking_stats.max*1e3:.1f} ms)")
        print(f"  scheduling rounds = {inst.scheduling_rounds} "
              f"(<= 2 per request: event-driven)")

        # exactness: preempted-and-resumed A == uninterrupted run
        a_tokens = np.random.default_rng(0).integers(0, CFG.vocab_size, LONG)
        solo = ex.run_all(ex.start(jnp.asarray(a_tokens[None], jnp.int32)))
        done = {t.head.rid: t for t in inst.completed_tasks}
        same = np.array_equal(np.asarray(done[A.rid].prefill_task.logits),
                              np.asarray(solo))
        print(f"  preempt/resume bit-exact vs uninterrupted: {same}")
    finally:
        inst.shutdown()


if __name__ == "__main__":
    main()
