"""Cluster-scale serving walkthrough: N prefill instances behind SLO-aware
dispatch, with decode-phase TPOT/TBT accounting — the multi-instance scenario
behind the paper's cluster-scale goodput claims.

Two modes share the SAME dispatch policy objects (repro.core.dispatch):

  default — calibrated discrete-event ClusterSim (fast, no model needed):
      PYTHONPATH=src python examples/serve_cluster.py [--instances 4]
          [--rate 24] [--burstiness 3] [--policy all]
          [--scenario fitted-chat]         # fitted/stress scenario trace
          [--hetero a800,a800,a100,a100]   # mixed-hardware pool
          [--decode-sched s-edf] [--decode-max-batch 16]
          [--decode-migration]             # TBT-slack-aware decode stage
          [--prefix-share]                 # shared-prefix trace + per-
          [--prefix-cache-blocks 2048]     # instance prefix KV caches

  --real  — a tiny REAL model on CPU: Proxy + N threaded PrefillInstances +
            a DecodeInstance, load-aware dispatch against live backlog
            (--prefix-share turns on the real prefix-sharing PagedKVCache:
            repeated prompts prefill suffix-only; add --scenario to replay
            a scenario's arrival pacing + hash-chained prompts against it):
      PYTHONPATH=src python examples/serve_cluster.py --real [--requests 10]

Chaos replay (--chaos churn | spot-wave | gray | seed:<int> | plan.json):
the SAME `FaultPlan` drives simulator instance churn and real fault
injection against the threaded pool (supervised recovery + watchdog,
docs/ARCHITECTURE.md) — both modes report retries / sheds / lost:
      PYTHONPATH=src python examples/serve_cluster.py --chaos churn
      PYTHONPATH=src python examples/serve_cluster.py --real --chaos gray
"""
import argparse

from repro.core.faults import FaultPlan
from repro.sim.cluster import simulate_cluster
from repro.traces.qwentrace import TraceConfig, generate

POLICIES = ["round-robin", "least-loaded", "deflection",
            "capacity-weighted", "decode-aware", "prefix-affinity"]


def _scenario_trace(args):
    from repro.traces.scenarios import SCENARIOS, scenario_names
    sc = SCENARIOS.get(args.scenario)
    if sc is None:
        raise SystemExit(f"unknown scenario {args.scenario!r}; "
                         f"known: {scenario_names()}")
    print(f"scenario {sc.name!r}: {sc.summary}")
    print(f"  punishes: {sc.punishes}")
    return generate(TraceConfig(scenario=args.scenario, rate=args.rate,
                                duration=args.duration, seed=args.seed))


def _chaos_plan(args, n_instances):
    if not args.chaos:
        return None
    plan = FaultPlan.from_spec(args.chaos, n_instances=n_instances,
                               duration=args.duration)
    print(f"chaos plan {args.chaos!r}: {len(plan)} fault event(s)")
    for e in plan:
        rejoin = "never" if e.up_at == float("inf") else f"{e.up_at:.1f}s"
        print(f"  t={e.time:6.1f}s  {e.kind:8s} {e.target}[{e.instance}]"
              + (f" notice={e.notice}s" if e.kind == "spot" else "")
              + (f" x{e.factor}" if e.kind == "slowdown" else "")
              + f"  rejoin={rejoin}")
    return plan


def run_sim(args):
    hardware = args.hetero.split(",") if args.hetero else None
    n = len(hardware) if hardware else args.instances
    pool = " hetero[" + args.hetero + "]" if hardware else ""
    spec = f", spec-decode k={args.draft_k} accept={args.spec_accept}" \
        if args.spec_decode else ""
    print(f"== ClusterSim: {n} prefill + {n} decode instances{pool}, "
          f"rate={args.rate} req/s, burstiness={args.burstiness}{spec} ==")
    plan = _chaos_plan(args, n)
    if args.scenario:
        # scenario traces bring their own fitted output/TBT/prefix shape;
        # they always carry hash chains, so the prefix caches go live
        reqs = _scenario_trace(args)
        cache_blocks = args.prefix_cache_blocks
    else:
        share = dict(shared_prefix_frac=0.25, multi_turn_prob=0.75) \
            if args.prefix_share else {}
        reqs = generate(TraceConfig(rate=args.rate, duration=args.duration,
                                    seed=args.seed,
                                    burstiness=args.burstiness,
                                    output_mean=200, tbt_slo=args.tbt_slo,
                                    **share))
        cache_blocks = args.prefix_cache_blocks if args.prefix_share else 0
    print(f"{len(reqs)} requests "
          f"({sum(r.num_tokens for r in reqs)} prefill tokens)"
          + (f", prefix caches {cache_blocks} blocks/instance"
             if cache_blocks else ""))
    policies = POLICIES if args.policy == "all" else [args.policy]
    fault_cols = f" {'retry':>5s} {'shed':>4s} {'lost':>4s}" \
        if plan or args.shed_policy != "off" else ""
    print(f"{'dispatch':>17s} | {'TTFT att':>8s} {'e2e att':>8s} "
          f"{'p99/SLO':>7s} {'imbalance':>9s} {'preempts':>8s} "
          f"{'dec-pre':>7s} {'migr':>4s} {'hit':>5s}{fault_cols} "
          f"| per-instance dispatched")
    for policy in policies:
        res = simulate_cluster("flowprefill", reqs,
                               num_instances=n, dispatch=policy,
                               decode_instances=n, hardware=hardware,
                               decode_hardware=hardware,
                               decode_policy=args.decode_sched,
                               decode_max_batch=args.decode_max_batch,
                               decode_migration=args.decode_migration,
                               prefix_cache_blocks=cache_blocks,
                               fault_plan=plan, recovery=args.recovery,
                               shed_policy=args.shed_policy,
                               shed_budget=args.shed_budget,
                               spec_decode=args.spec_decode,
                               draft_k=args.draft_k,
                               spec_accept=args.spec_accept)
        faults = f" {res.retries:5d} {res.shed_requests:4d} " \
                 f"{res.lost_requests:4d}" if fault_cols else ""
        print(f"{policy:>17s} | {res.attainment:8.3f} "
              f"{res.e2e_attainment:8.3f} {res.e2e_p99_norm:7.2f} "
              f"{res.imbalance:9.2f} "
              f"{res.preemptions:8d} {res.decode_preemptions:7d} "
              f"{res.migrations:4d} {res.prefix_hit_rate:5.2f}{faults} "
              f"| {res.dispatched}")


def run_real(args):
    import dataclasses
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_tiny_config
    from repro.core import Request, SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.models.segments import SegmentedPrefill
    from repro.serving.decode_instance import DecodeInstance
    from repro.serving.prefill_instance import PrefillInstance
    from repro.serving.proxy import Proxy

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    max_seq = 2048
    print(f"== real mode: {args.instances} PrefillInstances (tiny model, "
          f"CPU threads), dispatch={args.policy} ==")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ex = SegmentedPrefill(params, cfg, max_seq=max_seq, granularity="op",
                          chunk_tokens=512)
    xs, ys = [], []
    for n in (256, 1024, 2048):               # offline TTFT profile + warmup
        toks = jnp.zeros((1, n), jnp.int32)
        ex.run_all(ex.start(toks))
        t0 = time.monotonic()
        ex.run_all(ex.start(toks))
        xs.append(n)
        ys.append(time.monotonic() - t0)
    pred = TTFTPredictor.fit(xs, ys)

    policy = args.policy if args.policy != "all" else "least-loaded"
    # --prefix-share: per-instance prefix-sharing PagedKVCache (each
    # instance keeps its own trie; the executor itself is stateless and
    # stays shared) — resubmitted prompts prefill suffix-only
    insts = [PrefillInstance(
        params, cfg, SchedulerCore(predictor=pred, enable_batching=False),
        max_seq=max_seq, executor=ex,
        prefix_share=args.prefix_share,
        prefix_cache_blocks=args.prefix_cache_blocks)
        for _ in range(args.instances)]
    # the decode flags apply here too: --decode-sched picks the instances'
    # admission policy, --decode-max-batch the continuous-batching slot cap
    # (the REAL batched jitted step, paged KV), --decode-migration needs
    # >= 2 decode instances
    n_dec = 2 if args.decode_migration else 1
    # --spec-decode: the REAL speculative path (self-drafting n-gram drafter
    # + one batched k+1-position verify pass per step, bit-identical greedy
    # output); longer outputs give the drafter history to match against
    out_tokens = 16 if args.spec_decode else 2
    decs = [DecodeInstance(params, cfg, decode_tokens=2,
                           policy=args.decode_sched,
                           decode_max_batch=max(args.decode_max_batch, 1),
                           spec_decode=args.spec_decode,
                           draft_k=args.draft_k)
            for _ in range(n_dec)]
    # wire the hetero-pool signals so capacity-weighted / decode-aware run
    # against real measurements, not silent 1.0/0.0 defaults: capacity from
    # the measured profile (identical executors -> identical capacities),
    # decode pressure priced by the analytic decode model for this config
    from repro.sim.costmodel import A800, DecodeCostModel, ModelSpec
    cap = xs[-1] / ys[-1]                  # measured prefill tokens/s
    plan = _chaos_plan(args, args.instances)
    has_hang = plan is not None and any(e.kind == "hang" for e in plan)
    proxy = Proxy(insts, decs, dispatch=policy,
                  capacities=[cap] * args.instances,
                  decode_cost=DecodeCostModel(ModelSpec.from_config(cfg),
                                              A800),
                  decode_migration=args.decode_migration,
                  recovery=args.recovery,
                  shed_policy=args.shed_policy,
                  shed_budget=args.shed_budget,
                  # hangs are only detectable by the watchdog; generous
                  # period so tiny-model jit compiles don't false-positive
                  watchdog_s=2.0 if has_hang else 0.0)
    rng = np.random.default_rng(args.seed)

    # replay the plan in request-index space: event time t maps to "after
    # submission floor(t / duration * requests)", so a fault scheduled
    # mid-trace lands mid-stream regardless of real-mode pacing. Outages
    # are capped at 5s (the demo run is seconds, not the sim's minutes).
    import threading
    chaos_by_i = {}
    revive_timers = []
    if plan is not None:
        for e in plan:
            i = min(int(e.time / args.duration * args.requests),
                    args.requests - 1)
            chaos_by_i.setdefault(i, []).append(e)

    def fire(e):
        kind, idx = e.target, e.instance
        j = idx % (args.instances if kind == "prefill" else len(decs))
        outage = min(e.duration, 5.0)
        if e.kind in ("crash", "spot"):
            # spot notice is sub-second here; treat both as a kill + rejoin
            proxy.kill_instance(j, kind)
            t = threading.Timer(outage, proxy.revive_instance, args=(j, kind))
            t.daemon = True
            t.start()
            revive_timers.append(t)
            print(f"  [chaos] {e.kind} {kind}[{j}] (rejoin in {outage:.1f}s)")
        elif e.kind == "hang":
            target = insts[j] if kind == "prefill" else decs[j]
            target.inject_fault(("hang", min(e.duration, 2.0)))
            t = threading.Timer(outage, proxy.revive_instance, args=(j, kind))
            t.daemon = True
            t.start()
            revive_timers.append(t)
            print(f"  [chaos] hang {kind}[{j}] (watchdog will strand it)")
        else:
            print(f"  [chaos] {e.kind} not modeled in --real mode; skipped")
    scen = _scenario_trace(args)[:args.requests] if args.scenario else None

    def scenario_tokens(src, n):
        # block content derived from the chain key: equal keys -> equal
        # tokens, so resubmitted prefixes (multi-turn chains, templates)
        # genuinely hit the real PagedKVCache trie instead of merely
        # colliding in the sim's residency model
        toks = rng.integers(0, cfg.vocab_size, n)
        for bi, key in enumerate((src.prefix_hash or ())[:n // 128]):
            block_rng = np.random.default_rng(key & 0xFFFFFFFF)
            toks[bi * 128:(bi + 1) * 128] = \
                block_rng.integers(0, cfg.vocab_size, 128)
        return toks

    try:
        prev_arrival = scen[0].arrival if scen else 0.0
        for i in range(args.requests):
            if scen and i < len(scen):
                # replay the scenario's task mix, pacing, and hash-chained
                # prompts (truncated to the tiny model's max_seq); SLOs use
                # the real-mode convention — the tiny CPU model's latencies
                # are not A800's, so the scenario's SLOs don't transfer
                src = scen[i]
                n = min(src.num_tokens, max_seq)
                req = Request(num_tokens=n, slo=5.0 if n <= 256 else 30.0,
                              arrival=time.monotonic(),
                              task_type=src.task_type,
                              output_tokens=out_tokens, tbt_slo=2.0,
                              prefix_hash=(src.prefix_hash or ())[:n // 128])
                proxy.submit(req, scenario_tokens(src, n))
                gap, prev_arrival = src.arrival - prev_arrival, src.arrival
                time.sleep(min(max(gap, 0.0), 0.5))
            else:
                n = int(rng.choice([256, 256, 1024, 2048]))
                req = Request(num_tokens=n, slo=5.0 if n <= 256 else 30.0,
                              arrival=time.monotonic(),
                              output_tokens=out_tokens, tbt_slo=2.0)
                proxy.submit(req, rng.integers(0, cfg.vocab_size, n))
                time.sleep(float(rng.exponential(0.15)))
            for e in chaos_by_i.pop(i, ()):
                fire(e)
        if not proxy.drain(300.0):
            rep = proxy.report()
            raise SystemExit(
                f"drain timed out: {len(rep['stranded_rids'])} request(s) "
                f"stranded (rids {rep['stranded_rids']}), instance health "
                f"{rep['instance_health']}")
        time.sleep(0.5)
        rep = proxy.report()
        print(f"  requests={rep['n_requests']} "
              f"dispatched={rep['dispatched_by_instance']}")
        print(f"  SLO attainment={rep['slo_attainment']:.2f} "
              f"TTFT mean={rep['ttft']['mean']:.3f}s "
              f"p99={rep['ttft']['p99']:.3f}s "
              f"e2e p99/SLO={rep['percentiles']['e2e_p99_norm']:.2f}")
        print(f"  decoded={sum(len(d.finished) for d in decs)} "
              f"decode_migrations={rep['decode_migrations']} "
              f"decode_preemptions={rep['decode_preemptions']}")
        if args.spec_decode:
            sp = rep["spec"]
            print(f"  spec: steps={sp['spec_steps']} "
                  f"accept={sp['accept_rate']:.2f} "
                  f"tokens/step={sp['tokens_per_step']:.2f} "
                  f"(drafted {sp['draft_proposed']}, "
                  f"accepted {sp['draft_accepted']})")
        if plan is not None or args.shed_policy != "off":
            served = rep["n_requests"] - rep["lost_requests"] \
                - rep["shed_requests"]
            print(f"  chaos: retries={rep['retries']} "
                  f"shed={rep['shed_requests']} "
                  f"lost={rep['lost_requests']} "
                  f"recovered goodput={served}/{rep['n_requests']} served "
                  f"(health {rep['instance_health']})")
    finally:
        proxy.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--rate", type=float, default=24.0)
    ap.add_argument("--duration", type=float, default=40.0)
    ap.add_argument("--burstiness", type=float, default=3.0)
    ap.add_argument("--policy", default="all",
                    choices=["all"] + POLICIES)
    ap.add_argument("--scenario", default=None,
                    help="fitted/stress scenario trace (repro.traces."
                    "scenarios; see docs/TRACES.md). Sim mode runs the "
                    "scenario against each dispatch policy with prefix "
                    "caches on; --real replays its pacing, task mix, and "
                    "hash-chained prompt content (block tokens derived "
                    "from chain keys, so shared prefixes hit the real "
                    "PagedKVCache). Overrides --burstiness/--prefix-share "
                    "trace shaping")
    ap.add_argument("--hetero", default=None, metavar="HW,HW,...",
                    help="comma-separated per-instance hardware "
                    "(a800 / a100 / tpu-v5e); overrides --instances")
    ap.add_argument("--tbt-slo", type=float, default=0.02,
                    help="decode TBT SLO (s/token); tight values make the "
                    "decode-aware policy visible on mixed pools")
    ap.add_argument("--decode-sched", default="fcfs",
                    choices=["fcfs", "s-edf"],
                    help="decode batch-admission policy (s-edf = TBT-slack-"
                    "aware with token-boundary preemption)")
    ap.add_argument("--decode-max-batch", type=int, default=0,
                    help="decode KV slot cap per instance. Sim mode: 0 = "
                    "unbounded processor sharing (scheduling needs a cap to "
                    "matter). Real mode: the continuous-batching slot count "
                    "of the batched jitted decode step (min 1)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding. Sim mode: fluid multi-token "
                    "advancement at --spec-accept per-token acceptance; "
                    "--real: the actual self-drafting n-gram drafter + "
                    "batched verify pass (bit-identical greedy output)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per speculative step")
    ap.add_argument("--spec-accept", type=float, default=0.7,
                    help="sim mode: per-token draft accept probability "
                    "(--real measures the real n-gram accept rate instead)")
    ap.add_argument("--decode-migration", action="store_true",
                    help="cost-gated migration of queued decodes off "
                    "instances past the TBT knee")
    ap.add_argument("--prefix-share", action="store_true",
                    help="block-level prefix sharing: sim mode generates a "
                    "shared-prefix trace (system prompts + multi-turn) and "
                    "gives every instance a prefix cache; real mode turns "
                    "on the prefix-sharing PagedKVCache (pair with "
                    "--policy prefix-affinity to route onto cached KV)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=2048,
                    help="prefix cache capacity per instance, in KV blocks "
                    "of 128 tokens (with --prefix-share)")
    ap.add_argument("--chaos", default=None, metavar="PLAN",
                    help="replay a FaultPlan: a preset (churn, spot-wave, "
                    "gray), seed:<int> for a generated schedule, or a JSON "
                    "file from FaultPlan.to_json. Sim mode feeds it to "
                    "ClusterSim; --real injects the same faults into the "
                    "threaded pool (kill/revive + hang watchdog)")
    ap.add_argument("--recovery", default="retry",
                    choices=["retry", "none"],
                    help="stranded-work handling under --chaos: re-dispatch "
                    "with backoff (retry) or count as lost (none, the "
                    "naive baseline)")
    ap.add_argument("--shed-policy", default="off",
                    choices=["off", "doomed-only", "budget"],
                    help="SLO-aware admission control (docs/SCHEDULING.md): "
                    "reject doomed arrivals at the proxy instead of letting "
                    "them poison the tail")
    ap.add_argument("--shed-budget", type=float, default=2.0,
                    help="budget policy: shed when predicted TTFT > "
                    "budget x SLO")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--requests", type=int, default=10,
                    help="request count in --real mode")
    args = ap.parse_args()
    if args.decode_migration and args.decode_max_batch <= 0 and not args.real:
        ap.error("--decode-migration migrates QUEUED decodes: set "
                 "--decode-max-batch > 0 (unbounded decode never queues)")
    if args.real:
        run_real(args)
    else:
        run_sim(args)


if __name__ == "__main__":
    main()
