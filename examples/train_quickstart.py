"""Train a small llama-family model with the full training substrate:
AdamW, cosine schedule, remat, atomic checkpointing with auto-resume, and the
straggler watchdog. Kill it mid-run and re-run — it resumes from the latest
checkpoint bit-identically.

    PYTHONPATH=src python examples/train_quickstart.py [--steps 60] [--big]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import get_tiny_config
from repro.models import init_params, param_count
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, data_iterator
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train import LoopConfig, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_quickstart")
    ap.add_argument("--big", action="store_true",
                    help="~100M-param model (slower per step on CPU)")
    args = ap.parse_args()

    cfg = get_tiny_config("llama3_8b")
    if args.big:
        cfg = dataclasses.replace(cfg, num_layers=8, d_model=512, d_ff=2048,
                                  num_heads=8, num_kv_heads=4,
                                  vocab_size=32768)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"== training {cfg.name}: {param_count(params)/1e6:.1f}M params ==")

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    opt_state = init_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
    data = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)

    start = 0
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None and last < args.steps:
        restored = ckpt.restore(args.ckpt_dir, last,
                                {"params": params, "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        start = last
        print(f"auto-resumed from checkpoint step {last}")

    loop = LoopConfig(total_steps=args.steps, checkpoint_every=20,
                      checkpoint_dir=args.ckpt_dir, log_every=10)
    params, opt_state, info = train_loop(
        cfg, params, opt_state, step, data_iterator(data, start_step=start),
        loop, start_step=start)
    print(f"done: final loss {info['final_loss']:.4f}, "
          f"median step {info['median_step_time']*1e3:.0f} ms, "
          f"stragglers {info['stragglers']}")


if __name__ == "__main__":
    main()
