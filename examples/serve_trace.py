"""End-to-end serving driver: a QwenTrace-statistics workload served by the
full FlowPrefill stack — Proxy -> PrefillInstance (event-driven scheduler,
operator-level preemption, SLO-aware batching) -> DecodeInstance — with a REAL
(tiny) model on CPU. Compares S-EDF against FCFS on the same trace.

    PYTHONPATH=src python examples/serve_trace.py [--requests 12] [--policy both]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_tiny_config
from repro.core import Request, SchedulerCore, TTFTPredictor
from repro.core.metrics import attainment_by_task, slo_attainment
from repro.models import init_params
from repro.models.segments import SegmentedPrefill
from repro.serving.decode_instance import DecodeInstance
from repro.serving.prefill_instance import PrefillInstance
from repro.serving.proxy import Proxy

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ = 4096
# scaled-down QwenTrace mix: (task, tokens, slo_seconds, probability)
MIX = [("text", 256, 1.5, 0.60), ("image", 256, 3.0, 0.08),
       ("search", 2048, 15.0, 0.24), ("file", 4096, 25.0, 0.08)]


def build(params, pred, ex, policy):
    core = SchedulerCore(predictor=pred, policy=policy, batch_budget=512,
                         enable_batching=False)
    inst = PrefillInstance(params, CFG, core, max_seq=MAX_SEQ, executor=ex)
    dec = DecodeInstance(params, CFG, decode_tokens=2)
    return Proxy([inst], [dec]), inst, dec


def run(policy, params, pred, ex, n_requests, seed=0):
    proxy, inst, dec = build(params, pred, ex, policy)
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for i in range(n_requests):
            r = rng.random()
            acc = 0.0
            for task, tokens, slo, p in MIX:
                acc += p
                if r <= acc:
                    break
            req = Request(num_tokens=tokens, slo=slo, task_type=task,
                          arrival=time.monotonic())
            proxy.submit(req, rng.integers(0, CFG.vocab_size, tokens))
            reqs.append(req)
            time.sleep(float(rng.exponential(0.6)))
        assert proxy.drain(300.0)
        time.sleep(0.5)
        rep = proxy.report()
        rep["by_task"] = attainment_by_task(reqs)
        rep["decoded"] = len(dec.finished)
        return rep
    finally:
        proxy.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="both",
                    choices=["both", "s-edf", "fcfs"])
    args = ap.parse_args()

    print("== end-to-end FlowPrefill serving (real execution, tiny model) ==")
    params = init_params(CFG, jax.random.PRNGKey(0))
    ex = SegmentedPrefill(params, CFG, max_seq=MAX_SEQ, granularity="op",
                          chunk_tokens=512)
    xs, ys = [], []
    for n in (256, 1024, 2048, 4096):
        toks = jnp.zeros((1, n), jnp.int32)
        ex.run_all(ex.start(toks))
        t0 = time.monotonic()
        ex.run_all(ex.start(toks))
        xs.append(n)
        ys.append(time.monotonic() - t0)
    pred = TTFTPredictor.fit(xs, ys)

    policies = ["s-edf", "fcfs"] if args.policy == "both" else [args.policy]
    for policy in policies:
        rep = run(policy, params, pred, ex, args.requests)
        print(f"\n--- policy={policy} ---")
        print(f"  requests={rep['n_requests']} decoded={rep['decoded']}")
        print(f"  SLO attainment={rep['slo_attainment']:.2f} "
              f"by task={ {k: round(v, 2) for k, v in rep['by_task'].items()} }")
        print(f"  TTFT mean={rep['ttft']['mean']:.3f}s "
              f"p99={rep['ttft']['p99']:.3f}s")
        print(f"  scheduling rounds={rep['scheduling_rounds']}, "
              f"mean blocking={rep['blocking_mean']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
