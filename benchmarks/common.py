"""Shared helpers for the benchmark harness. Each fig*.py module exposes
run() -> list[(name, value, derived_note)] and prints nothing on its own."""
from __future__ import annotations

import time
from functools import lru_cache
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


@lru_cache(maxsize=None)
def cached_trace(*, rate, duration, seed, model="llama3-8b", burstiness=1.0,
                 output_mean=0.0, tbt_slo=0.1, tbt_slo_by_task=None):
    """Memoized qwentrace generation: policy sweeps replay the SAME trace
    (same seed/rate), and `simulate_cluster`/`simulate` copy requests before
    running, so the cached list is never mutated. `tbt_slo_by_task` must be
    hashable — pass a tuple of (task, slo) pairs."""
    from repro.traces.qwentrace import TraceConfig, generate
    return generate(TraceConfig(
        rate=rate, duration=duration, seed=seed, model=model,
        burstiness=burstiness, output_mean=output_mean, tbt_slo=tbt_slo,
        tbt_slo_by_task=dict(tbt_slo_by_task) if tbt_slo_by_task else None))


@lru_cache(maxsize=None)
def cached_scenario_trace(*, scenario, rate, duration, seed,
                          model="llama3-8b"):
    """Memoized fitted-scenario generation (`TraceConfig.scenario` path):
    every policy variant at a given (scenario, rate) replays the SAME trace
    — `simulate_cluster` copies requests before running, so the cached list
    is never mutated."""
    from repro.traces.qwentrace import TraceConfig, generate
    return generate(TraceConfig(scenario=scenario, rate=rate,
                                duration=duration, seed=seed, model=model))


def time_us(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(iters):
        fn()
    return (time.monotonic() - t0) / iters * 1e6


def emit(rows: List[Row]) -> None:
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
