"""Shared helpers for the benchmark harness. Each fig*.py module exposes
run() -> list[(name, value, derived_note)] and prints nothing on its own."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def time_us(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.monotonic()
    for _ in range(iters):
        fn()
    return (time.monotonic() - t0) / iters * 1e6


def emit(rows: List[Row]) -> None:
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
