"""Fig. 20 (decode-scheduling extension) — TBT-slack-aware decode scheduling
(S-EDF for decode) with cost-gated decode migration, vs the paper's
deliberately-plain FCFS decode stage.

Setup: every decode instance has a KV slot cap (``decode_max_batch=16``), so
admission order matters; the trace mixes TIGHT interactive TBT SLOs (text
15ms, image 30ms) with LOOSE agentic ones (search/file 100ms) — the
heterogeneous-SLO regime where slack-aware admission wins (motivation:
"Taming Request Imbalance" / "Optimal Scheduling Algorithms for LLM
Inference"). Three decode schedulers, all on the SAME prefill stack
(FlowPrefill S-EDF + op-level preemption):

  * ``fcfs``      — arrival-order admission, no displacement (the baseline).
  * ``s-edf``     — admission ranked by TBT-deadline slack, with
    token-boundary preemption: a near-deadline queued stream displaces the
    most slack-rich resident.
  * ``s-edf+mig`` — s-edf plus cost-gated migration of queued decodes off an
    instance past its TBT knee (KV handoff priced by
    `DecodeCostModel.kv_transfer_time`).

Panels:

  a) 2xA800 + 2xA100 pool, static paired PD wiring (capacity-weighted
     dispatch, prefill i -> decode i): the A100 half decodes ~1.3x slower
     (memory-bound), so static pairing queues decodes exactly where TBT is
     weakest — scheduling AND migration must recover it at run time.
     Acceptance (CI-gated): s-edf+mig >= 1.15x FCFS e2e goodput.
  b) homogeneous 4xA800, same wiring: no hardware skew — the win isolates
     slack-aware admission over the mixed-SLO stream itself.
  c) the same hetero pool under decode-aware dispatch (the best dispatch-time
     avoidance PR 2 ships) at a saturating rate: decode scheduling still
     roughly doubles TBT attainment, i.e. dispatch-time avoidance alone is
     not a substitute for decode-side scheduling.
"""
from benchmarks.common import cached_trace
from repro.core.metrics import max_goodput
from repro.sim.cluster import simulate_cluster

HETERO = ("a800", "a800", "a100", "a100")
HOMO = ("a800",) * 4
# tight interactive vs loose agentic TBT SLOs (seconds/token)
TBT_BY_TASK = (("text", 0.015), ("image", 0.03), ("search", 0.1),
               ("file", 0.1))
RATES = [4, 6, 8, 10, 12, 16, 20]
SAT_RATE = 20                        # panel (c): past every variant's knee
MAX_BATCH = 16                       # decode KV slot cap
OUTPUT_MEAN = 256

VARIANTS = (
    ("fcfs", dict(decode_policy="fcfs")),
    ("s-edf", dict(decode_policy="s-edf")),
    ("s-edf+mig", dict(decode_policy="s-edf", decode_migration=True)),
)


def run_variant(pool, variant_kw, rate, *, dispatch="capacity-weighted",
                decode_affinity=True, model="llama3-8b", duration=40, seed=3):
    reqs = cached_trace(rate=rate, duration=duration, seed=seed, model=model,
                        output_mean=OUTPUT_MEAN, tbt_slo_by_task=TBT_BY_TASK)
    return simulate_cluster("flowprefill", reqs, model=model,
                            hardware=list(pool), decode_hardware=list(pool),
                            decode_instances=len(pool), dispatch=dispatch,
                            decode_affinity=decode_affinity,
                            decode_max_batch=MAX_BATCH, **variant_kw)


def goodput_panel(pool, pool_name, model, rows):
    goodputs = {}
    for name, kw in VARIANTS:
        atts, migs, preempts = [], 0, 0
        for rate in RATES:
            res = run_variant(pool, kw, rate, model=model)
            atts.append(res.e2e_attainment)
            migs += res.migrations
            preempts += res.decode_preemptions
        g = max_goodput(RATES, atts)
        goodputs[name] = g
        rows.append((f"fig20/{model}/{pool_name}/{name}/goodput_req_s",
                     round(g, 2),
                     "e2e att@rates=" + "|".join(f"{a:.2f}" for a in atts)
                     + f" migrations={migs} decode_preemptions={preempts}"))
    fcfs = goodputs["fcfs"]
    for name in ("s-edf", "s-edf+mig"):
        if fcfs > 0:
            rows.append((f"fig20/{model}/{pool_name}/{name}_vs_fcfs",
                         round(goodputs[name] / fcfs, 2),
                         "e2e goodput ratio vs FCFS decode "
                         "(acceptance: s-edf+mig >= 1.15 on hetero)"))


def run(model="llama3-8b"):
    rows = []
    # (a) hetero pool, static paired PD wiring
    goodput_panel(HETERO, "a800-a100", model, rows)
    # (b) homogeneous pool, same wiring: pure admission-policy win
    goodput_panel(HOMO, "4xa800", model, rows)
    # (c) hetero pool under decode-aware dispatch at saturation: decode
    # scheduling on top of the best dispatch-time avoidance
    for name, kw in VARIANTS:
        res = run_variant(HETERO, kw, SAT_RATE, dispatch="decode-aware",
                          decode_affinity=None, model=model)
        rows.append((f"fig20/{model}/a800-a100/decode-aware-sat{SAT_RATE}/"
                     f"{name}/tbt_attainment",
                     round(res.tbt_attainment, 3),
                     f"TBT-SLO attainment at {SAT_RATE} req/s under "
                     f"decode-aware dispatch; e2e={res.e2e_attainment:.3f} "
                     f"migrations={res.migrations} "
                     f"decode_preemptions={res.decode_preemptions}"))
    return rows
