"""Fig. 10 — scheduling policy ablation: S-EDF vs D-EDF vs naive EDF."""
from repro.core.metrics import max_goodput
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [0.5, 1, 2, 4, 6, 8, 12, 16]


def run():
    rows = []
    for name, system in (("s-edf", "flowprefill"),
                         ("d-edf", "flowprefill-dedf"),
                         ("edf", "flowprefill-edf")):
        atts = []
        for rate in RATES:
            reqs = generate(TraceConfig(rate=rate, duration=60, seed=3))
            atts.append(simulate(system, reqs).attainment)
        rows.append((f"fig10/{name}/goodput_req_s",
                     round(max_goodput(RATES, atts), 2),
                     "att=" + "|".join(f"{a:.2f}" for a in atts)))
    return rows
