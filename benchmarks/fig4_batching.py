"""Fig. 4 — workload asymmetry in prefill batching: short requests gain
throughput from batching with modest latency growth (memory/efficiency-bound);
long requests see linear latency inflation for no throughput gain."""
from repro.sim.costmodel import A100, LLAMA3_8B, PrefillCostModel


def run():
    cost = PrefillCostModel(LLAMA3_8B, A100)
    rows = []
    # (a) throughput vs input length, single request
    for n in (32, 64, 128, 256, 512, 1024, 4096, 16384):
        rows.append((f"fig4a/len{n}/throughput_tok_s",
                     round(cost.throughput(n), 1), "single request"))
    # (b) batching short (256-token) requests
    t1 = cost.prefill_time(256)
    for bs in (1, 2, 4, 8, 16, 32):
        t = cost.prefill_time(256 * bs)
        rows.append((f"fig4b/short_batch{bs}/throughput_req_s",
                     round(bs / t, 2),
                     f"norm_ttft={t/t1:.2f}x"))
    # (b') batching long (16K) requests: latency inflates ~linearly
    t1 = cost.prefill_time(16384)
    for bs in (1, 2, 4):
        t = cost.prefill_time(16384 * bs)
        rows.append((f"fig4b/long_batch{bs}/norm_ttft",
                     round(t / t1, 2), f"throughput_req_s={bs/t:.3f}"))
    return rows
