"""Fig. 24 (colocation extension) — MEASURED colocation vs PD-disaggregation
at equal hardware: the matchup the paper's fig16 only approximates with a
hard-coded 35% utilization tax.

Three pool shapes over the same 4 cards, same traces, same SLOs
(`HybridSim` prices prefill chunks + woven decode steps into one
budget-capped round from the SAME `PrefillCostModel`/`DecodeCostModel` the
dedicated engines use — the interference is computed, not assumed):

  * ``disagg``    — 2 prefill + 2 decode, decode-aware dispatch (the PR 4/5
                    production stack: the PD baseline).
  * ``mixed``     — 1 prefill + 1 decode + 2 hybrids with decode offload:
                    hybrids absorb prefill bursts weave-free and hand
                    completed prompts to the decode card, so decode
                    consolidates where no chunk competes for the device.
  * ``colocated`` — 4 hybrids, every stream decodes where it prefilled
                    (no handoff at all; the pure-colocation extreme).

Gated rows per (scenario, rate): e2e/TTFT/TBT attainment per pool, plus the
``mixed_vs_disagg`` e2e ratio — the headline: under a prefill flood the
mixed pool BEATS disaggregation (hybrids convert idle decode-card compute
into prefill absorption), while under steady chat disaggregation holds the
edge and pure colocation pays the measured weave tax at tight TBT SLOs.
All sim rows are deterministic (seeded discrete-event results) and safe to
gate at exact values.

``real/*`` rows drive the REAL runtimes on the tiny bench config (fig21's):
TBT attainment of `HybridInstance` decode streams WHILE prefill chunks run
on the same device, against a dedicated `DecodeInstance` on the identical
workload. The TBT SLO is self-calibrated to the dedicated instance's
measured step time (runner-speed independent); committed baselines for
these wall-clock rows are CONSERVATIVE acceptance thresholds, not one
machine's measurements (docs/BENCHMARKS.md convention).
"""
import dataclasses

from benchmarks.common import cached_scenario_trace
from repro.sim.cluster import simulate_cluster

DURATION = 20
SEED = 3
GRID = [("fitted-chat", 16), ("fitted-chat", 24), ("flood", 4), ("flood", 8)]

# equal hardware: every pool is 4 cards of the same model
POOLS = {
    "disagg": dict(num_instances=2, decode_instances=2, decode_max_batch=16,
                   dispatch="decode-aware", decode_policy="s-edf"),
    "mixed": dict(num_instances=1, decode_instances=1, hybrid_instances=2,
                  decode_max_batch=16, dispatch="least-loaded",
                  decode_policy="s-edf", hybrid_token_budget=2048,
                  hybrid_decode_offload=True),
    "colocated": dict(num_instances=0, decode_instances=0,
                      hybrid_instances=4, decode_max_batch=0,
                      dispatch="least-loaded", decode_policy="s-edf",
                      hybrid_token_budget=2048),
}

# --- real-runtime panel (tiny bench config, CPU) ---------------------------
N_STREAMS = 4            # decode streams whose TBT is measured
OUT_TOKENS = 48          # decoded tokens per measured stream
PROMPT = 128             # one prompt length everywhere: one compile footprint
N_PREFILLS = 6           # concurrent prefill pressure on the hybrid
CHUNK = 64
SLO_STEPS = 5.0          # TBT SLO = this many dedicated median step times
CADENCE_STEPS = 2.0      # hybrid weave cadence in dedicated step times


def _bench_model():
    import jax

    from repro.configs.base import get_tiny_config
    from repro.models import init_params

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    return init_params(cfg, jax.random.PRNGKey(0)), cfg


def _sim_rows(model):
    rows = []
    for scenario, rate in GRID:
        reqs = cached_scenario_trace(scenario=scenario, rate=rate,
                                     duration=DURATION, seed=SEED,
                                     model=model)
        att = {}
        for pool, kw in POOLS.items():
            # simulate_cluster copies requests before running: every pool
            # replays the identical trace
            res = simulate_cluster("flowprefill", reqs, model=model, **kw)
            att[pool] = res.e2e_attainment
            tag = f"fig24/{model}/{scenario}@r{rate}/{pool}"
            rows.append((f"{tag}/e2e_attainment",
                         round(res.e2e_attainment, 3),
                         "TTFT and TBT SLOs both met (deterministic sim)"))
            rows.append((f"{tag}/ttft_attainment",
                         round(res.attainment, 3),
                         "TTFT-SLO attainment"))
            rows.append((f"{tag}/tbt_attainment",
                         round(res.tbt_attainment, 3),
                         "decode TBT/TPOT-SLO attainment (weave cadence "
                         "holds the mean TPOT for colocated streams)"))
        rows.append((f"fig24/{model}/{scenario}@r{rate}/mixed_vs_disagg",
                     round(att["mixed"] / max(att["disagg"], 1e-9), 3),
                     "e2e-attainment ratio at equal hardware (>1: the "
                     "mixed pool beats PD-disaggregation — the flood rows "
                     "are the headline win)"))
    return rows


def _measured_tbt(inst, mark):
    return [s for s in inst.tbt_samples[mark:]]


def _real_rows(model):
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.core.request import Request
    from repro.models.model import prefill
    from repro.serving.decode_instance import DecodeInstance, DecodeJob
    from repro.serving.hybrid_instance import HybridInstance

    params, cfg = _bench_model()
    rng = np.random.default_rng(0)
    max_seq = PROMPT + OUT_TOKENS + 8

    def prompt():
        return rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32)

    def handoff(toks):
        logits, cache = prefill(params, cfg, {"tokens": jnp.asarray(
            toks[None, :], jnp.int32)}, max_seq=max_seq)
        return int(jnp.argmax(logits, -1)[0]), \
            {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}

    def decode_req(slo):
        return Request(num_tokens=PROMPT, slo=60.0, arrival=0.0,
                       output_tokens=OUT_TOKENS, tbt_slo=slo)

    # --- dedicated decode reference (warmup pass, then measured pass) ------
    ded = DecodeInstance(params, cfg, decode_max_batch=N_STREAMS,
                         decode_tokens=OUT_TOKENS)
    for phase in ("warmup", "measure"):
        mark = len(ded.tbt_samples)
        for _ in range(N_STREAMS):
            first, cache = handoff(prompt())
            ded.submit(DecodeJob(request=decode_req(60.0), cache=cache,
                                 first_token=first))
        assert ded.drain(300.0), "dedicated decode did not drain"
    ded_tbt = _measured_tbt(ded, mark)
    ded.shutdown()
    median = float(np.median(ded_tbt))
    slo = SLO_STEPS * median

    # --- hybrid under concurrent prefill (same self-calibrated SLO) --------
    hyb = HybridInstance(params, cfg, max_seq=max_seq, chunk_tokens=CHUNK,
                         token_budget=4 * CHUNK,
                         decode_max_batch=N_STREAMS,
                         decode_cadence=CADENCE_STEPS * median,
                         kv_pool_blocks=128, prefix_share=False)
    for phase in ("warmup", "measure"):
        for _ in range(N_STREAMS):
            hyb.submit(decode_req(slo), prompt())
        # wait until every measured stream is actually decoding, then pile
        # prefill-only requests onto the same device
        deadline = time.monotonic() + 300.0
        while hyb.resident() < N_STREAMS and time.monotonic() < deadline:
            time.sleep(0.002)
        mark = len(hyb.tbt_samples)
        for _ in range(N_PREFILLS):
            hyb.submit(Request(num_tokens=PROMPT, slo=60.0, arrival=0.0,
                               output_tokens=0, tbt_slo=slo), prompt())
        assert hyb.drain(300.0), "hybrid did not drain"
    hyb_tbt = _measured_tbt(hyb, mark)
    hyb.shutdown()

    ded_att = sum(1 for s in ded_tbt if s <= slo) / max(len(ded_tbt), 1)
    hyb_att = sum(1 for s in hyb_tbt if s <= slo) / max(len(hyb_tbt), 1)
    note = (f"TBT SLO self-calibrated to {SLO_STEPS:.0f}x the dedicated "
            f"median step ({median * 1e3:.1f} ms); committed baseline is "
            f"the conservative acceptance threshold, not this measurement")
    return [
        (f"fig24/{model}/real/dedicated_tbt_attainment", round(ded_att, 3),
         f"dedicated DecodeInstance, {N_STREAMS} streams x {OUT_TOKENS} "
         f"tokens; {note}"),
        (f"fig24/{model}/real/hybrid_tbt_attainment", round(hyb_att, 3),
         f"HybridInstance decode TBT while {N_PREFILLS} prefills chunk "
         f"through the same device (true inter-token gaps incl. weave "
         f"pauses); {note}"),
        (f"fig24/{model}/real/hybrid_vs_dedicated",
         round(hyb_att / max(ded_att, 1e-9), 3),
         f"TBT-attainment ratio under concurrent prefill — the acceptance "
         f"criterion: colocated decode stays within tolerance of a "
         f"dedicated instance; {note}"),
    ]


def run(model="llama3-8b"):
    return _sim_rows(model) + _real_rows(model)
