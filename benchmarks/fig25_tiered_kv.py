"""Fig. 25 (tiered-KV extension) — HBM -> host -> disk prefix-cache tiers:
goodput vs HBM cache capacity, and the measured real-runtime
promote-vs-recompute crossover.

A single-tier prefix cache collapses the moment the shared-prefix working
set outgrows HBM residency: LRU eviction *destroys* KV that a follow-up
will need seconds later, so every capacity miss is a full recompute. The
tiered cache (`TieredBlockManager` + `PagedKVCache` host/disk tiers)
demotes evicted blocks down a host tier (then a disk tier) instead, and
dispatch prices warm/cold/absent as three prices: a cold hit is taken only
when the promotion copy (host_bw/disk_bw links in `HardwareSpec`) beats
the predictor-priced recompute — `InstanceLoad.ttft_saved` is already NET
of the copy.

Panels:

  a) capacity sweep — 4xA800 prefill pool on a session re-entry trace
     (64 agent sessions, each turn resubmitting the whole history, turns
     interleaved round-robin across sessions — the production workload
     motivating KV offload: inter-turn reuse distance spans the WHOLE
     session population, LRU's cyclic-scan worst case). TTFT goodput of
     one-tier vs tiered (same HBM residency + host/disk tiers) while
     per-instance HBM cache blocks shrink 512 -> 64. With residency >=
     working set the two are identical (the tier is pure fallback); as HBM
     shrinks, one-tier hit rate collapses toward zero (every block ages
     out before its session's next turn) while tiered serves the same hits
     as promotions. Acceptance (CI-gated): tiered >= 1.5x one-tier goodput
     at the smallest capacity point (ratio floored at the lowest swept
     rate when one-tier's goodput is 0 — the committed value understates
     the win), and the promote hit rate there is ~1 (every hit came up a
     tier).
  b) real runtime — a `PrefillInstance` with a tiered `PagedKVCache` on
     the tiny bench model: a prompt is cached, flooded out of HBM into the
     host tier, then resubmitted. The resubmission promotes (async
     host->HBM copy, checksum-verified) instead of recomputing.
     Acceptance (CI-gated): promoted >= 3x faster than the cold prefill.
     Wall-clock convention (docs/BENCHMARKS.md): the committed baseline is
     the conservative tolerance-compensated threshold, not one machine's
     measurement (steady-state CPU measures 5-30x).
"""
import dataclasses
import time

from repro.core.metrics import max_goodput
from repro.core.prefixcache import chain_extend
from repro.core.request import Request
from repro.sim.cluster import simulate_cluster

RATES = [16, 24, 32, 48, 64, 96]
N_INSTANCES = 4
CAPACITIES = [512, 256, 128, 64]     # per-instance HBM blocks (x128 tokens)
HOST_BLOCKS = 4096                   # host tier (per instance)
DISK_BLOCKS = 4096                   # disk tier behind it
SESSIONS = 64                        # concurrent agent sessions
TURNS = 6                            # turns per session (history grows)
SEG = 512                            # tokens appended per turn
KV_BLOCK = 128                       # hash-chain block granularity
SLO = 0.5
PROBE_RATE = 32                      # rate the hit/promote rates are read at


def _trace(rate):
    """Session re-entry: turn k of session s resubmits the whole history
    ((k+1) * SEG tokens, a deterministic per-session block hash chain).
    Turns interleave round-robin across ALL sessions, so the reuse distance
    between a session's consecutive turns is the entire population's
    working set — far beyond small HBM residency, well within the host
    tier."""
    reqs, t = [], 0.0
    for k in range(TURNS):
        for s in range(SESSIONS):
            n = (k + 1) * SEG
            keys = chain_extend((), [s * 10_000 + b
                                     for b in range(n // KV_BLOCK)])
            reqs.append(Request(num_tokens=n, slo=SLO, arrival=t,
                                prefix_hash=keys, output_tokens=0))
            t += 1.0 / rate
    return reqs


def _goodput(cache_blocks, tiered):
    kw = dict(dispatch="prefix-affinity", prefix_cache_blocks=cache_blocks)
    if tiered:
        kw.update(host_cache_blocks=HOST_BLOCKS,
                  disk_cache_blocks=DISK_BLOCKS)
    atts, probe = [], None
    for rate in RATES:
        res = simulate_cluster("flowprefill", _trace(rate),
                               num_instances=N_INSTANCES, **kw)
        atts.append(res.attainment)
        if rate == PROBE_RATE:
            probe = res
    return max_goodput(RATES, atts), atts, probe


def run(model="llama3-8b"):
    rows = []
    goodputs = {}
    for tiered in (False, True):
        name = "tiered" if tiered else "one-tier"
        for cap in CAPACITIES:
            g, atts, probe = _goodput(cap, tiered)
            goodputs[(name, cap)] = g
            extra = ""
            if tiered:
                extra = (f"; promote_rate={probe.promote_hit_rate:.2f} "
                         f"demotions={probe.tier_demotions}")
            rows.append((f"fig25/{model}/{name}/cap{cap}/goodput_req_s",
                         round(g, 2),
                         "TTFT att@rates="
                         + "|".join(f"{a:.2f}" for a in atts)
                         + f"; hit_rate={probe.prefix_hit_rate:.2f}"
                         + extra))
    small = CAPACITIES[-1]
    # one-tier goodput is 0 at the collapse point: floor the denominator at
    # the lowest swept rate so the gated ratio stays finite & conservative
    one = max(goodputs[("one-tier", small)], float(RATES[0]))
    rows.append((f"fig25/{model}/tiered_vs_one-tier",
                 round(goodputs[("tiered", small)] / one, 2),
                 f"goodput ratio at the smallest HBM capacity ({small} "
                 f"blocks/instance; one-tier measured "
                 f"{goodputs[('one-tier', small)]:.2f}, denominator "
                 f"floored at {RATES[0]}): the tier keeps the hits the "
                 f"single-tier cache destroys (acceptance: >= 1.5)"))
    _, _, probe = _goodput(small, True)
    rows.append((f"fig25/{model}/promote_hit_rate",
                 round(probe.promote_hit_rate, 3),
                 f"fraction of prefix-hit tokens served by host/disk "
                 f"promotion at cap={small}, {PROBE_RATE} req/s "
                 f"(hit_rate={probe.prefix_hit_rate:.2f}, promoted "
                 f"{probe.prefix_promoted_tokens} tokens)"))
    big = goodputs[("tiered", CAPACITIES[0])]
    if big > 0:
        one_ret = goodputs[("one-tier", small)] \
            / max(goodputs[("one-tier", CAPACITIES[0])], 1e-9)
        rows.append((f"fig25/{model}/graceful/tiered_min_vs_max",
                     round(goodputs[("tiered", small)] / big, 2),
                     f"tiered goodput retained shrinking HBM "
                     f"{CAPACITIES[0]} -> {small} blocks (graceful "
                     f"degradation; one-tier retains {one_ret:.2f})"))

    # (b) real runtime: measured promote-vs-recompute crossover
    rows.extend(run_runtime(model))
    return rows


def run_runtime(model="llama3-8b", *, prompt_tokens=2048, chunk=512):
    """Measured `PrefillInstance` promote-vs-recompute: an identical prompt
    cold (full prefill), after HBM eviction to the host tier (promotion:
    async copy up + 1-token suffix compute), vs recomputed from scratch.
    The instance's HBM cache is sized so a flood of filler prompts demotes
    the probe prompt's blocks without dropping them."""
    import jax
    import numpy as np

    from repro.configs.base import get_tiny_config
    from repro.core import Request, SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.serving.prefill_instance import PrefillInstance

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pred = TTFTPredictor(coeffs=np.array([1e-6, 0.0]), floor=0.0)
    blocks = prompt_tokens // 128            # kv_block_size default
    inst = PrefillInstance(
        params, cfg, SchedulerCore(predictor=pred, enable_batching=False),
        max_seq=prompt_tokens, chunk_tokens=chunk, prefix_share=True,
        # HBM holds ~3 prompts: the flood below evicts the probe prompt
        prefix_cache_blocks=3 * blocks,
        host_cache_blocks=16 * blocks)
    rng = np.random.default_rng(0)

    def run_once(toks):
        req = Request(num_tokens=len(toks), slo=600.0,
                      arrival=time.monotonic())
        t0 = time.monotonic()
        inst.submit_request(req, toks)
        assert inst.drain(600.0), \
            f"instance did not drain serving rid {req.rid}"
        return time.monotonic() - t0, req

    try:
        warmup = rng.integers(0, cfg.vocab_size, prompt_tokens)
        run_once(warmup)                   # compile cold shapes
        run_once(warmup)                   # compile warm (suffix) shapes
        probe = rng.integers(0, cfg.vocab_size, prompt_tokens)
        cold, _ = run_once(probe)
        # calibrate the promote-vs-recompute gate to THIS machine's
        # measured prefill speed (the toy predictor above prices recompute
        # at ~2us — no real copy could beat that)
        inst.scheduler.predictor = TTFTPredictor(
            coeffs=np.array([cold / prompt_tokens, 0.0]), floor=0.0)
        # flood HBM: filler prompts demote the probe prompt to the host tier
        for _ in range(4):
            run_once(rng.integers(0, cfg.vocab_size, prompt_tokens))
        promoted, wr = run_once(probe)
        n_promos = inst.prefix_promotions
        stats = inst.kv.tier_stats()
    finally:
        inst.shutdown()
    assert wr.prefix_hit > 0 and n_promos > 0, \
        f"promotion did not engage (hit={wr.prefix_hit}, promos={n_promos})"
    return [
        (f"fig25/{model}/real/cold_ms", round(cold * 1e3, 1),
         f"full prefill of {prompt_tokens} tokens (measured, runner-speed "
         f"dependent — not gated)"),
        (f"fig25/{model}/real/promoted_ms", round(promoted * 1e3, 1),
         f"same prompt after HBM eviction: host->HBM promotion of "
         f"hit={wr.prefix_hit} tokens + suffix compute "
         f"(demotions={stats['demotions']}, promotions="
         f"{stats['promotions']}; measured — not gated)"),
        (f"fig25/{model}/real/promote_vs_recompute_speedup",
         round(cold / promoted, 2),
         "measured speedup of promoting the evicted prefix over "
         "recomputing it (acceptance: >= 3.0; committed baseline is the "
         "tolerance-compensated conservative threshold, steady-state CPU "
         "measures 5-30x)"),
    ]
