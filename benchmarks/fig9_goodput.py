"""Fig. 9 — end-to-end: SLO attainment vs request rate (goodput) and vs SLO
scale (min supportable SLO), FlowPrefill vs DistServe / DistServe-CP2K /
DistServe-CP8K, on the QwenTrace-statistics synthetic trace."""
from repro.core.metrics import max_goodput, min_slo_scale
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

SYSTEMS = ("distserve", "distserve-cp2k", "distserve-cp8k", "flowprefill")
RATES = [0.25, 0.5, 1, 2, 4, 6, 8, 12, 16]
SCALES = [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0]


def run(model="llama3-8b", duration=60, seed=3):
    rows = []
    goodputs = {}
    for system in SYSTEMS:
        atts = []
        for rate in RATES:
            reqs = generate(TraceConfig(rate=rate, duration=duration,
                                        seed=seed, model=model))
            atts.append(simulate(system, reqs, model=model).attainment)
        g = max_goodput(RATES, atts)
        goodputs[system] = g
        rows.append((f"fig9/{model}/{system}/goodput_req_s", round(g, 2),
                     "att@rates=" + "|".join(f"{a:.2f}" for a in atts)))
    for system in SYSTEMS:
        if goodputs[system] > 0:
            rows.append((f"fig9/{model}/flowprefill_vs_{system}",
                         round(goodputs["flowprefill"] / goodputs[system], 2),
                         "goodput ratio (paper: 4.7-5.6x vs distserve)"))
    # SLO-scale sweep at a fixed moderate rate
    rate = 4.0
    for system in SYSTEMS:
        atts = []
        for scale in SCALES:
            reqs = generate(TraceConfig(rate=rate, duration=duration,
                                        seed=seed, model=model,
                                        slo_scale=scale))
            atts.append(simulate(system, reqs, model=model).attainment)
        s = min_slo_scale(SCALES, atts)
        rows.append((f"fig9/{model}/{system}/min_slo_scale", round(s, 2),
                     f"rate={rate}; att=" + "|".join(f"{a:.2f}" for a in atts)))
    return rows
