"""Fig. 19 (hetero extension) — heterogeneous-pool dispatch: mixed hardware
generations with per-instance cost models, capacity-weighted and decode-aware
dispatch vs the hardware-blind baselines.

Three panels:

  a) mixed A800/A100 pool WITH a paired decode stage and a tight TBT SLO.
     A800 and A100 prefill at the same speed (compute-bound, same peak
     FLOPs), but decode is memory-bound, so A100 decode is ~1.3x slower —
     a hardware-blind JSQ (least-loaded) balances prefill backlog straight
     into TBT-SLO violations on the A100 side. Decode-aware dispatch prices
     the downstream knee (DecodeCostModel.step_time / TBT SLO) and wins on
     end-to-end goodput (acceptance: >= 1.15x over least-loaded JSQ).
  b) mixed A800/TPU-v5e pool, prefill-only: peak prefill throughput differs
     ~1.6x, so capacity-weighted JSQ routes proportionally more work to the
     faster card than blind cycling.
  c) online predictor refit: an A800-fitted TTFT prior deployed on TPU-v5e
     instances (~1.6x slower — A100 would be a no-op prior, its prefill curve
     matches A800's); OnlineTTFTPredictor converges to the instance's true
     cost curve from observed prefill latencies (rel. error before/after).
"""
import numpy as np

from benchmarks.common import cached_trace
from repro.core.metrics import max_goodput
from repro.sim.cluster import simulate_cluster
from repro.sim.costmodel import (A100, A800, TPU_V5E, MODEL_SPECS, MODEL_TP,
                                 PrefillCostModel)
from repro.traces.qwentrace import TraceConfig, generate

MIXED_A800_A100 = [A800, A800, A100, A100]
MIXED_A800_TPU = [A800, A800, TPU_V5E, TPU_V5E]
POLICIES = ("round-robin", "least-loaded", "capacity-weighted",
            "decode-aware")
RATES = [8, 12, 16, 20, 24, 28]
TBT_SLO = 0.018                      # ~55 tok/s/stream: binds A100 decode
OUTPUT_MEAN = 256


def e2e_goodput(policy, *, pool, rates=RATES, duration=40, seed=3,
                model="llama3-8b"):
    atts = []
    for rate in rates:
        reqs = cached_trace(rate=rate, duration=duration, seed=seed,
                            model=model, output_mean=OUTPUT_MEAN,
                            tbt_slo=TBT_SLO)
        res = simulate_cluster("flowprefill", reqs, model=model,
                               hardware=pool, decode_hardware=pool,
                               decode_instances=len(pool), dispatch=policy)
        atts.append(res.e2e_attainment)
    return max_goodput(rates, atts), atts


def prefill_goodput(policy, *, pool, rates, duration=40, seed=3):
    atts = []
    dispatched = None
    for rate in rates:
        reqs = cached_trace(rate=rate, duration=duration, seed=seed)
        res = simulate_cluster("flowprefill", reqs, hardware=pool,
                               dispatch=policy)
        atts.append(res.attainment)
        dispatched = res.dispatched
    return max_goodput(rates, atts), atts, dispatched


def refit_error(hardware, prior_hw=A800, *, model="llama3-8b", rate=8,
                duration=40, seed=3):
    """Mean relative TTFT-prediction error of the per-instance predictors
    against the instance's true cost curve, before vs after an online-refit
    run with a `prior_hw`-fitted prior."""
    from dataclasses import replace

    spec = replace(MODEL_SPECS[model], tp=MODEL_TP.get(model, 1))
    prior_cost = PrefillCostModel(spec, prior_hw)
    true_cost = PrefillCostModel(spec, hardware)
    probe = np.linspace(256, 24576, 16)

    def err(predict):
        rel = [abs(predict(n) - true_cost.prefill_time(int(n)))
               / true_cost.prefill_time(int(n)) for n in probe]
        return float(np.mean(rel))

    from repro.sim.cluster import ClusterSim
    from repro.sim.policies import preset
    import copy

    sim = ClusterSim(prior_cost, preset("flowprefill"), num_instances=2,
                     hardware=[hardware, hardware], predictor=None,
                     dispatch="least-loaded", online_refit=True)
    # hetero pools fit per-instance predictors from their own hardware; the
    # mis-calibration under study is the dispatch-level prior — force it onto
    # the engines to model "profile shipped from the wrong generation"
    sim.instance_predictors = [sim.predictor] * 2
    before = err(sim.predictor.predict)
    reqs = generate(TraceConfig(rate=rate, duration=duration, seed=seed))
    sim.run(copy.deepcopy(reqs))
    after = float(np.mean([err(p.predict) for p in sim.run_predictors]))
    return before, after


def run(model="llama3-8b"):
    rows = []
    # (a) A800/A100 + decode: e2e goodput per policy
    goodputs = {}
    for policy in POLICIES:
        g, atts = e2e_goodput(policy, pool=MIXED_A800_A100, model=model)
        goodputs[policy] = g
        rows.append((f"fig19/{model}/a800-a100/{policy}/goodput_req_s",
                     round(g, 2),
                     "e2e att@rates=" + "|".join(f"{a:.2f}" for a in atts)))
    jsq = goodputs["least-loaded"]
    for policy in ("capacity-weighted", "decode-aware"):
        if jsq > 0:
            rows.append((f"fig19/{model}/a800-a100/{policy}_vs_jsq",
                         round(goodputs[policy] / jsq, 2),
                         "goodput ratio vs load-blind JSQ "
                         "(acceptance: decode-aware >= 1.15)"))
    # (b) A800/TPU-v5e prefill-only: capacity-weighted routing
    rates = [6, 9, 12, 15, 18, 21, 24]
    shares = {}
    for policy in ("round-robin", "least-loaded", "capacity-weighted"):
        g, atts, disp = prefill_goodput(policy, pool=MIXED_A800_TPU,
                                        rates=rates)
        shares[policy] = sum(disp[:2]) / max(sum(disp), 1)
        rows.append((f"fig19/{model}/a800-tpu/{policy}/goodput_req_s",
                     round(g, 2),
                     "TTFT att@rates=" + "|".join(f"{a:.2f}" for a in atts)))
    rows.append((f"fig19/{model}/a800-tpu/capacity-weighted/fast_share",
                 round(shares["capacity-weighted"], 3),
                 f"fraction routed to A800 half (round-robin="
                 f"{shares['round-robin']:.3f}, "
                 f"least-loaded={shares['least-loaded']:.3f})"))
    # (c) online predictor refit on a mis-calibrated prior (A800 prior
    # deployed on TPU-v5e instances)
    before, after = refit_error(TPU_V5E, prior_hw=A800, model=model)
    rows.append((f"fig19/{model}/refit/prior_rel_err", round(before, 4),
                 "A800-fitted prior evaluated on TPU-v5e truth"))
    rows.append((f"fig19/{model}/refit/refit_rel_err", round(after, 4),
                 "after online refit from observed prefill latencies"))
    return rows
