"""Fig. 15 — FlowPrefill combined with chunked prefill: chunking tightens the
blocking-time bound for very long inputs (one operator on 32K tokens is still
long), at the cost of splitting overhead — an intermediate chunk balances."""
import numpy as np

from repro.core.metrics import max_goodput
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [1, 2, 4, 6, 8, 12, 16]


def run():
    rows = []
    for chunk in (0, 2048, 4096, 8192, 16384):
        atts, blocks = [], []
        for rate in RATES:
            reqs = generate(TraceConfig(rate=rate, duration=50, seed=3))
            res = simulate("flowprefill", reqs, chunk_tokens=chunk)
            atts.append(res.attainment)
            blocks.extend(res.blocking_times)
        name = "none" if chunk == 0 else f"{chunk//1024}k"
        rows.append((f"fig15/chunk_{name}/goodput_req_s",
                     round(max_goodput(RATES, atts), 2),
                     f"mean_blocking_ms="
                     f"{np.mean(blocks)*1e3 if blocks else 0:.2f}"))
    return rows
