"""Fig. 13 — TTFT prediction accuracy: polynomial offline fit vs realized
prefill latency on trace-distributed lengths."""
import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.sim.costmodel import A800, LLAMA3_8B, PrefillCostModel
from repro.traces.qwentrace import TraceConfig, generate


def run():
    cost = PrefillCostModel(LLAMA3_8B, A800)
    pred = TTFTPredictor.from_cost_model(cost.prefill_time, max_tokens=32768)
    reqs = generate(TraceConfig(rate=10, duration=60, seed=7))
    errs = []
    for r in reqs:
        actual = cost.prefill_time(r.num_tokens)
        errs.append(abs(pred.predict(r.num_tokens) - actual) / max(actual, 1e-9))
    return [
        ("fig13/predictor_mape_pct", round(float(np.mean(errs)) * 100, 2),
         f"n={len(errs)} p99={np.percentile(errs, 99)*100:.2f}%"),
    ]
