"""§Roofline — three-term roofline per (arch x shape) cell from the dry-run
artifacts (results/dryrun/*.json), TPU v5e single-pod (16x16 = 256 chips):

    compute term    = HLO_FLOPs_global / (chips * 197e12 FLOP/s)
    memory term     = HLO_bytes_global / (chips * 819e9 B/s)
    collective term = collective_bytes_global / (chips * 50e9 B/s)

The dry-run JSONs store per-device numbers from the partitioned module
(scan-trip-count corrected); global = per_device * chips. MODEL_FLOPS uses
6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode, one token).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link / chip

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if cfg.is_encoder_decoder:
        # whisper: prefill = the encoder over 1500 frames (+cross-KV proj);
        # train = encoder + decoder; decode = decoder layers only
        enc_tokens = shape.global_batch * cfg.encoder_seq
        if shape.kind == "prefill":
            return 2.0 * n_active * enc_tokens
        if shape.kind == "train":
            return 6.0 * n_active * (tokens + enc_tokens) / 2.0
        return 2.0 * (n_active / 2.0) * shape.global_batch
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: one token/req


def analytic_hbm_bytes(arch: str, shape_name: str, chips: int) -> float:
    """TPU-realistic per-step HBM traffic estimate (global, bytes).

    The HLO 'bytes accessed' from the CPU backend overstates TPU traffic —
    the CPU pipeline fuses far less, and the Pallas attention kernel keeps its
    online-softmax state in VMEM where the XLA fallback round-trips it. This
    analytic model is what a tuned TPU lowering moves:
      weights (TP-sharded reads, x3 for fwd+bwd+remat in training),
      optimizer state (16 B/param, ZeRO-sharded -> counted once globally),
      KV cache (read for decode / written for prefill),
      activations (tokens x d_model x L x alpha bytes, alpha: residency factor).
    """
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tp = 16                                  # 'model' axis
    n_params = cfg.param_count()
    w_bytes = 2.0 * n_params                 # bf16
    tokens = shape.global_batch * shape.seq_len
    d, L = cfg.d_model, cfg.num_layers

    # global weight reads: every DP replica streams its TP shard
    if shape.kind == "train":
        w_traffic = 3.0 * w_bytes * (chips // tp)   # fwd + bwd + remat
        opt = 16.0 * n_params                 # fp32 m+v read/write, ZeRO once
        act = tokens * d * L * 24 * 2.0
        return w_traffic + opt + act
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            tokens = shape.global_batch * cfg.encoder_seq
        w_traffic = w_bytes * (chips // tp)
        act = tokens * d * L * 12 * 2.0
        cache = _cache_bytes(cfg, shape)
        return w_traffic + act + cache
    # decode
    w_traffic = w_bytes * (chips // tp)
    cache = _cache_bytes(cfg, shape)
    act = shape.global_batch * d * L * 12 * 2.0
    return w_traffic + cache + act


def _cache_bytes(cfg, shape) -> float:
    """Total KV/state cache bytes for this cell (global)."""
    import numpy as np

    from repro.models.model import cache_shapes
    total = 0
    for name, (shp, dtype) in cache_shapes(
            cfg, shape.global_batch, shape.seq_len).items():
        size = int(np.prod(shp)) if shp else 1
        total += size * np.dtype(dtype).itemsize
    return float(total)


def load_cells(mesh: str = "pod1") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    chips = cell.get("devices", 256)
    flops_g = cell["flops"] * chips
    bytes_hlo_g = cell["bytes_accessed"] * chips
    bytes_ana_g = analytic_hbm_bytes(cell["arch"], cell["shape"], chips)
    coll_g = cell["collective_total"] * chips
    t_c = flops_g / (chips * PEAK_FLOPS)
    t_m_hlo = bytes_hlo_g / (chips * HBM_BW)
    t_m = bytes_ana_g / (chips * HBM_BW)
    t_n = coll_g / (chips * ICI_BW)
    # dominance from the TPU-realistic terms (HLO bytes reported alongside;
    # CPU-backend fusion inflates them — see EXPERIMENTS.md §Roofline notes)
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    mf = model_flops(cell["arch"], cell["shape"])
    bound = max(t_c, t_m, t_n)
    ideal = mf / (chips * PEAK_FLOPS)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "memory_hlo_s": t_m_hlo,
        "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": flops_g,
        "useful_ratio": mf / flops_g if flops_g else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "memory_bytes_per_device": cell.get("memory", {}),
    }


def run():
    rows = []
    for cell in load_cells("pod1"):
        a = analyze(cell)
        if a is None:
            rows.append((f"roofline/{cell['arch']}/{cell['shape']}/skipped",
                         0.0, cell.get("reason", cell.get("error", ""))[:80]))
            continue
        rows.append((
            f"roofline/{a['arch']}/{a['shape']}/{a['dominant']}_bound",
            round(max(a["compute_s"], a["memory_s"], a["collective_s"]) * 1e3, 3),
            f"ms; c={a['compute_s']*1e3:.2f} m={a['memory_s']*1e3:.2f} "
            f"n={a['collective_s']*1e3:.2f} useful={a['useful_ratio']:.2f} "
            f"roofline_frac={a['roofline_fraction']:.2f}"))
    return rows


def table(mesh: str = "pod1") -> str:
    """Markdown table for EXPERIMENTS.md."""
    lines = ["| arch | shape | compute (ms) | memory (ms) | mem-HLO (ms) | "
             "collective (ms) | dominant | MODEL/HLO | roofline frac | note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for cell in load_cells(mesh):
        if cell.get("status") == "skipped":
            lines.append(f"| {cell['arch']} | {cell['shape']} | — | — | — | — | "
                         f"skip | — | — | {cell['reason']} |")
            continue
        if cell.get("status") != "ok":
            lines.append(f"| {cell['arch']} | {cell['shape']} | — | — | — | — | "
                         f"ERROR | — | — | {cell.get('error','')[:60]} |")
            continue
        a = analyze(cell)
        note = {
            "compute": "more FLOP/s: better MXU util / less remat",
            "memory": "cut bytes: fuse, cache layout, quantize KV",
            "collective": "reshard: cut all-gathers / overlap",
        }[a["dominant"]]
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']*1e3:.2f} | "
            f"{a['memory_s']*1e3:.2f} | {a['memory_hlo_s']*1e3:.2f} | "
            f"{a['collective_s']*1e3:.2f} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} | {note} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
