"""Fig. 12 — average preemption blocking time under operator- vs layer-level
boundaries. Two measurements:
  (sim)  cluster-scale A800 calibration — the paper's 3.5-4.2x claim;
  (real) the actual threaded executor on CPU with a tiny model — proves the
         mechanism's bound end-to-end (dispatch-window x op time).
"""
import numpy as np

from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate


def run():
    rows = []
    reqs = generate(TraceConfig(rate=6, duration=60, seed=4))
    blocking = {}
    for gran in ("op", "layer", "chunk"):
        kw = dict(granularity=gran)
        if gran == "chunk":
            kw["chunk_tokens"] = 2048
        res = simulate("flowprefill", reqs, **kw)
        b = np.mean(res.blocking_times) if res.blocking_times else 0.0
        blocking[gran] = b
        rows.append((f"fig12/sim/{gran}/mean_blocking_ms", round(b * 1e3, 3),
                     f"max={max(res.blocking_times or [0])*1e3:.1f}ms "
                     f"n={len(res.blocking_times)}"))
    if blocking["op"] > 0:
        rows.append(("fig12/sim/layer_over_op_ratio",
                     round(blocking["layer"] / blocking["op"], 2),
                     "paper: 3.5-4.2x"))
    return rows


def run_real():
    """Real-executor blocking measurement (slower; used by examples)."""
    import dataclasses
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_tiny_config
    from repro.core import Request, SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.models.segments import SegmentedPrefill
    from repro.serving.prefill_instance import PrefillInstance

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pred = TTFTPredictor(coeffs=np.array([2e-4, 0.0]), floor=0.0)
    rows = []
    for gran in ("op", "layer", "whole"):
        ex = SegmentedPrefill(params, cfg, max_seq=4096, granularity=gran,
                              chunk_tokens=512)
        ex.run_all(ex.start(jnp.zeros((1, 4096), jnp.int32)))  # warm
        ex.run_all(ex.start(jnp.zeros((1, 128), jnp.int32)))
        core = SchedulerCore(predictor=pred, enable_batching=False)
        inst = PrefillInstance(params, cfg, core, max_seq=4096, executor=ex)
        try:
            rng = np.random.default_rng(0)
            A = Request(num_tokens=4096, slo=60.0, arrival=time.monotonic())
            inst.submit_request(A, rng.integers(0, cfg.vocab_size, 4096))
            time.sleep(0.3)
            B = Request(num_tokens=128, slo=5.0, arrival=time.monotonic())
            inst.submit_request(B, rng.integers(0, cfg.vocab_size, 128))
            if not inst.drain(120.0):
                raise RuntimeError(
                    f"fig12 {gran}: instance did not drain; blocking stats "
                    f"would be measured on incomplete work")
            b = inst.blocking_stats.mean
            rows.append((f"fig12/real/{gran}/mean_blocking_ms",
                         round(b * 1e3, 2),
                         f"n={len(inst.blocking_stats.samples)}"))
        finally:
            inst.shutdown()
    return rows
