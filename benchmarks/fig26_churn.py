"""Fig. 26 (robustness extension) — instance churn and SLO-aware shedding.

Two panels characterize the fault-tolerance layer (docs/ARCHITECTURE.md
failure model, docs/SCHEDULING.md shedding):

**Panel A — churn.** A burst-then-outage schedule on a 2-instance cluster:
a spot preemption drains instance 1 (1s notice) and a crash takes instance
0 the moment the spot wave lands, so the whole pool is down for 4s right
after a 120-request arrival burst queued deep backlogs. Both variants run
the SAME trace and `FaultPlan`:

  * ``fault_tolerant`` — supervised recovery: stranded work re-dispatched
    with backoff under a retry budget. Gated: overall ``attainment`` (every
    stranded request recovers within the 16s SLO), ``lost_requests`` == 0
    (exact-zero gate: ANY lost request under recovery is a correctness
    regression, not a perf drift), and the finite ``e2e_p99_norm`` tail.
  * ``naive`` — recovery="none": stranded requests are lost and count as
    +inf tail events (the PR 6 convention), so its attainment collapses to
    the surviving fraction and its p99 is +inf (reported as a note, not a
    row — the committed JSON stays finite).

The headline gate is the **recovery ratio** ``fault_tolerant_vs_naive``
(attainment ratio on the same churn schedule, acceptance threshold >= 1.5).

**Panel B — overload shedding.** A 30s steady 2x-overload trace: without
admission control every queue grows without bound and the tail poisons
every request; ``doomed-only`` shedding rejects exactly the requests whose
predicted TTFT already exceeds their SLO while the pool is saturated.
Gated per shedding policy: ``admitted_attainment`` (the requests we said
yes to are actually served on time) and ``admitted_ttft_p99_norm`` (their
tail stays within SLO). The no-shedding collapse is reported as ungated
context rows (``noshed_att``, ``noshed_tail_norm``) — they are the
motivation, not the contract.
"""
import numpy as np

from repro.core import Request
from repro.core.faults import FaultEvent, FaultPlan
from repro.sim.cluster import simulate_cluster

SEED = 0
SLO = 16.0                  # churn SLO: generous enough that recovery (full
                            # re-prefill after the outage) can still meet it
N_INSTANCES = 2
BURST_AT, BURST_N = 10.0, 120
OUTAGE = 4.0

# the churn schedule: spot drains instance 1 (notice 1s, dies at 11s),
# crash takes instance 0 at the same instant — a total 4s pool outage
# right after the burst, rejoining together at 15s
PLAN = FaultPlan(events=(
    FaultEvent(time=10.0, instance=1, kind="spot", notice=1.0,
               duration=OUTAGE),
    FaultEvent(time=11.0, instance=0, kind="crash", duration=OUTAGE),
))

SHED_RATE, SHED_SLO = 20.0, 4.0      # ~2x capacity of the 2-instance pool


def _poisson_trace(rng, rate, duration, slo):
    reqs, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration:
            break
        reqs.append(Request(num_tokens=int(rng.integers(800, 4000)),
                            slo=slo, arrival=round(t, 4)))
    return reqs


def churn_trace():
    """4 req/s Poisson background + a 120-request burst at t=10 — the
    backlog the outage strands."""
    rng = np.random.default_rng(SEED)
    reqs = _poisson_trace(rng, 4.0, 40.0, SLO)
    reqs += [Request(num_tokens=int(rng.integers(800, 4000)), slo=SLO,
                     arrival=round(BURST_AT + 0.005 * i, 4))
             for i in range(BURST_N)]
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def _admitted_stats(res):
    adm = [r for r in res.requests if not r.shed]
    att = sum(r.slo_met for r in adm) / max(len(adm), 1)
    norms = [(r.first_token_time - r.arrival) / r.slo
             if r.first_token_time is not None else np.inf for r in adm]
    return adm, att, float(np.percentile(norms, 99))


def run(model="llama3-8b"):
    rows = []

    # ---------------- Panel A: churn, fault-tolerant vs naive -------------
    reqs = churn_trace()
    res = {}
    for variant, kw in (("naive", dict(recovery="none")),
                        ("fault_tolerant",
                         dict(recovery="retry", max_retries=5))):
        res[variant] = simulate_cluster(
            "flowprefill", reqs, model=model, num_instances=N_INSTANCES,
            dispatch="least-loaded", fault_plan=PLAN, **kw)
    ft, naive = res["fault_tolerant"], res["naive"]
    sched = (f"spot@10s(notice 1s)+crash@11s; {OUTAGE:.0f}s total outage; "
             f"{len(reqs)} reqs")
    rows.append((f"fig26/{model}/churn/fault_tolerant/attainment",
                 round(ft.attainment, 4),
                 f"supervised recovery on {sched}; {ft.retries} retries"))
    rows.append((f"fig26/{model}/churn/naive_att",
                 round(naive.attainment, 4),
                 f"recovery=none on the SAME schedule: {naive.lost_requests}"
                 f" stranded requests lost (+inf tail); context; ungated"))
    rows.append((f"fig26/{model}/churn/fault_tolerant_vs_naive",
                 round(ft.attainment / naive.attainment, 3),
                 "recovery ratio (attainment; same trace+plan); acceptance "
                 "threshold 1.5"))
    rows.append((f"fig26/{model}/churn/fault_tolerant/lost_requests",
                 ft.lost_requests,
                 "exact-zero gate: recovery may never lose a request "
                 "(naive loses "
                 f"{naive.lost_requests} on this schedule)"))
    rows.append((f"fig26/{model}/churn/fault_tolerant/e2e_p99_norm",
                 round(ft.e2e_p99_norm, 3),
                 "p99 SLO-normalized e2e under churn (naive's is +inf: "
                 "lost requests are +inf tail events)"))
    rows.append((f"fig26/{model}/churn/ft_retries", ft.retries,
                 "re-dispatches performed by recovery (context; ungated)"))

    # ---------------- Panel B: overload shedding --------------------------
    shed_reqs = _poisson_trace(np.random.default_rng(SEED + 1),
                               SHED_RATE, 30.0, SHED_SLO)
    noshed = simulate_cluster("flowprefill", shed_reqs, model=model,
                              num_instances=N_INSTANCES,
                              dispatch="least-loaded", shed_policy="off")
    _, ns_att, ns_p99 = _admitted_stats(noshed)
    for pol, kw in (("doomed-only", {}),
                    ("budget", dict(shed_budget=1.5))):
        r = simulate_cluster("flowprefill", shed_reqs, model=model,
                             num_instances=N_INSTANCES,
                             dispatch="least-loaded", shed_policy=pol, **kw)
        adm, att, p99 = _admitted_stats(r)
        rows.append((f"fig26/{model}/shed/{pol}/admitted_attainment",
                     round(att, 4),
                     f"{len(adm)}/{len(shed_reqs)} admitted at 2x overload "
                     f"({r.shed_requests} shed)"))
        rows.append((f"fig26/{model}/shed/{pol}/admitted_ttft_p99_norm",
                     round(p99, 3),
                     "admitted-only p99(TTFT/SLO) — shedding must hold the "
                     "tail it promised"))
        rows.append((f"fig26/{model}/shed/{pol}/shed_fraction",
                     round(r.shed_requests / len(shed_reqs), 3),
                     "context (ungated): the price paid for the held tail"))
    rows.append((f"fig26/{model}/shed/noshed_att", round(ns_att, 4),
                 "no admission control at the same 2x overload (context; "
                 "ungated: the collapse shedding prevents)"))
    rows.append((f"fig26/{model}/shed/noshed_tail_norm", round(ns_p99, 3),
                 "p99(TTFT/SLO) with shedding off — the poisoned tail "
                 "(context; ungated)"))
    return rows
