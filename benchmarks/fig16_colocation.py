"""Fig. 16 — PD-colocation (simplified model): prefill and decode share the
device; decode load taxes prefill efficiency. We model colocation as a
utilization tax on the prefill cost model (decode steals ~35% of compute) and
compare FlowPrefill vs vLLM-CP2K on TTFT attainment. TBT effects are noted
qualitatively (EXPERIMENTS.md) — decode optimization is out of the paper's
scope (§4)."""
import dataclasses

from repro.core.metrics import max_goodput
from repro.sim.costmodel import A800
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [0.5, 1, 2, 4, 6, 8]
COLOCATED = dataclasses.replace(A800, eff_c=A800.eff_c * 0.65,
                                hbm_bw=A800.hbm_bw * 0.65)


def run():
    rows = []
    for name, system in (("flowprefill", "flowprefill"),
                         ("vllm-cp2k", "distserve-cp2k")):
        atts = []
        for rate in RATES:
            # colocated: half the GPUs -> relaxed TTFT SLO (3x, paper §6.5)
            reqs = generate(TraceConfig(rate=rate, duration=50, seed=3,
                                        slo_scale=3.0))
            atts.append(simulate(system, reqs, hw=COLOCATED).attainment)
        rows.append((f"fig16/{name}/goodput_req_s",
                     round(max_goodput(RATES, atts), 2),
                     "att=" + "|".join(f"{a:.2f}" for a in atts)))
    return rows
