"""Fig. 16 — PD-colocation (paper-faithful SIMPLIFIED model): prefill and
decode share the device; decode load taxes prefill efficiency. We model
colocation as a hard-coded utilization tax on the prefill cost model
(decode steals ~35% of compute) and compare FlowPrefill vs vLLM-CP2K on
TTFT attainment. TBT effects are noted qualitatively (EXPERIMENTS.md) —
decode optimization is out of the paper's scope (§4).

NOTE: this figure is kept as the paper's approximation. The MEASURED
counterpart is `benchmarks/fig24_colocation.py`, where `HybridSim` prices
prefill chunks and woven decode steps into one budget-capped step from the
same `PrefillCostModel`/`DecodeCostModel` the dedicated engines use — the
interference there is computed from the workload (and validated against
the real `HybridInstance` runtime), not assumed."""
import dataclasses

from repro.core.metrics import max_goodput
from repro.sim.costmodel import A800
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [0.5, 1, 2, 4, 6, 8]
# the paper's fixed 0.65 guess — fig24's HybridSim replaces this with
# measured, workload-dependent interference (a ~50% prefill "weave tax" at
# tight TBT SLOs, near-zero when hybrids offload decode to dedicated cards)
COLOCATED = dataclasses.replace(A800, eff_c=A800.eff_c * 0.65,
                                hbm_bw=A800.hbm_bw * 0.65)


def run():
    rows = []
    for name, system in (("flowprefill", "flowprefill"),
                         ("vllm-cp2k", "distserve-cp2k")):
        atts = []
        for rate in RATES:
            # colocated: half the GPUs -> relaxed TTFT SLO (3x, paper §6.5)
            reqs = generate(TraceConfig(rate=rate, duration=50, seed=3,
                                        slo_scale=3.0))
            atts.append(simulate(system, reqs, hw=COLOCATED).attainment)
        rows.append((f"fig16/{name}/goodput_req_s",
                     round(max_goodput(RATES, atts), 2),
                     "att=" + "|".join(f"{a:.2f}" for a in atts)))
    return rows
