"""Fig. 11 — SLO-aware batching under varying batch token budgets vs no
batching: attainment (risk grows with budget) and throughput (no batching
lowest, diminishing returns past 4K)."""
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate


def run(rate=40, duration=60, seed=3):
    rows = []
    reqs = generate(TraceConfig(rate=rate, duration=duration, seed=seed))
    for name, system, kw in (
            ("none", "flowprefill-nobatch", {}),
            ("2k", "flowprefill", dict(batch_budget=2048)),
            ("4k", "flowprefill", dict(batch_budget=4096)),
            ("8k", "flowprefill", dict(batch_budget=8192))):
        res = simulate(system, reqs, **kw)
        thr = len(res.requests) / res.makespan
        rows.append((f"fig11/budget_{name}/throughput_req_s", round(thr, 2),
                     f"attainment={res.attainment:.3f} rate={rate}"))
    return rows
