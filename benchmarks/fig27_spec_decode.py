"""Fig. 27 (speculative-decoding extension) — tokens/s and TBT attainment of
speculative decoding inside the batched ragged decode runtime, across accept
regimes.

The runtime drafts per resident stream, scores all k+1 positions of every row
in ONE batched `decode_verify_ragged` pass, and commits the longest
greedy-matching prefix — output is bit-identical to plain greedy decoding
(pinned by tests/test_spec_decode.py), so the ONLY question this figure
answers is throughput: how much faster per accepted token, and what the
overhead costs when drafts never hit.

Panels (real runtime, tiny llama3-8b derivative on CPU — the serving tests'
config):

  a) high-accept regime: an ORACLE drafter (drafts the stream's known greedy
     continuation from a reference replay) makes every draft position accept,
     so each verify step commits k+1 tokens. The tiny seeded model greedy-
     decodes pseudorandom token sequences, so the natural n-gram drafter has
     nothing to match — the oracle isolates the runtime's ceiling at accept
     rate ~1 exactly like a well-matched draft corpus would on real text.
     Gated: tokens/s >= 1.5x plain decode.
  b) adversarial low-accept regime: every draft token is chosen to MISS, the
     worst case for speculation. The per-stream accept-rate EMA throttles
     drafting (probe 1-in-spec_probe_period steps), and an all-rows-empty
     draft step delegates to the plain batched step — so the cost of being
     wrong is bounded. Gated: tokens/s >= 0.9x plain (no-regression floor).
  c) cluster sim (deterministic, seeded): `ClusterSim` advances decode
     streams from the SAME analytic accept surface the runtime's EMA
     converges to (`expected_accept_tokens`), so TBT attainment and mean
     TPOT under load are gated exactly — the evaluated policy is the
     deployed one.

Wall-clock-derived metric convention (docs/BENCHMARKS.md): the committed
speedup baselines are CONSERVATIVE floors pre-compensated for the gate's
tolerance, not one machine's measurements; the sim rows are deterministic
and committed exactly.
"""
import dataclasses
import time

DRAFT_K = 4
OUT_TOKENS = 48
PROMPTS = (32, 48, 80, 100)      # measured streams (one batch of 4)
MAX_SEQ = 256
SIM_ACCEPT = 0.8                 # panel c's accept surface


def _bench_model():
    import jax

    from repro.configs.base import get_tiny_config
    from repro.models import init_params

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    return init_params(cfg, jax.random.PRNGKey(0)), cfg


def _handoff(params, cfg, n, seed):
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model import prefill

    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    logits, cache = prefill(params, cfg, {"tokens": toks}, max_seq=MAX_SEQ)
    return int(jnp.argmax(logits, -1)[0]), \
        {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]}


def _replay(params, cfg, first, cache, n_tokens):
    import jax.numpy as jnp

    from repro.models.model import decode_step

    tok = jnp.asarray([first], jnp.int32)
    c = dict(cache)
    out = []
    for _ in range(n_tokens):
        logits, c = decode_step(params, cfg, tok, c)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def _decode_run(params, cfg, streams, *, spec, draft_fn=None):
    """Decode every stream twice on one instance — an unmeasured warmup pass
    that compiles every bucketed shape the run touches, then the timed pass.
    Returns (elapsed_s, instance, jobs)."""
    from repro.core.request import Request
    from repro.serving.decode_instance import DecodeInstance, DecodeJob

    def jobs_of(ss):
        out = []
        for first, cache in ss:
            req = Request(num_tokens=int(cache["pos"]), slo=100.0,
                          arrival=0.0, output_tokens=OUT_TOKENS,
                          tbt_slo=100.0)
            out.append(DecodeJob(request=req, cache=dict(cache),
                                 first_token=first))
        return out

    inst = DecodeInstance(params, cfg, decode_tokens=OUT_TOKENS,
                          decode_max_batch=len(streams), kv_block_size=64,
                          spec_decode=spec, draft_k=DRAFT_K,
                          draft_fn=draft_fn)
    try:
        warm = jobs_of(streams)
        for j in warm:
            inst.submit(j)
        if not inst.drain(300.0):
            raise RuntimeError("warmup drain timed out")
        jobs = jobs_of(streams)
        t0 = time.monotonic()
        for j in jobs:
            inst.submit(j)
        if not inst.drain(300.0):
            raise RuntimeError("measured drain timed out")
        elapsed = time.monotonic() - t0
    finally:
        inst.shutdown()
    return elapsed, inst, jobs


def run(model="llama3-8b"):
    params, cfg = _bench_model()
    streams = [_handoff(params, cfg, n, seed=200 + i)
               for i, n in enumerate(PROMPTS)]
    # reference greedy continuations: the oracle drafter's corpus AND the
    # bit-parity check below (+DRAFT_K so the final step can draft fully)
    seqs = [_replay(params, cfg, f, c, OUT_TOKENS + DRAFT_K)
            for f, c in streams]
    # draft_fn receives (rid, history, k); history[0] is the prefill's
    # argmax token, so (first_token, generated prefix) must be a prefix of
    # the reference [first] + seq chain — match streams by first token
    # (distinct across the 4 prompts by construction of the seeds)
    by_first = {f: s for (f, _), s in zip(streams, seqs)}
    assert len(by_first) == len(streams), "first tokens must be distinct"

    def oracle(rid, history, k):
        seq = by_first[history[0]]
        done = len(history) - 1          # generated so far (past first)
        return seq[done:done + k]

    def adversarial(rid, history, k):
        seq = by_first[history[0]]
        done = len(history) - 1
        # one token guaranteed != the true greedy continuation: the first
        # draft position always rejects, accept rate is exactly 0
        return [(seq[done] + 1) % cfg.vocab_size] if done < len(seq) else []

    t_plain, _, _ = _decode_run(params, cfg, streams, spec=False)
    t_hi, inst_hi, jobs_hi = _decode_run(params, cfg, streams, spec=True,
                                         draft_fn=oracle)
    t_lo, inst_lo, _ = _decode_run(params, cfg, streams, spec=True,
                                   draft_fn=adversarial)

    # bit-parity sanity (the pinned test is authoritative; this catches a
    # broken bench harness before it publishes a meaningless speedup)
    for j, (f, _) in zip(jobs_hi, streams):
        want = by_first[f][OUT_TOKENS - 1]
        if j.next_token != want:
            raise RuntimeError(f"spec decode diverged: {j.next_token} != "
                               f"{want} (rid {j.request.rid})")

    total = len(streams) * OUT_TOKENS
    rows = []
    for label, t in (("plain", t_plain), ("high_accept", t_hi),
                     ("low_accept", t_lo)):
        rows.append((f"fig27/{model}/tokens_per_s_{label}",
                     round(total / t, 1),
                     f"{total} tokens in {t * 1e3:.0f} ms (measured, "
                     f"runner-speed dependent — not gated)"))
    hi_accept = inst_hi.draft_accepted / max(inst_hi.draft_proposed, 1)
    rows.append((f"fig27/{model}/high_accept_vs_plain_speedup",
                 round(t_plain / t_hi, 2),
                 f"oracle drafter (accept rate {hi_accept:.2f}, "
                 f"{len(inst_hi.tbt_samples) / max(inst_hi.row_steps, 1):.2f}"
                 f" tokens/step): one k+1-wide verify pass replaces up to "
                 f"k+1 plain steps (acceptance: >= 1.5; committed baseline "
                 f"is the tolerance-compensated conservative threshold)"))
    rows.append((f"fig27/{model}/low_accept_vs_plain_speedup",
                 round(t_plain / t_lo, 2),
                 f"adversarial drafter (accept rate 0, {inst_lo.spec_steps} "
                 f"of {inst_lo.steps} steps verify-shaped after EMA "
                 f"throttling): speculation overhead must stay within the "
                 f"0.9x no-regression floor"))

    rows.extend(_sim_rows(model))
    return rows


def _sim_rows(model):
    """Panel c: deterministic cluster-sim TBT outcomes under load, spec off
    vs on — the accept surface the scheduler prices (S-EDF slack, migration,
    hybrid budgets) is the one the fluid model advances by."""
    from repro.sim.cluster import simulate_cluster
    from repro.traces.qwentrace import TraceConfig, generate

    reqs = generate(TraceConfig(rate=10.0, duration=30.0, seed=2,
                                output_mean=200.0, tbt_slo=0.02))
    kw = dict(num_instances=2, decode_instances=2, decode_max_batch=8,
              decode_policy="s-edf")
    plain = simulate_cluster("flowprefill", reqs, **kw)
    spec = simulate_cluster("flowprefill", reqs, spec_decode=True,
                            draft_k=DRAFT_K, spec_accept=SIM_ACCEPT, **kw)

    def mean_tpot(res):
        ts = [r.mean_tpot for r in res.requests if r.mean_tpot is not None]
        return sum(ts) / max(len(ts), 1)

    return [
        (f"fig27/{model}/sim_tbt_attainment_plain",
         round(plain.tbt_attainment, 4),
         "decode-stage TBT-SLO attainment, spec off (deterministic seeded "
         "sim — gated exactly)"),
        (f"fig27/{model}/sim_tbt_attainment_spec",
         round(spec.tbt_attainment, 4),
         f"TBT-SLO attainment with spec_decode on (accept {SIM_ACCEPT}, "
         f"k={DRAFT_K}): multi-token steps lift the loaded decode stage "
         f"(deterministic — gated exactly)"),
        (f"fig27/{model}/sim_tpot_spec_vs_plain_speedup",
         round(mean_tpot(plain) / max(mean_tpot(spec), 1e-12), 3),
         "mean-TPOT ratio plain/spec under identical load (deterministic "
         "— gated exactly)"),
    ]
