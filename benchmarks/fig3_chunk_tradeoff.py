"""Fig. 3 — throughput and latency of chunked prefill for a 32K-token input
under different chunk sizes (Llama3-8B). Small chunks collapse throughput
(weight re-reads + launch overheads); large chunks recover it but lengthen the
uninterruptible unit."""
from repro.sim.costmodel import A100, LLAMA3_8B, PrefillCostModel


def run():
    cost = PrefillCostModel(LLAMA3_8B, A100)
    rows = []
    tokens = 32768
    base = cost.prefill_time(tokens, 0)
    for chunk in (256, 512, 1024, 2048, 4096, 8192, 16384, 32768):
        t = cost.prefill_time(tokens, chunk)
        thr = tokens / t
        rows.append((f"fig3/chunk{chunk}/throughput_tok_s", round(thr, 1),
                     f"latency={t:.3f}s overhead_vs_unchunked={t/base:.2f}x"))
    return rows
