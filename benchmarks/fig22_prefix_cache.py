"""Fig. 22 (prefix-sharing extension) — skip recomputing shared prompt
prefixes end-to-end: goodput/TTFT vs prefix-cache hit rate, and the measured
real-runtime speedup of a cached prefill.

Production prompts share massive prefixes (per-task system prompts,
multi-turn resubmission), yet without sharing every request prefills from
token 0 — the single largest avoidable cost on the TTFT path FlowPrefill
optimizes. This figure evaluates the full stack built on block-level prefix
sharing: the `PrefixBlockManager` residency model (refcounts + trie + LRU),
`PrefillCostModel.op_durations(prefix=...)` suffix-only pricing, and the
`prefix-affinity` dispatch policy that routes a request to the instance
holding its prefix KV unless queue pressure outweighs the saving
(docs/SCHEDULING.md).

Panels:

  a) headline sweep — 4xA800 prefill pool on a ~60%-hit-rate trace
     (class-shared system prompts + multi-turn resubmission,
     `TraceConfig.shared_prefix_frac` / `multi_turn_prob`), TTFT goodput of:
       * no-sharing        (capacity-weighted, the pre-sharing system),
       * sharing + blind   (capacity-weighted: hits only by luck of routing),
       * sharing + prefix-affinity.
     Acceptance (CI-gated): prefix-affinity >= 2x no-sharing goodput, AND
     prefix-affinity > blind (the dispatch policy matters, not just the
     cache — an affinity-blind router scatters multi-turn follow-ups away
     from their conversation's KV).
  b) hit-rate sweep — the same three-way comparison across trace mixes from
     no sharing to heavy multi-turn: goodput gain vs achieved hit rate.
  c) real runtime — a `PrefillInstance` with a prefix-sharing `PagedKVCache`
     on the tiny bench model: measured prefill latency of a fully-cached
     prompt (suffix-only compute: trie probe -> pinned prefix ->
     `SegmentedPrefill` resumes at the cached operator offset) vs the same
     prompt cold. Acceptance (CI-gated): warm >= 3x faster. Wall-clock
     convention (docs/BENCHMARKS.md): the committed baseline is the
     conservative tolerance-compensated threshold, not one machine's
     measurement (steady-state CPU measures 20-40x).
"""
import dataclasses
import time

from repro.core.metrics import max_goodput
from repro.sim.cluster import simulate_cluster
from repro.traces.qwentrace import TraceConfig, generate, oracle_hit_rate

RATES = [8, 16, 24, 32, 48, 64]
N_INSTANCES = 4
CACHE_BLOCKS = 2048                  # per-instance residency (x128 tokens)
HEADLINE = dict(shared_prefix_frac=0.25, multi_turn_prob=0.75)  # ~60% hit
HIT_PROBE_RATE = 16                  # rate the achieved hit rate is read at
DURATION = 30
SEED = 3

# (label, trace mix) for the hit-rate sweep — no sharing to heavy multi-turn
SWEEP = (
    ("mix0", dict(shared_prefix_frac=0.0, multi_turn_prob=0.0)),
    ("mix1", dict(shared_prefix_frac=0.15, multi_turn_prob=0.3)),
    ("mix2", dict(shared_prefix_frac=0.25, multi_turn_prob=0.55)),
    ("mix3", HEADLINE),
)

VARIANTS = (
    ("no-sharing", dict(dispatch="capacity-weighted")),
    ("blind", dict(dispatch="capacity-weighted",
                   prefix_cache_blocks=CACHE_BLOCKS)),
    ("prefix-affinity", dict(dispatch="prefix-affinity",
                             prefix_cache_blocks=CACHE_BLOCKS)),
)


def _trace(rate, mix):
    return generate(TraceConfig(rate=rate, duration=DURATION, seed=SEED,
                                **mix))


def _goodput(mix, variant_kw):
    atts, hits = [], {}
    for rate in RATES:
        res = simulate_cluster("flowprefill", _trace(rate, mix),
                               num_instances=N_INSTANCES, **variant_kw)
        atts.append(res.attainment)
        hits[rate] = res.prefix_hit_rate
    return max_goodput(RATES, atts), atts, hits


def run(model="llama3-8b"):
    rows = []
    # (a) headline: three variants on the ~60%-hit trace
    goodputs, hit_at = {}, {}
    for name, kw in VARIANTS:
        g, atts, hits = _goodput(HEADLINE, kw)
        goodputs[name], hit_at[name] = g, hits[HIT_PROBE_RATE]
        rows.append((f"fig22/{model}/{name}/goodput_req_s", round(g, 2),
                     "TTFT att@rates=" + "|".join(f"{a:.2f}" for a in atts)))
    rows.append((f"fig22/{model}/hit_rate",
                 round(hit_at["prefix-affinity"], 3),
                 f"prefix-affinity achieved hit rate at {HIT_PROBE_RATE} "
                 f"req/s (trace oracle "
                 f"{oracle_hit_rate(_trace(HIT_PROBE_RATE, HEADLINE)):.3f})"))
    rows.append((f"fig22/{model}/blind_hit_rate",
                 round(hit_at["blind"], 3),
                 "affinity-blind dispatch achieved hit rate (same trace/"
                 "cache): the routing, not just the cache, makes the hits"))
    ns = goodputs["no-sharing"]
    if ns > 0:
        rows.append((f"fig22/{model}/prefix-affinity_vs_no-sharing",
                     round(goodputs["prefix-affinity"] / ns, 2),
                     "TTFT-goodput ratio (acceptance: >= 2.0 at the ~60% "
                     "hit-rate trace)"))
    if goodputs["blind"] > 0:
        rows.append((f"fig22/{model}/prefix-affinity_vs_blind",
                     round(goodputs["prefix-affinity"] / goodputs["blind"],
                           2),
                     "goodput ratio over affinity-blind capacity-weighted "
                     "dispatch with the SAME cache (acceptance: > 1.0)"))

    # (b) hit-rate sweep: goodput gain vs achieved hit rate
    for label, mix in SWEEP:
        g_ns, _, _ = _goodput(mix, dict(VARIANTS[0][1]))
        g_aff, _, hits = _goodput(mix, dict(VARIANTS[2][1]))
        ratio = g_aff / g_ns if g_ns > 0 else 0.0
        rows.append((f"fig22/{model}/sweep/{label}/gain_vs_hit_rate",
                     round(ratio, 2),
                     f"affinity/no-sharing goodput at achieved hit rate "
                     f"{hits[HIT_PROBE_RATE]:.2f} "
                     f"(oracle {oracle_hit_rate(_trace(HIT_PROBE_RATE, mix)):.2f})"))

    # (c) real runtime: measured warm-vs-cold prefill on the bench model
    rows.extend(run_runtime(model))
    return rows


def run_runtime(model="llama3-8b", *, prompt_tokens=2048, chunk=512,
                repeats=3):
    """Measured `PrefillInstance` latency: identical prompt cold (first
    submission: full prefill + cache insert) vs warm (second submission:
    trie hit, suffix-only compute — here a single live token). Shapes are
    warmed first so the numbers are steady-state, not compile time."""
    import jax
    import numpy as np

    from repro.configs.base import get_tiny_config
    from repro.core import Request, SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.serving.prefill_instance import PrefillInstance

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pred = TTFTPredictor(coeffs=np.array([1e-6, 0.0]), floor=0.0)
    inst = PrefillInstance(
        params, cfg, SchedulerCore(predictor=pred, enable_batching=False),
        max_seq=prompt_tokens, chunk_tokens=chunk, prefix_share=True,
        prefix_cache_blocks=16 * (repeats + 2) * 2)
    rng = np.random.default_rng(0)

    def run_once(toks):
        req = Request(num_tokens=len(toks), slo=600.0,
                      arrival=time.monotonic())
        t0 = time.monotonic()
        inst.submit_request(req, toks)
        assert inst.drain(600.0), \
            f"instance did not drain serving rid {req.rid}"
        return time.monotonic() - t0, req

    try:
        warmup = rng.integers(0, cfg.vocab_size, prompt_tokens)
        run_once(warmup)                       # compile cold shapes
        run_once(warmup)                       # compile warm (suffix) shapes
        colds, warms = [], []
        hit = 0
        for _ in range(repeats):
            toks = rng.integers(0, cfg.vocab_size, prompt_tokens)
            c, _ = run_once(toks)
            w, wr = run_once(toks)
            colds.append(c)
            warms.append(w)
            hit = wr.prefix_hit
    finally:
        inst.shutdown()
    cold = float(np.median(colds))
    warm = float(np.median(warms))
    return [
        (f"fig22/{model}/real/cold_ms", round(cold * 1e3, 1),
         f"median full prefill of {prompt_tokens} tokens (measured, "
         f"runner-speed dependent — not gated)"),
        (f"fig22/{model}/real/warm_ms", round(warm * 1e3, 1),
         f"median cached-prefix prefill, hit={hit} tokens (suffix-only "
         f"compute; measured — not gated)"),
        (f"fig22/{model}/real/warm_vs_cold_speedup",
         round(cold / warm, 2),
         "measured prefill speedup on a fully-cached prefix (acceptance: "
         ">= 3.0; committed baseline is the tolerance-compensated "
         "conservative threshold, steady-state CPU measures 20-40x)"),
    ]
