"""Fig. 14 — single-SLO ShareGPT-like workload: FlowPrefill matches baseline
throughput (operator-level preemption checks cost ~nothing when unused) while
keeping SLO attainment at least as high."""
from repro.sim.policies import simulate
from repro.traces.qwentrace import sharegpt_like


def run():
    rows = []
    for rate in (4.0, 8.0, 12.0):
        reqs = sharegpt_like(n=400, rate=rate, seed=5)
        rf = simulate("flowprefill", reqs)
        rc = simulate("distserve-cp2k", reqs)
        rows.append((f"fig14/rate{rate}/flowprefill_attainment",
                     round(rf.attainment, 3),
                     f"cp2k={rc.attainment:.3f} "
                     f"thr_ratio={(len(reqs)/rf.makespan)/(len(reqs)/rc.makespan):.3f}"))
    return rows
