"""Fig. 23 (production-traffic extension) — p99-goodput frontier per stress
scenario: the tail-gated counterpart of every mean/attainment-gated figure.

The paper's goodput claim is an *attainment* claim evaluated on production
traces where tail TTFT, not mean TTFT, decides SLO violations. This figure
re-characterizes the scheduling stack at the tail on the fitted scenario
suite (`repro.traces.scenarios`, docs/TRACES.md): for each scenario, the
policy the scenario is designed to punish vs the robust alternative, both
measured two ways on the SAME traces —

  * ``p99_goodput_req_s`` — max rate whose p99 SLO-normalized end-to-end
    latency stays <= 1 (`percentile_goodput`; unfinished requests count as
    +inf tail events). CI-gated, higher is better.
  * ``att_goodput_req_s`` — the classic 90%-attainment goodput
    (`max_goodput`) on the same attainment samples, reported so the
    mean-vs-tail ORDERING gap is visible in one artifact: aggregate
    attainment can sit above 0.9 while the p99 tail is several SLOs out
    (the flood scenario is built to produce exactly that).
  * ``e2e_p99_norm`` at the probe rate — the raw tail statistic. CI-gated,
    LOWER is better (the `p99` gate family in benchmarks/compare.py).

Cluster under test: 4 prefill + 4 decode instances, decode slot cap 16,
per-instance prefix caches — the full production stack PR 2-5 built, so
every prior policy (S-EDF prefill, decode S-EDF, prefix-affinity) is
exercised against traffic engineered to find its tail."""
from benchmarks.common import cached_scenario_trace
from repro.core.metrics import max_goodput, percentile_goodput
from repro.sim.cluster import simulate_cluster

PROBE_RATE = 8                        # rate the raw p99 rows are read at
N_INSTANCES = 4
MAX_BATCH = 16                        # decode KV slot cap
CACHE_BLOCKS = 2048                   # per-instance prefix cache (x128 tok)
DURATION = 60                         # p99 needs samples: >=~240 reqs/rate
SEED = 3

# per-scenario rate grid, bracketing where that scenario's p99 frontier
# actually crosses 1.0 (the chat mixtures hold their tail to ~30+ req/s on
# this cluster; the adversarial scenarios collapse far earlier). PROBE_RATE
# must appear in every grid.
RATES_BY = {
    "fitted-chat": [8, 16, 24, 32, 48],
    "diurnal": [8, 16, 24, 32, 48],
    "heavy-tail": [4, 8, 12, 16, 24],
    "prefix-adversary": [4, 8, 12, 16, 24],
    "flood": [4, 6, 8, 12, 16],
}

BASE_KW = dict(num_instances=N_INSTANCES, decode_instances=N_INSTANCES,
               decode_max_batch=MAX_BATCH, prefix_cache_blocks=CACHE_BLOCKS)

# per-scenario matchup: (variant name, simulate_cluster kwargs — merged
# over BASE_KW, so a matchup can also shrink the cluster to saturate the
# resource its scenario targets, or override the per-instance prefill
# `policy`). The first variant is the policy the scenario punishes, the
# second the robust alternative (docs/TRACES.md names the intent per
# scenario); the gated ratio row is second_vs_first.
MATCHUPS = {
    "fitted-chat": (
        ("round-robin", dict(dispatch="round-robin", decode_policy="s-edf")),
        ("least-loaded", dict(dispatch="least-loaded",
                              decode_policy="s-edf")),
    ),
    "diurnal": (
        ("round-robin", dict(dispatch="round-robin", decode_policy="s-edf")),
        ("deflection", dict(dispatch="deflection", decode_policy="s-edf")),
    ),
    # 2 decode instances (not 4): the Pareto output tail must actually
    # contend for KV slots, or admission order is irrelevant and both
    # decode policies coincide
    "heavy-tail": (
        ("fcfs-decode", dict(dispatch="least-loaded", decode_policy="fcfs",
                             decode_instances=2)),
        ("s-edf-decode", dict(dispatch="least-loaded", decode_policy="s-edf",
                              decode_instances=2)),
    ),
    "prefix-adversary": (
        ("prefix-affinity", dict(dispatch="prefix-affinity")),
        ("capacity-weighted", dict(dispatch="capacity-weighted")),
    ),
    # deadline-blind FCFS prefill admission vs S-EDF on the same flooded
    # cluster ("policy" reaches the per-instance scheduler via preset
    # overrides): the flood's tight-SLO burst collapses FCFS outright
    "flood": (
        ("fcfs-prefill", dict(dispatch="least-loaded", decode_policy="s-edf",
                              policy="fcfs")),
        ("s-edf-prefill", dict(dispatch="least-loaded",
                               decode_policy="s-edf")),
    ),
}


def _frontier(scenario, kw, model):
    """(p99 goodput, attainment goodput, p99 norms, attainments)."""
    norms, atts = [], []
    for rate in RATES_BY[scenario]:
        reqs = cached_scenario_trace(scenario=scenario, rate=rate,
                                     duration=DURATION, seed=SEED,
                                     model=model)
        res = simulate_cluster("flowprefill", reqs, model=model,
                               **{**BASE_KW, **kw})
        norms.append(res.e2e_p99_norm)
        atts.append(res.e2e_attainment)
    rates = RATES_BY[scenario]
    return (percentile_goodput(rates, norms), max_goodput(rates, atts),
            norms, atts)


def run(model="llama3-8b"):
    rows = []
    for scenario, matchup in MATCHUPS.items():
        rates = RATES_BY[scenario]
        goodputs = {}
        for name, kw in matchup:
            p99_g, att_g, norms, atts = _frontier(scenario, kw, model)
            goodputs[name] = p99_g
            rows.append((f"fig23/{model}/{scenario}/{name}/p99_goodput_req_s",
                         round(p99_g, 2),
                         "p99(e2e/SLO)@" + "|".join(
                             f"r{r}:{v:.2f}" for r, v in zip(rates, norms))))
            rows.append((f"fig23/{model}/{scenario}/{name}/att_goodput_req_s",
                         round(att_g, 2),
                         "mean-gated goodput on the SAME runs; e2e att@"
                         + "|".join(f"r{r}:{a:.2f}"
                                    for r, a in zip(rates, atts))))
            probe = norms[rates.index(PROBE_RATE)]
            rows.append((f"fig23/{model}/{scenario}/{name}/e2e_p99_norm",
                         round(probe, 3),
                         f"p99 SLO-normalized e2e latency at {PROBE_RATE} "
                         f"req/s (p99 gate family: LOWER is better)"))
            if p99_g > 0:
                # how far the mean-gated capacity claim overstates what
                # the tail can sustain — the motivating number for tail
                # gating (docs/BENCHMARKS.md). Deliberately NOT a gated
                # name: a tail IMPROVEMENT shrinks it, which must not
                # read as a regression.
                rows.append((
                    f"fig23/{model}/{scenario}/{name}/mean_tail_gap_x",
                    round(att_g / p99_g, 2),
                    "attainment-gated / p99-gated goodput (>1: the mean "
                    "hides a tail this many times worse; informational)"))
        (punished, _), (robust, _) = matchup
        if goodputs[punished] > 0:
            rows.append((f"fig23/{model}/{scenario}/{robust}_vs_{punished}",
                         round(goodputs[robust] / goodputs[punished], 2),
                         "p99-goodput ratio (the scenario is built to "
                         f"punish {punished}; a 0-capacity punished "
                         "variant suppresses this row)"))
    return rows
