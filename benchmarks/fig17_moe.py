"""Fig. 17 — MoE generality (Qwen3-30B-A3B, TP=2): operator-level preemption
with the gate/experts fused-operator boundaries still beats chunk baselines."""
from repro.core.metrics import max_goodput
from repro.sim.policies import simulate
from repro.traces.qwentrace import TraceConfig, generate

RATES = [2, 4, 8, 16, 24, 32, 48, 64]
MODEL = "qwen3-30b-a3b"


def run():
    rows = []
    gp = {}
    for system in ("distserve-cp2k", "distserve-cp8k", "flowprefill"):
        atts = []
        for rate in RATES:
            reqs = generate(TraceConfig(rate=rate, duration=40, seed=3,
                                        model=MODEL))
            atts.append(simulate(system, reqs, model=MODEL).attainment)
        gp[system] = max_goodput(RATES, atts)
        rows.append((f"fig17/{system}/goodput_req_s", round(gp[system], 2),
                     "att=" + "|".join(f"{a:.2f}" for a in atts)))
    if gp["distserve-cp2k"] > 0:
        rows.append(("fig17/flowprefill_vs_cp2k",
                     round(gp["flowprefill"] / gp["distserve-cp2k"], 2),
                     "paper: up to 1.6x"))
    return rows
