"""CI benchmark-regression gate.

Diffs a fresh ``--json-out`` bench run against the committed reference
results in ``benchmarks/baselines/`` and exits nonzero when a gated metric
regresses beyond tolerance — so the perf trajectory is *enforced* on every
push, not just uploaded as an artifact someone might read.

Two gated families (see docs/BENCHMARKS.md):

  * higher-is-better SLO outcomes (name contains ``goodput``,
    ``attainment``, ``_vs_`` ratios, or ``share``): a drop beyond tolerance
    fails — this includes the fig23 ``p99_goodput`` frontier rows;
  * lower-is-better metrics: error families (name contains ``rel_err``,
    e.g. the fig19 online-refit prediction errors) and the ``p99`` tail
    family (``p99_norm`` / ``ttft_p99`` / ``tbt_p99`` — SLO-normalized
    tail latencies from the fig23 scenario suite): a RISE beyond tolerance
    fails. Production SLOs gate on tails; a regression that leaves the
    mean alone but fattens the p99 must trip.

Wall-clock and harness bookkeeping rows are ignored (they vary with runner
speed — the simulator metrics themselves are deterministic, seeded
discrete-event results, so cross-machine values match exactly and the
tolerance only absorbs intentional drift).

    python -m benchmarks.compare --baseline benchmarks/baselines \
        --fresh bench-artifacts [--tolerance 0.10] \
        [--summary-out "$GITHUB_STEP_SUMMARY"]

``--summary-out`` appends a per-metric markdown table (baseline vs fresh vs
bound, pass/fail) to the given file — CI points it at
``$GITHUB_STEP_SUMMARY`` so gate trips are readable on the run page without
downloading artifacts.

Refreshing baselines after an intentional perf change:

    PYTHONPATH=src python -m benchmarks.run --only fig9,fig18,fig19,fig20 \
        --json-out benchmarks/baselines
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# substrings of metric names that are gated, higher is better ("speedup"
# covers the fig21/fig22 measured wall-clock curves and the fig27
# speculative-decoding accept-regime family — high_accept_vs_plain_speedup /
# low_accept_vs_plain_speedup / sim_tpot_spec_vs_plain_speedup all match
# "_vs_"+"speedup", sim_tbt_attainment_* matches "attainment"; "hit_rate"
# the fig22 prefix-cache residency outcomes)
GATED = ("goodput", "attainment", "_vs_", "share", "speedup", "hit_rate")
# substrings of metric names that are gated, LOWER is better: error families,
# the p99 tail family (SLO-normalized tail latencies), and `lost_requests`
# (fig26: a 0 baseline makes this an exact-zero gate — losing ANY request
# under recovery is a correctness regression, not perf drift). NOTE: checked
# before GATED, so a name matching both is lower-is-better — which is why
# the fig23 frontier rows are named `p99_goodput_req_s` (matches `goodput`
# only: the frontier is a rate, higher is better) while raw tail rows end
# in `p99_norm` / `ttft_p99` / `tbt_p99`.
GATED_LOWER = ("rel_err", "p99_norm", "ttft_p99", "tbt_p99", "lost_requests")
# metric-name substrings never gated (runner-speed or error bookkeeping)
SKIPPED = ("_elapsed_s", "/_error", "/_real_error")


def is_gated_lower(name: str) -> bool:
    """Lower-is-better gated metric: regression = value RISING."""
    if any(s in name for s in SKIPPED):
        return False
    return any(s in name for s in GATED_LOWER)


def is_gated(name: str) -> bool:
    """Higher-is-better gated metric: regression = value dropping."""
    if any(s in name for s in SKIPPED) or is_gated_lower(name):
        return False
    return any(s in name for s in GATED)


def load_dir(path: str) -> Dict[str, Dict[str, float]]:
    """{bench name: {metric: value}} for every BENCH_*.json in `path`."""
    out: Dict[str, Dict[str, float]] = {}
    for f in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        name = d.get("bench") or os.path.basename(f)[6:-5]
        out[name] = {k: v for k, v in d.get("metrics", {}).items()
                     if isinstance(v, (int, float))}
    return out


def compare(baseline: Dict[str, Dict[str, float]],
            fresh: Dict[str, Dict[str, float]],
            tolerance: float) -> Tuple[List[str], List[str], List[dict]]:
    """Returns (report lines, regression lines, per-metric records).
    Each record: {name, base, new, bound, delta, ok} — the structured form
    `write_summary` renders as the CI step-summary table."""
    lines: List[str] = []
    regressions: List[str] = []
    records: List[dict] = []
    for bench, base_metrics in sorted(baseline.items()):
        if bench not in fresh:
            regressions.append(
                f"{bench}: no fresh BENCH_{bench}.json (bench vanished "
                f"or failed — its _error row is not a metric)")
            records.append({"name": f"{bench} (whole bench)", "base": "—",
                            "new": "missing", "bound": "—", "delta": "—",
                            "ok": False})
            continue
        fresh_metrics = fresh[bench]
        for name, base in sorted(base_metrics.items()):
            lower = is_gated_lower(name)
            if not (is_gated(name) or lower):
                continue
            if name not in fresh_metrics:
                regressions.append(f"{name}: gated metric missing from "
                                   f"fresh run (baseline={base})")
                records.append({"name": name, "base": base, "new": "missing",
                                "bound": "—", "delta": "—", "ok": False})
                continue
            new = fresh_metrics[name]
            if lower:
                # base == 0 is a perfect error score: ANY positive fresh
                # value is an unambiguous regression (no division-safety
                # excuse here, unlike the higher-is-better floor)
                ceil = base * (1.0 + tolerance)
                bad = new > ceil if base > 0 else new > 0
                bound = f"ceiling {ceil:.3g}"
            else:
                floor = base * (1.0 - tolerance)
                bad = base > 0 and new < floor
                bound = f"floor {floor:.3g}"
            delta = f"{(new / base - 1.0) * 100:+.1f}%" if base else "n/a"
            records.append({"name": name, "base": base, "new": new,
                            "bound": bound, "delta": delta, "ok": not bad})
            if bad:
                regressions.append(f"{name}: {base} -> {new} "
                                   f"({delta}, {bound})")
            else:
                lines.append(f"  ok {name}: {base} -> {new} ({delta})")
    for bench in sorted(set(fresh) - set(baseline)):
        lines.append(f"  new bench (no baseline, not gated): {bench}")
    return lines, regressions, records


def write_summary(path: str, records: List[dict], tolerance: float,
                  n_benches: int) -> None:
    """Append the gate outcome as a markdown table (GitHub step summary)."""
    n_fail = sum(1 for r in records if not r["ok"])
    verdict = "✅ PASS" if n_fail == 0 else f"❌ FAIL ({n_fail} regression(s))"
    out = [
        f"## Benchmark gate: {verdict}",
        "",
        f"{n_benches} baseline bench(es), {len(records)} gated metrics, "
        f"tolerance ±{tolerance:.0%}.",
        "",
        "| metric | baseline | fresh | bound | Δ | status |",
        "|---|---:|---:|---|---:|---|",
    ]
    # failures first so a long table never buries the trip
    for r in sorted(records, key=lambda r: r["ok"]):
        status = "ok" if r["ok"] else "**FAIL**"
        out.append(f"| `{r['name']}` | {r['base']} | {r['new']} | "
                   f"{r['bound']} | {r['delta']} | {status} |")
    with open(path, "a") as fh:
        fh.write("\n".join(out) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail (exit 1) when a gated benchmark metric regresses "
                    "vs the committed baselines")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory with reference BENCH_*.json files")
    ap.add_argument("--fresh", required=True,
                    help="directory with the fresh run's BENCH_*.json files")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative drop for gated metrics "
                    "(default 0.10 = -10%%)")
    ap.add_argument("--summary-out", default=None, metavar="FILE",
                    help="append a per-metric markdown table to FILE "
                    "(CI passes $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)

    baseline = load_dir(args.baseline)
    if not baseline:
        print(f"error: no BENCH_*.json baselines in {args.baseline!r}",
              file=sys.stderr)
        return 2
    fresh = load_dir(args.fresh)
    lines, regressions, records = compare(baseline, fresh, args.tolerance)
    if args.summary_out:
        write_summary(args.summary_out, records, args.tolerance,
                      len(baseline))

    print(f"benchmark gate: {len(baseline)} baseline bench(es), "
          f"tolerance -{args.tolerance:.0%}")
    for ln in lines:
        print(ln)
    if regressions:
        print(f"\nREGRESSIONS ({len(regressions)}):", file=sys.stderr)
        for r in regressions:
            print(f"  FAIL {r}", file=sys.stderr)
        return 1
    print("benchmark gate: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
