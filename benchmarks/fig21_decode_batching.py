"""Fig. 21 (decode-batching extension) — measured decode step-time curve of
the REAL continuous-batching runtime: one jitted `decode_step_ragged` over
all resident streams, paged KV (`PagedKVCache.gather_batch`/`write_tokens`).

Decode is bandwidth-bound: weights are streamed once per step regardless of
how many streams share it, so tokens/s should scale near-linearly with the
resident batch B — the behavior the simulator's `DecodeSim`/`DecodeCostModel`
has assumed since PR 3 and the runtime only now delivers (the old
`DecodeInstance` decoded one stream at a time).

Panels:

  a) tokens/s vs B on the bench config (tiny llama3-8b derivative on CPU —
     the same reduced config the serving tests drive): per-step wall time is
     measured by `profile_step_times` from the real jitted step, after jit
     warmup. Acceptance (CI-gated): B=8 >= 3x B=1 tokens/s.
  b) sim-vs-runtime step-time agreement: the measured samples seed a
     `MeasuredStepTime` surface (`DecodeStepPredictor.from_profile`) — the
     profiled prior the TBT-slack scheduler prices loads with. Gated metric:
     the surface's mean relative error over the measured samples (the
     runtime's deployed latency model must track the hardware it runs on).
     The analytic `DecodeCostModel` prior's error after one-scale calibration
     is reported alongside (ungated — CPU is not the A800 it models).

Wall-clock-derived metric convention (docs/BENCHMARKS.md): the committed
baselines for this figure are CONSERVATIVE floors/ceilings (acceptance
thresholds), not the measured values of one machine, so the gate tracks the
claim (>= 3x scaling, sane fit) instead of runner-speed noise.
"""
import dataclasses

from repro.core.predictor import MeasuredStepTime

BATCH_SIZES = (1, 2, 4, 8)
CTXS = (128, 320)        # two context points per B: 8 samples for the
                         # 3-parameter MeasuredStepTime fit (one point per B
                         # leaves the fit hostage to a single noisy median)
CTX = CTXS[0]            # the tokens/s scaling panel's operating point
DECODE_TOKENS = 24
WARMUP = 4


def _bench_model():
    import jax

    from repro.configs.base import get_tiny_config
    from repro.models import init_params

    cfg = dataclasses.replace(get_tiny_config("llama3_8b"),
                              num_layers=2, d_model=128, d_ff=256)
    return init_params(cfg, jax.random.PRNGKey(0)), cfg


def run(model="llama3-8b"):
    from repro.serving.decode_instance import profile_step_times
    from repro.sim.costmodel import (A800, DecodeCostModel, ModelSpec)

    params, cfg = _bench_model()
    by_ctx = {c: profile_step_times(params, cfg, batch_sizes=BATCH_SIZES,
                                    ctx=c, decode_tokens=DECODE_TOKENS,
                                    warmup=WARMUP, kv_block_size=128)
              for c in CTXS}
    samples = [s for c in CTXS for s in by_ctx[c]]
    rows = []
    tps = {}
    for b, mean_ctx, t_step in by_ctx[CTX]:
        tps[b] = b / t_step
        rows.append((f"fig21/{model}/tokens_per_s_b{b}",
                     round(tps[b], 1),
                     f"B={b} ctx~{mean_ctx:.0f}: {t_step * 1e3:.2f} ms/step "
                     f"(measured, runner-speed dependent — not gated)"))
    b_lo, b_hi = BATCH_SIZES[0], BATCH_SIZES[-1]
    rows.append((f"fig21/{model}/b{b_hi}_vs_b{b_lo}_speedup",
                 round(tps[b_hi] / tps[b_lo], 2),
                 f"tokens/s scaling of the batched jitted step "
                 f"(acceptance: >= 3.0; committed baseline is the "
                 f"tolerance-compensated conservative threshold)"))

    # measured prior fit quality (the deployed latency model) — gated
    fit = MeasuredStepTime.fit(samples)
    rows.append((f"fig21/{model}/measured_prior_rel_err",
                 round(fit.rel_err(samples), 4),
                 "mean |fit - measured| / measured of the profiled "
                 "step_time(B, ctx) surface over the sweep (gated: a rise "
                 "means the runtime's latency model stopped tracking the "
                 "real step)"))

    # analytic prior after one-scale calibration at B=1 — informational
    spec = ModelSpec.from_config(cfg)
    analytic = DecodeCostModel(spec, A800)
    scale = samples[0][2] / analytic.step_time(1, samples[0][1])
    errs = [abs(scale * analytic.step_time(b, c) - t) / t
            for b, c, t in samples]
    rows.append((f"fig21/{model}/analytic_prior/_real_error",
                 round(sum(errs) / len(errs), 3),
                 "analytic DecodeCostModel (A800 spec) vs CPU measurements "
                 "after one-scale calibration at B=1 — why the measured "
                 "profile replaces the analytic seed (not gated)"))
    return rows
