"""Benchmark harness — one module per paper table/figure. Prints
``name,value,derived`` CSV. ``python -m benchmarks.run [--only fig9] [--real]``.
"""
import argparse
import sys
import time
import traceback

from benchmarks import (fig3_chunk_tradeoff, fig4_batching, fig9_goodput,
                        fig10_policies, fig11_budget, fig12_blocking,
                        fig13_predictor, fig14_single_slo,
                        fig15_chunk_interplay, fig16_colocation, fig17_moe,
                        roofline)

MODULES = [
    ("fig3", fig3_chunk_tradeoff),
    ("fig4", fig4_batching),
    ("fig9", fig9_goodput),
    ("fig10", fig10_policies),
    ("fig11", fig11_budget),
    ("fig12", fig12_blocking),
    ("fig13", fig13_predictor),
    ("fig14", fig14_single_slo),
    ("fig15", fig15_chunk_interplay),
    ("fig16", fig16_colocation),
    ("fig17", fig17_moe),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--real", action="store_true",
                    help="also run real-executor measurements (fig12)")
    args = ap.parse_args()

    print("name,value,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.monotonic()
        try:
            for row in mod.run():
                print(f"{row[0]},{row[1]},{row[2]}")
            print(f"{name}/_elapsed_s,{time.monotonic()-t0:.1f},harness")
        except Exception as e:  # noqa
            failures += 1
            print(f"{name}/_error,1,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        if args.real and hasattr(mod, "run_real"):
            try:
                for row in mod.run_real():
                    print(f"{row[0]},{row[1]},{row[2]}")
            except Exception as e:  # noqa
                failures += 1
                print(f"{name}/_real_error,1,{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
