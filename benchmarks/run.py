"""Benchmark harness — one module per paper table/figure. Prints
``name,value,derived`` CSV. ``python -m benchmarks.run [--only fig9] [--real]
[--json-out DIR]`` (``--json-out`` also writes one ``BENCH_<fig>.json`` per
module — the CI perf-trajectory artifact).
"""
import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import (fig3_chunk_tradeoff, fig4_batching, fig9_goodput,
                        fig10_policies, fig11_budget, fig12_blocking,
                        fig13_predictor, fig14_single_slo,
                        fig15_chunk_interplay, fig16_colocation, fig17_moe,
                        fig18_cluster, fig19_hetero, fig20_decode,
                        fig21_decode_batching, fig22_prefix_cache,
                        fig23_scenarios, fig24_colocation, fig25_tiered_kv,
                        fig26_churn, fig27_spec_decode, roofline)

MODULES = [
    ("fig3", fig3_chunk_tradeoff),
    ("fig4", fig4_batching),
    ("fig9", fig9_goodput),
    ("fig10", fig10_policies),
    ("fig11", fig11_budget),
    ("fig12", fig12_blocking),
    ("fig13", fig13_predictor),
    ("fig14", fig14_single_slo),
    ("fig15", fig15_chunk_interplay),
    ("fig16", fig16_colocation),
    ("fig17", fig17_moe),
    ("fig18", fig18_cluster),
    ("fig19", fig19_hetero),
    ("fig20", fig20_decode),
    ("fig21", fig21_decode_batching),
    ("fig22", fig22_prefix_cache),
    ("fig23", fig23_scenarios),
    ("fig24", fig24_colocation),
    ("fig25", fig25_tiered_kv),
    ("fig26", fig26_churn),
    ("fig27", fig27_spec_decode),
    ("roofline", roofline),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. fig9,fig18)")
    ap.add_argument("--real", action="store_true",
                    help="also run real-executor measurements (fig12)")
    ap.add_argument("--json-out", default=None, metavar="DIR",
                    help="write BENCH_<fig>.json per module into DIR")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only:
        known = {name for name, _ in MODULES}
        unknown = sorted(only - known)
        if unknown:
            # a typo here used to silently run NOTHING and exit green —
            # catastrophic for a CI gate selecting --only fig9,fig18
            ap.error(f"unknown figure name(s): {', '.join(unknown)} "
                     f"(known: {', '.join(sorted(known))})")

    print("name,value,derived")
    failures = 0
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.monotonic()
        try:
            rows = list(mod.run())
            for row in rows:
                print(f"{row[0]},{row[1]},{row[2]}")
            elapsed = time.monotonic() - t0
            print(f"{name}/_elapsed_s,{elapsed:.1f},harness")
            if args.json_out:
                os.makedirs(args.json_out, exist_ok=True)
                payload = {
                    "bench": name,
                    "elapsed_s": round(elapsed, 2),
                    "metrics": {r[0]: r[1] for r in rows},
                    "notes": {r[0]: r[2] for r in rows},
                }
                path = os.path.join(args.json_out, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
        except Exception as e:  # noqa
            failures += 1
            print(f"{name}/_error,1,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        if args.real and hasattr(mod, "run_real"):
            try:
                for row in mod.run_real():
                    print(f"{row[0]},{row[1]},{row[2]}")
            except Exception as e:  # noqa
                failures += 1
                print(f"{name}/_real_error,1,{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
