"""Fig. 18 (cluster extension) — end-to-end cluster goodput: instance count x
dispatch policy on the QwenTrace mixture, Poisson and bursty arrivals, with
the decode-phase TPOT/TBT model enabled (goodput = max rate with >= 90%
end-to-end attainment).

Expected shape: goodput scales with instance count, and the load-aware
policies (least-loaded JSQ, slack-aware deflection) beat blind round-robin —
most visibly under bursty arrivals, where blind cycling piles bursts onto
already-loaded instances."""
from benchmarks.common import cached_trace
from repro.core.metrics import max_goodput
from repro.sim.cluster import simulate_cluster

POLICIES = ("round-robin", "least-loaded", "deflection")
PER_INSTANCE_RATES = [2, 4, 6, 8, 12]
INSTANCE_COUNTS = (1, 2, 4)


def cluster_goodput(num_instances, policy, burstiness=1.0, *,
                    model="llama3-8b", duration=40, seed=3, output_mean=200):
    rates = [r * num_instances for r in PER_INSTANCE_RATES]
    atts = []
    for rate in rates:
        reqs = cached_trace(rate=rate, duration=duration, seed=seed,
                            model=model, burstiness=burstiness,
                            output_mean=output_mean)
        res = simulate_cluster("flowprefill", reqs,
                               num_instances=num_instances, dispatch=policy,
                               decode_instances=num_instances, model=model)
        atts.append(res.e2e_attainment)
    return max_goodput(rates, atts), atts


def run(model="llama3-8b"):
    rows = []
    # goodput vs instance count (Poisson, least-loaded dispatch)
    for n in INSTANCE_COUNTS:
        g, atts = cluster_goodput(n, "least-loaded", model=model)
        rows.append((f"fig18/{model}/least-loaded/n{n}/goodput_req_s",
                     round(g, 2),
                     "e2e att@rates=" + "|".join(f"{a:.2f}" for a in atts)))
    # dispatch policy comparison at n=4, Poisson and bursty
    for scenario, burst in (("poisson", 1.0), ("bursty", 3.0)):
        goodputs = {}
        for policy in POLICIES:
            g, atts = cluster_goodput(4, policy, burstiness=burst,
                                      model=model)
            goodputs[policy] = g
            rows.append((f"fig18/{model}/{scenario}/{policy}/goodput_req_s",
                         round(g, 2),
                         "e2e att@rates=" + "|".join(f"{a:.2f}"
                                                     for a in atts)))
        rr = goodputs["round-robin"]
        for policy in ("least-loaded", "deflection"):
            if rr > 0:
                rows.append((f"fig18/{model}/{scenario}/{policy}_vs_rr",
                             round(goodputs[policy] / rr, 2),
                             "goodput ratio (>1: load-aware dispatch wins)"))
    return rows
