import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell on the production mesh using ShapeDtypeStruct stand-ins (no allocation),
print memory_analysis + cost_analysis, and extract collective traffic from the
partitioned HLO for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-spot]
Results are cached as JSON under results/dryrun/ so runs are incremental.
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                get_config, shape_applicable)
from repro.distributed import sharding as shd
from repro.distributed.collectives import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, AdamWState
from repro.training.train import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

ASSIGNED_ARCHS = ARCH_IDS[:10]           # the 10 assigned (paper models extra)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (input_specs)
# ---------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def tree_sds(shapes_tree, dtype):
    return jax.tree.map(lambda s: sds(s, dtype), shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                param_dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                         param_dtype)
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                  param_dtype)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                         param_dtype)
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                  param_dtype)
        return batch
    # decode: one new token against a seq_len cache
    cache = {k: sds(s, d) for k, (s, d) in
             M.cache_shapes(cfg, B, S, jnp.bfloat16).items()}
    return {"tokens": sds((B,), jnp.int32), "cache": cache}


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return tree_sds(M.model_shapes(cfg), dtype)


def opt_specs(params_sds):
    zeros = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=zeros,
                      v=jax.tree.map(lambda s: s, zeros))


# ---------------------------------------------------------------------------
# Sharding builders
# ---------------------------------------------------------------------------


def batch_sharding(cfg, batch_sds, mesh, rules):
    def spec_for_leafpath(name, s):
        if name in ("tokens", "labels"):
            dims = ("act_batch",) + (None,) * (len(s.shape) - 1)
        else:
            dims = ("act_batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, shd.spec_for(s.shape, dims, mesh, rules))
    return {k: spec_for_leafpath(k, v) for k, v in batch_sds.items()}


def params_sharding(cfg, params_sds, mesh, rules):
    axes = M.param_axes(cfg)
    shapes = jax.tree.map(lambda s: s.shape, params_sds)
    return shd.tree_shardings(axes, shapes, mesh, rules)


def cache_sharding(cfg, cache_sds, mesh, rules):
    axes = M.cache_axes(cfg)
    shapes = {k: v.shape for k, v in cache_sds.items()}
    return {k: NamedSharding(mesh, shd.spec_for(shapes[k], axes[k], mesh, rules))
            for k in cache_sds}


# ---------------------------------------------------------------------------
# Lowering per shape kind
# ---------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """Returns (lowered, args_info_str)."""
    specs = input_specs(cfg, shape)
    p_sds = param_specs(cfg)
    p_shard = params_sharding(cfg, p_sds, mesh, rules)

    if shape.kind == "train":
        opt_sds = opt_specs(p_sds)
        opt_shard = AdamWState(
            step=NamedSharding(mesh, P()),
            m=params_sharding(cfg, opt_sds.m, mesh, rules),
            v=params_sharding(cfg, opt_sds.v, mesh, rules))
        b_shard = batch_sharding(cfg, specs, mesh, rules)
        opt_cfg = AdamWConfig()
        remat = os.environ.get("REPRO_REMAT", "full")
        step = make_train_step(cfg, opt_cfg, attn_impl="auto", remat=remat)
        fn = jax.jit(step,
                     in_shardings=(p_shard, opt_shard, b_shard),
                     out_shardings=(p_shard, opt_shard, None))
        return fn.lower(p_sds, opt_sds, specs)

    if shape.kind == "prefill":
        b_shard = batch_sharding(cfg, specs, mesh, rules)

        def prefill_fn(params, batch):
            return M.prefill(params, cfg, batch, max_seq=shape.seq_len,
                             attn_impl="auto", cache_dtype=jnp.bfloat16)
        fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
        return fn.lower(p_sds, specs)

    # decode
    cache_sds = specs["cache"]
    c_shard = cache_sharding(cfg, cache_sds, mesh, rules)
    tok_shard = NamedSharding(
        mesh, shd.spec_for((shape.global_batch,), ("act_batch",), mesh, rules))

    def decode_fn(params, tokens, cache):
        return M.decode_step(params, cfg, tokens, cache, attn_impl="naive")
    fn = jax.jit(decode_fn, in_shardings=(p_shard, tok_shard, c_shard),
                 out_shardings=None)
    return fn.lower(p_sds, specs["tokens"], cache_sds)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def _layer_unit(cfg: ModelConfig) -> int:
    """Smallest stack unit that scans cleanly (pattern triple / moe pair)."""
    if cfg.family == "hybrid":
        return len(cfg.layer_pattern)
    if cfg.num_experts and cfg.moe_layer_freq == 2:
        return 2
    return 1


def cost_dict(compiled) -> Dict:
    """Normalize Compiled.cost_analysis(): newer jaxlib returns a per-device
    list of dicts, older a single dict (or None)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cell_costs(cfg, shape, mesh, rules):
    """lower+compile and return (flops, bytes, coll_dict, hlo_len)."""
    lowered = lower_cell(cfg, shape, mesh, rules)
    compiled = lowered.compile()
    cost = cost_dict(compiled)
    hlo = compiled.as_text()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            collective_bytes(hlo), compiled)


def corrected_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules) -> Dict:
    """XLA's cost_analysis counts a scan (while-loop) body ONCE, not x trip
    count — so scanned-layer FLOPs/bytes/collectives are undercounted. We
    compile two shallow variants (1 and 2 layer-units) and extrapolate:
        total = f(1u) + (L/unit - 1) * (f(2u) - f(1u))
    which is exact for homogeneous stacks (embed/head live in f(1u))."""
    import dataclasses

    from repro.models.scan_ctl import unrolled_scans
    unit = _layer_unit(cfg)
    n_units = cfg.num_layers / unit
    cfg1 = dataclasses.replace(cfg, num_layers=unit,
                               num_encoder_layers=min(cfg.num_encoder_layers, 1))
    cfg2 = dataclasses.replace(cfg, num_layers=2 * unit,
                               num_encoder_layers=min(cfg.num_encoder_layers, 2))
    with unrolled_scans():
        f1, b1, c1, _ = _cell_costs(cfg1, shape, mesh, rules)
        f2, b2, c2, _ = _cell_costs(cfg2, shape, mesh, rules)
    scale = n_units - 1.0
    coll = {k: int(c1.get(k, 0) + scale * (c2.get(k, 0) - c1.get(k, 0)))
            for k in set(c1) | set(c2)}
    return {
        "flops": f1 + scale * (f2 - f1),
        "bytes_accessed": b1 + scale * (b2 - b1),
        "collective_bytes_per_device": coll,
        "collective_total": int(sum(coll.values())),
        "per_layer_unit": {"flops": f2 - f1, "bytes": b2 - b1,
                           "collective": int(sum(c2.values()) - sum(c1.values()))},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False) -> Dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_name = "pod2" if multi_pod else "pod1"
    out_path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    result: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "kind": shape.kind}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.update(status="skipped", reason=why)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = (shd.train_rules(multi_pod=multi_pod) if shape.kind == "train"
             else shd.serve_rules(multi_pod=multi_pod))
    t0 = time.time()
    try:
        with mesh, shd.use_sharding(mesh, rules):
            # 1) full-depth compile: proves the cell lowers+compiles, gives
            #    memory analysis (buffer sizes are full-depth-correct)
            lowered = lower_cell(cfg, shape, mesh, rules)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = cost_dict(compiled)
            hlo = compiled.as_text()
            raw_coll = collective_bytes(hlo)
            # 2) shallow-extrapolated costs (scan bodies counted x trip count)
            corr = corrected_costs(cfg, shape, mesh, rules)
        result.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=corr["flops"],
            bytes_accessed=corr["bytes_accessed"],
            collective_bytes_per_device=corr["collective_bytes_per_device"],
            collective_total=corr["collective_total"],
            per_layer_unit=corr["per_layer_unit"],
            raw_hlo_flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            raw_collective_total=int(sum(raw_coll.values())),
            memory={
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            devices=int(np.prod(list(mesh.shape.values()))),
        )
        print(f"[ok] {arch} {shape_name} {mesh_name}: "
              f"flops={result['flops']:.3e} "
              f"coll={result['collective_total']:.3e}B "
              f"compile={t_compile:.1f}s", flush=True)
    except Exception as e:  # noqa
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        print(f"[ERROR] {arch} {shape_name} {mesh_name}: {e}", flush=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                r = run_cell(arch, shape_name, mp, force=args.force)
                n_ok += r["status"] == "ok"
                n_err += r["status"] == "error"
                n_skip += r["status"] == "skipped"
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
