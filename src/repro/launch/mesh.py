"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked at first jax init, and only
launch/dryrun.py sets the 512-host-device XLA flag)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axis roles (see distributed/sharding.py):
      pod  — data parallel across pods (gradient sync over DCI)
      data — DP/FSDP within a pod
      model — tensor/expert parallel (heads, ffn, experts, decode kv-seq)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale sharding validation (2x2 / 2x2x2)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
