"""Training launcher: fault-tolerant training of any assigned architecture.

CPU container: reduced configs train for real. TPU runtime: pass
--full-config and a production mesh is bound with the train_rules sharding
(the dry-run proves every (arch x train_4k) cell compiles on it).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config, get_tiny_config
from repro.models import init_params, param_count
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, data_iterator
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train import LoopConfig, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_tiny_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"training {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"seq={args.seq} batch={args.batch}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt_state = init_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=args.remat))
    data = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)

    start = 0
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None and last < args.steps:
        restored = ckpt.restore(args.ckpt_dir, last,
                                {"params": params, "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        start = last
        print(f"auto-resumed from step {last}")

    loop = LoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                      checkpoint_dir=args.ckpt_dir)
    _, _, info = train_loop(cfg, params, opt_state, step,
                            data_iterator(data, start_step=start, model_cfg=cfg),
                            loop, start_step=start)
    print(f"done: {info}")


if __name__ == "__main__":
    main()
