"""Serving launcher: bring up a FlowPrefill PD-disaggregated deployment.

On this CPU container it serves a reduced-config model end-to-end (the same
code path the tests and examples exercise); on a TPU runtime the same launcher
binds the production mesh and the Pallas attention kernels (`--attn pallas`).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \
        --requests 12 --policy s-edf [--granularity op] [--chunk 512]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_tiny_config
from repro.core import Request, SchedulerCore, TTFTPredictor
from repro.core.metrics import attainment_by_task, ttft_stats
from repro.models import init_params
from repro.models.segments import SegmentedPrefill
from repro.serving.decode_instance import DecodeInstance
from repro.serving.prefill_instance import PrefillInstance
from repro.serving.proxy import Proxy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (TPU runtimes)")
    ap.add_argument("--policy", default="s-edf",
                    choices=["s-edf", "d-edf", "edf", "fcfs"])
    ap.add_argument("--granularity", default="op")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--batch-budget", type=int, default=4096)
    ap.add_argument("--attn", default=None, choices=[None, "xla", "pallas"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=4096)
    ap.add_argument("--decode-tokens", type=int, default=4)
    ap.add_argument("--decode-max-batch", type=int, default=4,
                    help="continuous-batching decode slot cap (batched "
                    "jitted step + paged KV; families without a dense "
                    "per-layer KV cache fall back to 1)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="prefix-sharing prompt KV cache: resubmitted "
                    "prompt prefixes (this launcher's mix reuses a few "
                    "fixed lengths of random tokens, so exact repeats "
                    "occur) are served from cache and prefilled "
                    "suffix-only")
    ap.add_argument("--prefix-cache-blocks", type=int, default=512,
                    help="prefix cache capacity in 128-token KV blocks "
                    "(with --prefix-share)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_tiny_config(args.arch)
    attn = args.attn or ("pallas" if jax.default_backend() == "tpu" else "xla")
    print(f"serving {cfg.name} ({cfg.family}) attn={attn} "
          f"granularity={args.granularity} chunk={args.chunk}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    executor = SegmentedPrefill(params, cfg, max_seq=args.max_seq,
                                granularity=args.granularity,
                                chunk_tokens=args.chunk, attn_impl=attn)
    # offline TTFT profile (paper §6.4)
    xs, ys = [], []
    for n in (256, 1024, args.max_seq):
        toks = jnp.zeros((1, n), jnp.int32)
        executor.run_all(executor.start(toks))
        t0 = time.monotonic()
        executor.run_all(executor.start(toks))
        xs.append(n)
        ys.append(time.monotonic() - t0)
    pred = TTFTPredictor.fit(xs, ys)
    print("TTFT profile:", {n: f"{y*1e3:.0f}ms" for n, y in zip(xs, ys)})

    core = SchedulerCore(predictor=pred, policy=args.policy,
                         batch_budget=args.batch_budget)
    inst = PrefillInstance(params, cfg, core, max_seq=args.max_seq,
                           executor=executor,
                           prefix_share=args.prefix_share,
                           prefix_cache_blocks=args.prefix_cache_blocks)
    from repro.models.model import supports_ragged_decode
    dmb = args.decode_max_batch if supports_ragged_decode(cfg) else 1
    dec = DecodeInstance(params, cfg, decode_tokens=args.decode_tokens,
                         decode_max_batch=dmb)
    proxy = Proxy([inst], [dec])
    rng = np.random.default_rng(args.seed)
    try:
        mix = [(256, 1.5, "text", 0.7), (args.max_seq // 2, 15.0, "search", 0.2),
               (args.max_seq, 25.0, "file", 0.1)]
        # with --prefix-share: each task class gets a fixed system-prompt
        # template covering half its prompt — repeat submissions hit the
        # prefix cache and prefill only the random tail
        templates = {task: rng.integers(0, cfg.vocab_size, tokens // 2)
                     for tokens, _, task, _ in mix} if args.prefix_share \
            else {}
        for _ in range(args.requests):
            r = rng.random()
            acc = 0.0
            for tokens, slo, task, p in mix:
                acc += p
                if r <= acc:
                    break
            req = Request(num_tokens=tokens, slo=slo, task_type=task,
                          arrival=time.monotonic())
            tail = rng.integers(0, cfg.vocab_size,
                                tokens - len(templates.get(task, ())))
            toks = np.concatenate([templates[task], tail]) \
                if args.prefix_share else tail
            proxy.submit(req, toks)
            time.sleep(float(rng.exponential(0.5)))
        proxy.drain(600.0)
        time.sleep(0.5)
        rep = proxy.report()
        print(f"\nattainment={rep['slo_attainment']:.2f} "
              f"by_task={ {k: round(v,2) for k,v in attainment_by_task(proxy.requests).items()} }")
        print(f"ttft={ttft_stats(proxy.requests)}")
        print(f"rounds={rep['scheduling_rounds']} "
              f"blocking_mean={rep['blocking_mean']*1e3:.1f}ms "
              f"decoded={len(dec.finished)}")
        if args.prefix_share:
            print(f"prefix hits={rep['prefix_hits']} "
                  f"({rep['prefix_hit_tokens']} prompt tokens served "
                  f"from cache)")
    finally:
        proxy.shutdown()


if __name__ == "__main__":
    main()
