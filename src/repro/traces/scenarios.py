"""Production/stress scenario suite: fitted multi-turn traces and
adversarial workloads behind ``TraceConfig.scenario`` (docs/TRACES.md).

The base QwenTrace generator draws arrivals and lengths from hand-set
uniform knobs (``multi_turn_prob``, ``burstiness``). Production traffic is
not shaped like that: conversations arrive as *sessions* whose turn counts,
think times, and per-turn prompt growth follow heavy-tailed distributions,
and the tail — not the mean — is where scheduler differences live
("Taming Request Imbalance", PAPERS.md). This module provides:

  * moment-matching fits (`fit_lognormal`, `fit_gamma`) from summary
    statistics (mean/std or mean/CV) — the *fitted-distribution scenario
    format*: every scenario is fully specified by a handful of published
    moments, never by raw data;
  * a session-structured generator (`SessionFit` + the internal
    ``_session_trace``): sessions arrive Poisson (optionally modulated by a
    deterministic rate profile), each runs a lognormal number of turns with
    Gamma-distributed think times, and each follow-up turn resubmits the
    conversation's full prompt — its hash chain extends the parent's, so
    prefix caches see genuine multi-turn reuse, not a uniform coin flip;
  * the stress suite (`SCENARIOS`): each scenario names the policy or
    mechanism it is designed to punish, and `benchmarks/fig23_scenarios.py`
    gates a p99-goodput frontier per scenario.

Determinism contract (tested in tests/test_traces.py): a given
``TraceConfig`` (scenario, seed, rate, duration, model, ...) produces an
IDENTICAL request list — same arrivals, lengths, SLOs, and hash chains —
across processes and platforms. All randomness flows from
``np.random.default_rng(cfg.seed)``; rejected thinning candidates still
consume draws, so modulated and unmodulated paths stay independently
reproducible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.prefixcache import chain_extend
from repro.core.request import Request
from repro.traces.qwentrace import (TABLE1, TABLE2_SLO, TraceConfig,
                                    sample_length)


# ---------------------------------------------------------------- fitting
def fit_lognormal(mean: float, std: float):
    """(mu, sigma) of the lognormal with the given mean/std (moment
    matching). The fit is exact: lognormal(mu, sigma) has exactly these
    first two moments."""
    sigma2 = math.log(1.0 + (std / mean) ** 2)
    return math.log(mean) - sigma2 / 2.0, math.sqrt(sigma2)


def fit_gamma(mean: float, cv: float):
    """(shape, scale) of the Gamma with the given mean and coefficient of
    variation (std/mean). cv=1 degenerates to the exponential."""
    shape = 1.0 / (cv * cv)
    return shape, mean / shape


@dataclass(frozen=True)
class SessionFit:
    """Session-structured multi-turn shape, specified purely by summary
    statistics (the fitted-distribution scenario format): turn counts are
    lognormal (clipped to [1, max_turns]), think times Gamma, per-turn
    prompt growth lognormal. Defaults fit a chat-assistant profile: ~3-turn
    sessions with a heavy tail of long conversations, think times of a few
    seconds with occasional minute-long gaps, and each follow-up appending
    the user turn plus the assistant recap to the resubmitted prompt."""
    turns_mean: float = 3.2           # mean turns per session
    turns_std: float = 2.6
    max_turns: int = 12
    think_mean: float = 8.0           # seconds from one turn to the next
    think_cv: float = 1.4             # Gamma CV (>1: bursty re-engagement)
    growth_mean: float = 220.0        # tokens appended per follow-up turn
    growth_std: float = 260.0


CHAT_FIT = SessionFit()

# scenario-default workload knobs, applied only where the caller left the
# TraceConfig field at its zero default (the sweep knobs — rate, duration,
# seed, model, slo_scale, max_len, prefix_block — are always the caller's)
DEFAULT_SHARED_PREFIX_FRAC = 0.25
DEFAULT_OUTPUT_MEAN = 160.0
DEFAULT_TBT_BY_TASK = {"text": 0.03, "image": 0.05,
                       "search": 0.1, "file": 0.1}

# Length-aware TTFT SLO floor (seconds per prompt token). Fixed class SLOs
# are physically unreachable for the far length tail — a 2K-token "text"
# prompt needs ~0.36 s of bare prefill on A800 against a 0.25 s SLO — so a
# p99<=SLO tail gate would be degenerately empty at EVERY rate. Production
# SLOs scale with prompt length; the floor here is ~1.5x the worst-case
# per-token prefill slope on the reference accelerator (~0.23 ms/token for
# a 32K prompt), which makes every request feasible unloaded while leaving
# typical-length requests on their class SLO. Scenario traces only: the
# legacy uniform-knob path keeps fixed class SLOs (attainment-gated
# figures tolerate the infeasible tail; committed baselines byte-equal).
TTFT_SLO_PER_TOKEN = 3.5e-4


def _slo(task: str, n_tok: int, slos: Dict[str, float],
         cfg: TraceConfig) -> float:
    return max(slos[task], n_tok * TTFT_SLO_PER_TOKEN) * cfg.slo_scale


def _with_chat_defaults(cfg: TraceConfig) -> TraceConfig:
    return replace(
        cfg,
        shared_prefix_frac=cfg.shared_prefix_frac
        or DEFAULT_SHARED_PREFIX_FRAC,
        output_mean=cfg.output_mean or DEFAULT_OUTPUT_MEAN,
        tbt_slo_by_task=cfg.tbt_slo_by_task or dict(DEFAULT_TBT_BY_TASK))


def _sample_output(cfg: TraceConfig, rng: np.random.Generator) -> int:
    if cfg.output_mean <= 0:
        return 0
    mu, sigma = fit_lognormal(cfg.output_mean,
                              cfg.output_std or cfg.output_mean)
    return int(np.clip(int(rng.lognormal(mu, sigma)), 1, 8192))


def _sample_turns(rng: np.random.Generator, fit: SessionFit) -> int:
    mu, sigma = fit_lognormal(fit.turns_mean, fit.turns_std)
    return int(np.clip(int(rng.lognormal(mu, sigma)), 1, fit.max_turns))


# ------------------------------------------- session-structured generation
def _session_trace(cfg: TraceConfig, fit: SessionFit, *,
                   rate_fn: Optional[Callable[[float], float]] = None,
                   rate_peak: float = 1.0,
                   output_sampler: Optional[Callable] = None
                   ) -> List[Request]:
    """Fitted multi-turn trace: sessions arrive Poisson at ``cfg.rate /
    fit.turns_mean`` (so the REQUEST rate is ~cfg.rate), optionally thinned
    against ``rate_fn(t)/rate_peak`` for time-varying load. Every follow-up
    turn resubmits the conversation's full prompt — the child's hash chain
    extends the parent's at full-block granularity — and the per-class
    system-prompt template (``shared_prefix_frac``) is shared across all
    sessions of a class, exactly as the legacy generator does."""
    rng = np.random.default_rng(cfg.seed)
    ratios = cfg.task_ratios or {k: v["ratio"] for k, v in TABLE1.items()}
    tasks = list(ratios)
    probs = np.asarray([ratios[t] for t in tasks], dtype=np.float64)
    probs = probs / probs.sum()
    slos = TABLE2_SLO[cfg.model]
    tbt_by = cfg.tbt_slo_by_task or {}
    bs = cfg.prefix_block

    tpl_keys: Dict[str, tuple] = {}
    tpl_len: Dict[str, int] = {}
    for ti, task in enumerate(tasks):
        n = int(cfg.shared_prefix_frac * TABLE1[task]["mean"])
        tpl_len[task] = n
        tpl_keys[task] = chain_extend((), range(n // bs), salt=1000 + ti)

    # session arrivals (thinning keeps the draw sequence deterministic)
    session_rate = cfg.rate / max(fit.turns_mean, 1.0)
    starts: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / (session_rate * rate_peak))
        if t >= cfg.duration:
            break
        if rate_fn is None or rng.random() < rate_fn(t) / rate_peak:
            starts.append(t)

    mu_g, sg_g = fit_lognormal(fit.growth_mean, fit.growth_std)
    shape_th, scale_th = fit_gamma(fit.think_mean, fit.think_cv)
    out: List[Request] = []
    uid = 0
    for t0 in starts:
        task = tasks[int(rng.choice(len(tasks), p=probs))]
        n_turns = _sample_turns(rng, fit)
        base_keys, base_len = tpl_keys[task], tpl_len[task]
        n_tok = min(max(sample_length(task, rng, max_len=cfg.max_len),
                        base_len + 16), cfg.max_len)
        t_turn = t0
        for turn in range(n_turns):
            if turn > 0:
                t_turn += rng.gamma(shape_th, scale_th)
                if t_turn >= cfg.duration:
                    break
                grow = max(int(rng.lognormal(mu_g, sg_g)), 16)
                n_tok = min(n_tok + grow, cfg.max_len)
            uid += 1
            n_full = n_tok // bs
            shared = min(base_len // bs, len(base_keys), n_full)
            keys = chain_extend(base_keys[:shared],
                                range(n_full - shared), salt=uid)
            out_tokens = output_sampler(rng) if output_sampler \
                else _sample_output(cfg, rng)
            tbt = tbt_by.get(task, cfg.tbt_slo)
            out.append(Request(
                num_tokens=n_tok,
                slo=_slo(task, n_tok, slos, cfg),
                arrival=t_turn,
                task_type=task,
                output_tokens=out_tokens,
                tbt_slo=tbt if out_tokens else float("inf"),
                prefix_hash=keys,
            ))
            base_keys, base_len = keys, n_tok  # next turn extends this turn
    out.sort(key=lambda r: r.arrival)
    return out


# ------------------------------------------------------------- scenarios
def _fitted_chat(cfg: TraceConfig) -> List[Request]:
    return _session_trace(_with_chat_defaults(cfg), CHAT_FIT)


DIURNAL_AMPLITUDE = 0.85              # rate swings rate*(1±0.85)
DIURNAL_CYCLES = 2.0                  # bursts per trace


def _diurnal(cfg: TraceConfig) -> List[Request]:
    period = cfg.duration / DIURNAL_CYCLES
    amp = DIURNAL_AMPLITUDE

    def rate_fn(t: float) -> float:
        # trough at t=0 so the trace warms up before the burst hits
        return 1.0 + amp * math.sin(2.0 * math.pi * t / period
                                    - math.pi / 2.0)

    return _session_trace(_with_chat_defaults(cfg), CHAT_FIT,
                          rate_fn=rate_fn, rate_peak=1.0 + amp)


HEAVY_TAIL_FRAC = 0.08                # fraction of requests in the tail
HEAVY_TAIL_ALPHA = 1.15               # Pareto index (alpha<2: infinite var)
HEAVY_TAIL_SCALE = 600.0              # tail minimum output tokens


def _heavy_tail(cfg: TraceConfig) -> List[Request]:
    cfg = _with_chat_defaults(cfg)

    def sample(rng: np.random.Generator) -> int:
        if rng.random() < HEAVY_TAIL_FRAC:
            return int(np.clip(
                HEAVY_TAIL_SCALE * (1.0 + rng.pareto(HEAVY_TAIL_ALPHA)),
                1, 8192))
        return _sample_output(cfg, rng)

    return _session_trace(cfg, CHAT_FIT, output_sampler=sample)


# prefix-adversary geometry (tests/test_traces.py pins the collide/diverge
# property at these constants; docs/TRACES.md documents them)
ADVERSARY_FAMILIES = 24               # distinct hot trunks
ADVERSARY_TRUNK_BLOCKS = 16           # shared chain prefix per family
ADVERSARY_TAIL_BLOCKS = (16, 48)      # unique blocks per request [lo, hi)


def _prefix_adversary(cfg: TraceConfig) -> List[Request]:
    """Prefix-hash adversary: every request probes one of a small set of
    hot trunks (so `prefix-affinity` concentrates whole families onto the
    trunk holder — manufactured hotspots), then appends a LONG unique tail
    (so every request inserts 16-48 never-reused blocks, and the LRU churn
    evicts other families' trunks — the trie thrashes instead of serving).
    Family popularity is Zipf-ish: the hottest trunks stay resident just
    long enough to keep attracting traffic."""
    rng = np.random.default_rng(cfg.seed)
    slos = TABLE2_SLO[cfg.model]
    bs = cfg.prefix_block
    trunks = [chain_extend((), range(ADVERSARY_TRUNK_BLOCKS), salt=7000 + f)
              for f in range(ADVERSARY_FAMILIES)]
    fam_probs = 1.0 / (1.0 + np.arange(ADVERSARY_FAMILIES, dtype=np.float64))
    fam_probs = fam_probs / fam_probs.sum()
    tbt_by = cfg.tbt_slo_by_task or {}
    out: List[Request] = []
    t = 0.0
    uid = 0
    while True:
        t += rng.exponential(1.0 / cfg.rate)
        if t >= cfg.duration:
            break
        uid += 1
        fam = int(rng.choice(ADVERSARY_FAMILIES, p=fam_probs))
        tail = int(rng.integers(*ADVERSARY_TAIL_BLOCKS))
        n_tok = min((ADVERSARY_TRUNK_BLOCKS + tail) * bs
                    + int(rng.integers(bs)), cfg.max_len)
        n_full = n_tok // bs
        shared = min(ADVERSARY_TRUNK_BLOCKS, n_full)
        keys = chain_extend(trunks[fam][:shared], range(n_full - shared),
                            salt=uid)
        out_tokens = _sample_output(cfg, rng)
        out.append(Request(
            num_tokens=n_tok,
            slo=_slo("search", n_tok, slos, cfg),  # long-prompt agentic class
            arrival=t,
            task_type="search",
            output_tokens=out_tokens,
            tbt_slo=tbt_by.get("search", cfg.tbt_slo)
            if out_tokens else float("inf"),
            prefix_hash=keys,
        ))
    return out


FLOOD_MULT = 6.0                      # flood tenant rate vs base rate
FLOOD_WINDOW = (0.35, 0.6)            # active window, fraction of duration
FLOOD_PREFIX_TOKENS = 512             # the tenant's one shared template


def _flood(cfg: TraceConfig) -> List[Request]:
    """Single-tenant flood: the fitted chat mixture at cfg.rate, plus one
    aggressive tenant firing near-identical tight-SLO text requests at
    ``FLOOD_MULT x cfg.rate`` for a window mid-trace. Deadline-blind FCFS
    admission collapses outright under the burst (fig23's flood matchup),
    and even under S-EDF the burst produces the divergence tail gating
    exists to catch: aggregate attainment barely moves while the p99 tail
    runs several SLOs out. S-EDF also has no fairness term — the flood's
    tight deadlines legally preempt the base tenants' turns during the
    window (the motivating case for the ROADMAP multi-tenant-fairness
    item)."""
    cfg = _with_chat_defaults(cfg)
    base = _session_trace(cfg, CHAT_FIT)
    rng = np.random.default_rng(cfg.seed + 0x5EED)
    slos = TABLE2_SLO[cfg.model]
    bs = cfg.prefix_block
    tpl = chain_extend((), range(FLOOD_PREFIX_TOKENS // bs), salt=9999)
    tbt_by = cfg.tbt_slo_by_task or {}
    t = FLOOD_WINDOW[0] * cfg.duration
    end = FLOOD_WINDOW[1] * cfg.duration
    flood: List[Request] = []
    uid = 0
    while True:
        t += rng.exponential(1.0 / (FLOOD_MULT * cfg.rate))
        if t >= end:
            break
        uid += 1
        n_tok = min(FLOOD_PREFIX_TOKENS + 16 + int(rng.integers(256)),
                    cfg.max_len)
        n_full = n_tok // bs
        shared = min(len(tpl), n_full)
        keys = chain_extend(tpl[:shared], range(n_full - shared),
                            salt=0x0F100D + uid)
        out_tokens = _sample_output(cfg, rng)
        flood.append(Request(
            num_tokens=n_tok,
            slo=_slo("text", n_tok, slos, cfg),
            arrival=t,
            task_type="text",
            output_tokens=out_tokens,
            tbt_slo=tbt_by.get("text", cfg.tbt_slo)
            if out_tokens else float("inf"),
            prefix_hash=keys,
        ))
    out = base + flood
    out.sort(key=lambda r: r.arrival)
    return out


@dataclass(frozen=True)
class Scenario:
    name: str
    summary: str                      # one line: what the workload looks like
    punishes: str                     # the policy/mechanism it stresses
    build: Callable[[TraceConfig], List[Request]]


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    Scenario(
        name="fitted-chat",
        summary="fitted session-structured multi-turn chat mixture "
                "(lognormal turns/growth, Gamma think times)",
        punishes="nothing by design — the production-shaped baseline the "
                 "stress scenarios perturb",
        build=_fitted_chat),
    Scenario(
        name="diurnal",
        summary="the fitted chat mixture under a sinusoidal rate profile "
                "(troughs to 0.15x, peaks to 1.85x the nominal rate)",
        punishes="headroom-blind dispatch (round-robin): bursts pile onto "
                 "already-loaded instances while troughs idle them",
        build=_diurnal),
    Scenario(
        name="heavy-tail",
        summary="fitted chat with a Pareto(alpha=1.15) splice on output "
                "lengths: ~8% of decodes run 600 to 8192 tokens",
        punishes="slack-blind FCFS decode admission: marathon decodes "
                 "squat KV slots while tight-TBT streams queue",
        build=_heavy_tail),
    Scenario(
        name="prefix-adversary",
        summary="Zipf traffic over 24 hot trunk chains, each request "
                "appending 16-48 unique blocks",
        punishes="prefix-affinity dispatch (manufactured hotspots) and the "
                 "PrefixBlockManager LRU (unique tails evict hot trunks)",
        build=_prefix_adversary),
    Scenario(
        name="flood",
        summary="fitted chat plus one tenant firing near-identical "
                "tight-SLO text requests at 6x the base rate mid-trace",
        punishes="deadline-blind FCFS admission (collapses under the "
                 "burst) and attainment-gated capacity claims: aggregate "
                 "attainment holds while the p99 tail runs SLOs out",
        build=_flood),
)}


def scenario_names() -> List[str]:
    return sorted(SCENARIOS)


def generate_scenario(cfg: TraceConfig) -> List[Request]:
    """Entry point `repro.traces.qwentrace.generate` delegates to when
    ``cfg.scenario`` is set."""
    sc = SCENARIOS.get(cfg.scenario or "")
    if sc is None:
        raise ValueError(f"unknown scenario {cfg.scenario!r}; known: "
                         f"{scenario_names()}")
    return sc.build(cfg)
