"""Synthetic QwenTrace (paper §6.1, Table 1 / Fig. 1).

The real trace [53] is not shipped with the paper; we generate a synthetic
trace matching its published per-task statistics exactly: four task types with
the Table 1 prompt-length distributions (lognormal fits to mean/std — the fit
reproduces the published P99s within ~5%), mixture ratios, Poisson (or bursty
Gamma) arrivals, and the Table 2 TTFT SLOs. The paper itself uses randomly
generated token IDs of the specified lengths, so content is immaterial.

Shared-prefix structure (prefix-cache workloads, benchmarks/fig22): real
production prompts share massive prefixes — per-task system prompts /
few-shot templates, and multi-turn conversations that resubmit the whole
history. ``shared_prefix_frac`` gives every request of a task class a common
leading template (sized as that fraction of the class's mean length);
``multi_turn_prob`` makes a request a follow-up that extends an earlier
conversation's full prompt. Both populate `Request.prefix_hash` — the block
hash chain (`repro.core.prefixcache.chain_extend` semantics) that the
cache-residency model and prefix-affinity dispatch key on: equal leading
keys == equal leading tokens.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.prefixcache import chain_extend
from repro.core.request import Request

# Table 1: prompt length stats per task type
TABLE1 = {
    #                 mean   p99    std   ratio
    "text":   dict(mean=590,  p99=3040,  std=652,  ratio=0.68),
    "image":  dict(mean=532,  p99=2764,  std=510,  ratio=0.08),
    "search": dict(mean=5976, p99=16635, std=3456, ratio=0.20),
    "file":   dict(mean=6833, p99=22390, std=5186, ratio=0.04),
}

# Table 2: TTFT SLOs (seconds) per model
TABLE2_SLO = {
    "llama3-8b":   {"text": 0.25, "image": 0.5, "search": 4.0, "file": 6.0},
    "qwen2.5-14b": {"text": 0.4,  "image": 0.8, "search": 6.5, "file": 9.0},
    "llama3-70b":  {"text": 1.0,  "image": 2.0, "search": 15.0, "file": 18.0},
    # MoE generality model (§6.5) — between 8B and 14B dense cost
    "qwen3-30b-a3b": {"text": 0.4, "image": 0.8, "search": 6.5, "file": 9.0},
}


def _lognormal_params(mean: float, std: float):
    sigma2 = math.log(1.0 + (std / mean) ** 2)
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2)


def sample_length(task: str, rng: np.random.Generator,
                  min_len: int = 16, max_len: int = 32768) -> int:
    t = TABLE1[task]
    mu, sigma = _lognormal_params(t["mean"], t["std"])
    n = int(rng.lognormal(mu, sigma))
    return int(np.clip(n, min_len, max_len))


@dataclass
class TraceConfig:
    model: str = "llama3-8b"
    rate: float = 2.0                 # requests / second
    duration: float = 60.0            # seconds
    slo_scale: float = 1.0            # Fig. 9 row 2 sweeps this
    burstiness: float = 1.0           # 1 = Poisson; >1 = bursty (Gamma CV)
    seed: int = 0
    task_ratios: Optional[Dict[str, float]] = None
    max_len: int = 32768
    # decode phase (cluster end-to-end accounting); 0 = prefill-only trace
    output_mean: float = 0.0          # mean output length (lognormal)
    output_std: float = 0.0           # 0 -> defaults to output_mean
    tbt_slo: float = 0.1              # per-token TBT SLO when decoding
    # heterogeneous TBT SLOs per task type (e.g. tight for interactive text,
    # loose for search/file agents) — the workload where slack-aware decode
    # admission wins; unlisted tasks fall back to `tbt_slo`
    tbt_slo_by_task: Optional[Dict[str, float]] = None
    # speculative decoding: per-task draft accept probability stamped onto
    # Request.spec_accept (drafts hit well on templated/file tasks, poorly
    # on freeform text). None = legacy trace, spec_accept stays 0.0 —
    # bit-identical requests; unlisted tasks also get 0.0.
    spec_accept_by_task: Optional[Dict[str, float]] = None
    # shared-prefix structure (0.0/0.0 = the original trace, prefix_hash
    # left None — bit-identical requests)
    shared_prefix_frac: float = 0.0   # of each class's MEAN length: the
                                      # class-wide system-prompt template
    multi_turn_prob: float = 0.0      # P(request extends a prior same-class
                                      # conversation's full prompt)
    prefix_block: int = 128           # hash-chain block granularity (tokens)
    multi_turn_window: int = 32       # recent conversations eligible as
                                      # parents (live sessions, not all time)
    # named production/stress scenario (repro.traces.scenarios): when set,
    # generate() delegates to the scenario's fitted generator — lognormal/
    # Gamma distributions fitted from summary statistics and session-
    # structured multi-turn chains replace the uniform knobs above. The
    # sweep knobs (rate/duration/seed/model/slo_scale/max_len/prefix_block)
    # keep their meaning; docs/TRACES.md specifies each scenario.
    scenario: Optional[str] = None


def generate(cfg: TraceConfig) -> List[Request]:
    if cfg.scenario is not None:
        # fitted/stress scenarios own their whole generation path; the
        # legacy uniform-knob path below stays byte-identical for every
        # existing trace (committed fig9/18/19/20/22 baselines depend on it)
        from repro.traces.scenarios import generate_scenario
        return generate_scenario(cfg)
    rng = np.random.default_rng(cfg.seed)
    ratios = cfg.task_ratios or {k: v["ratio"] for k, v in TABLE1.items()}
    tasks = list(ratios)
    probs = np.asarray([ratios[t] for t in tasks], dtype=np.float64)
    probs = probs / probs.sum()
    slos = TABLE2_SLO[cfg.model]

    sharing = cfg.shared_prefix_frac > 0 or cfg.multi_turn_prob > 0
    bs = cfg.prefix_block
    # per-class system-prompt template: a fixed-content (fixed hash chain)
    # leading segment every request of the class shares
    tpl_keys: Dict[str, tuple] = {}
    tpl_len: Dict[str, int] = {}
    if sharing:
        for ti, task in enumerate(tasks):
            n = int(cfg.shared_prefix_frac * TABLE1[task]["mean"])
            tpl_len[task] = n
            tpl_keys[task] = chain_extend((), range(n // bs), salt=1000 + ti)
    # recent conversations per class: (prompt_len, full-block hash chain)
    history: Dict[str, List] = {task: [] for task in tasks}
    uid = 0

    out: List[Request] = []
    t = 0.0
    mean_gap = 1.0 / cfg.rate
    while t < cfg.duration:
        if cfg.burstiness == 1.0:
            gap = rng.exponential(mean_gap)
        else:
            # Gamma interarrival with CV = burstiness (shape k = 1/CV^2)
            k = 1.0 / (cfg.burstiness ** 2)
            gap = rng.gamma(k, mean_gap / k)
        t += gap
        if t >= cfg.duration:
            break
        task = tasks[int(rng.choice(len(tasks), p=probs))]
        out_tokens = 0
        if cfg.output_mean > 0:
            mu, sigma = _lognormal_params(cfg.output_mean,
                                          cfg.output_std or cfg.output_mean)
            out_tokens = int(np.clip(int(rng.lognormal(mu, sigma)), 1, 8192))
        tbt = (cfg.tbt_slo_by_task or {}).get(task, cfg.tbt_slo)
        n_tok = sample_length(task, rng, max_len=cfg.max_len)
        keys = None
        if sharing:
            uid += 1
            hist = history[task]
            if hist and rng.random() < cfg.multi_turn_prob:
                # follow-up turn: the parent's whole prompt is the prefix,
                # the new sample is the appended user turn + response recap
                parent_len, parent_keys = hist[
                    int(rng.integers(len(hist)))]
                n_tok = parent_len + max(n_tok // 2, 16)
                base_keys, base_len = parent_keys, parent_len
            else:
                # fresh conversation: class template + unique remainder
                base_keys, base_len = tpl_keys[task], tpl_len[task]
                n_tok = max(n_tok, base_len + 16)
            # max_len binds the TOTAL prompt, template included — a tight
            # max_len truncates the shared base rather than exceeding the
            # length contract callers size max_seq from
            n_tok = min(n_tok, cfg.max_len)
            n_full = n_tok // bs
            # blocks fully inside the shared base keep its chain; the
            # boundary block (base tail + unique start) and everything
            # after hash uniquely for this request
            shared_full = min(base_len // bs, len(base_keys), n_full)
            keys = chain_extend(base_keys[:shared_full],
                                range(n_full - shared_full), salt=uid)
            hist.append((n_tok, keys))
            del hist[:-cfg.multi_turn_window]
        out.append(Request(
            num_tokens=n_tok,
            slo=slos[task] * cfg.slo_scale,
            arrival=t,
            task_type=task,
            output_tokens=out_tokens,
            tbt_slo=tbt if out_tokens else float("inf"),
            prefix_hash=keys,
            spec_accept=(cfg.spec_accept_by_task or {}).get(task, 0.0),
        ))
    return out


def oracle_hit_rate(requests: Sequence[Request],
                    prefix_block: int = 128) -> float:
    """Trace-intrinsic prefix-cache hit rate: the fraction of prompt tokens
    an UNBOUNDED single cache would serve from blocks already produced by
    earlier requests (arrival order). The upper bound any finite,
    partitioned (per-instance) cache can approach — fig22 sweeps traces by
    this number."""
    seen: set = set()
    hit_tokens = 0
    total = 0
    for r in sorted(requests, key=lambda r: r.arrival):
        total += r.num_tokens
        if not r.prefix_hash:
            continue
        run = 0
        for k in r.prefix_hash:
            if k not in seen:
                break
            run += 1
        hit_tokens += min(run * prefix_block, r.num_tokens)
        seen.update(r.prefix_hash)
    return hit_tokens / max(total, 1)


def sharegpt_like(n: int = 500, rate: float = 2.0, slo: float = 0.25,
                  seed: int = 0, max_len: int = 2048) -> List[Request]:
    """Single-SLO workload (paper §6.5): ShareGPT-like short prompts with the
    chatbot SLO and Poisson arrivals. Lengths follow the published ShareGPT
    prompt distribution shape (lognormal, mean~330, heavy tail, <2K)."""
    rng = np.random.default_rng(seed)
    mu, sigma = _lognormal_params(330.0, 380.0)
    out: List[Request] = []
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        n_tok = int(np.clip(int(rng.lognormal(mu, sigma)), 16, max_len))
        out.append(Request(num_tokens=n_tok, slo=slo, arrival=t,
                           task_type="text"))
    return out
