"""Synthetic QwenTrace (paper §6.1, Table 1 / Fig. 1).

The real trace [53] is not shipped with the paper; we generate a synthetic
trace matching its published per-task statistics exactly: four task types with
the Table 1 prompt-length distributions (lognormal fits to mean/std — the fit
reproduces the published P99s within ~5%), mixture ratios, Poisson (or bursty
Gamma) arrivals, and the Table 2 TTFT SLOs. The paper itself uses randomly
generated token IDs of the specified lengths, so content is immaterial.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.request import Request

# Table 1: prompt length stats per task type
TABLE1 = {
    #                 mean   p99    std   ratio
    "text":   dict(mean=590,  p99=3040,  std=652,  ratio=0.68),
    "image":  dict(mean=532,  p99=2764,  std=510,  ratio=0.08),
    "search": dict(mean=5976, p99=16635, std=3456, ratio=0.20),
    "file":   dict(mean=6833, p99=22390, std=5186, ratio=0.04),
}

# Table 2: TTFT SLOs (seconds) per model
TABLE2_SLO = {
    "llama3-8b":   {"text": 0.25, "image": 0.5, "search": 4.0, "file": 6.0},
    "qwen2.5-14b": {"text": 0.4,  "image": 0.8, "search": 6.5, "file": 9.0},
    "llama3-70b":  {"text": 1.0,  "image": 2.0, "search": 15.0, "file": 18.0},
    # MoE generality model (§6.5) — between 8B and 14B dense cost
    "qwen3-30b-a3b": {"text": 0.4, "image": 0.8, "search": 6.5, "file": 9.0},
}


def _lognormal_params(mean: float, std: float):
    sigma2 = math.log(1.0 + (std / mean) ** 2)
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2)


def sample_length(task: str, rng: np.random.Generator,
                  min_len: int = 16, max_len: int = 32768) -> int:
    t = TABLE1[task]
    mu, sigma = _lognormal_params(t["mean"], t["std"])
    n = int(rng.lognormal(mu, sigma))
    return int(np.clip(n, min_len, max_len))


@dataclass
class TraceConfig:
    model: str = "llama3-8b"
    rate: float = 2.0                 # requests / second
    duration: float = 60.0            # seconds
    slo_scale: float = 1.0            # Fig. 9 row 2 sweeps this
    burstiness: float = 1.0           # 1 = Poisson; >1 = bursty (Gamma CV)
    seed: int = 0
    task_ratios: Optional[Dict[str, float]] = None
    max_len: int = 32768
    # decode phase (cluster end-to-end accounting); 0 = prefill-only trace
    output_mean: float = 0.0          # mean output length (lognormal)
    output_std: float = 0.0           # 0 -> defaults to output_mean
    tbt_slo: float = 0.1              # per-token TBT SLO when decoding
    # heterogeneous TBT SLOs per task type (e.g. tight for interactive text,
    # loose for search/file agents) — the workload where slack-aware decode
    # admission wins; unlisted tasks fall back to `tbt_slo`
    tbt_slo_by_task: Optional[Dict[str, float]] = None


def generate(cfg: TraceConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    ratios = cfg.task_ratios or {k: v["ratio"] for k, v in TABLE1.items()}
    tasks = list(ratios)
    probs = np.asarray([ratios[t] for t in tasks], dtype=np.float64)
    probs = probs / probs.sum()
    slos = TABLE2_SLO[cfg.model]

    out: List[Request] = []
    t = 0.0
    mean_gap = 1.0 / cfg.rate
    while t < cfg.duration:
        if cfg.burstiness == 1.0:
            gap = rng.exponential(mean_gap)
        else:
            # Gamma interarrival with CV = burstiness (shape k = 1/CV^2)
            k = 1.0 / (cfg.burstiness ** 2)
            gap = rng.gamma(k, mean_gap / k)
        t += gap
        if t >= cfg.duration:
            break
        task = tasks[int(rng.choice(len(tasks), p=probs))]
        out_tokens = 0
        if cfg.output_mean > 0:
            mu, sigma = _lognormal_params(cfg.output_mean,
                                          cfg.output_std or cfg.output_mean)
            out_tokens = int(np.clip(int(rng.lognormal(mu, sigma)), 1, 8192))
        tbt = (cfg.tbt_slo_by_task or {}).get(task, cfg.tbt_slo)
        out.append(Request(
            num_tokens=sample_length(task, rng, max_len=cfg.max_len),
            slo=slos[task] * cfg.slo_scale,
            arrival=t,
            task_type=task,
            output_tokens=out_tokens,
            tbt_slo=tbt if out_tokens else float("inf"),
        ))
    return out


def sharegpt_like(n: int = 500, rate: float = 2.0, slo: float = 0.25,
                  seed: int = 0, max_len: int = 2048) -> List[Request]:
    """Single-SLO workload (paper §6.5): ShareGPT-like short prompts with the
    chatbot SLO and Poisson arrivals. Lengths follow the published ShareGPT
    prompt distribution shape (lognormal, mean~330, heavy tail, <2K)."""
    rng = np.random.default_rng(seed)
    mu, sigma = _lognormal_params(330.0, 380.0)
    out: List[Request] = []
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        n_tok = int(np.clip(int(rng.lognormal(mu, sigma)), 16, max_len))
        out.append(Request(num_tokens=n_tok, slo=slo, arrival=t,
                           task_type="text"))
    return out
