"""InternVL2-76B — InternViT + InternLM2 backbone. [arXiv:2404.16821; unverified]

VLM: the vision frontend is a STUB; ``input_specs()`` provides precomputed patch
embeddings (num_patches x d_model) that replace the leading token positions.
Backbone below is the 76B-class LM: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    num_patches=256,
    source="[arXiv:2404.16821; unverified]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="internvl2-tiny",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        num_patches=8,
    )
