"""RecurrentGemma-9B — RG-LRU + local attention, 1:2 pattern. [arXiv:2402.19427; unverified]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Layer pattern is
(rglru, rglru, attn) repeating, truncated to 38 layers; attention layers use a
2048-token sliding window, so the arch is sub-quadratic (runs long_500k).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    local_window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    tie_embeddings=True,
    source="[arXiv:2402.19427; unverified]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-tiny",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        local_window=32,
        layer_pattern=("rglru", "rglru", "attn"),
        lru_width=64,
        tie_embeddings=True,
    )
