"""Llama4-Maverick-400B-A17B — MoE, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1,
dense/MoE layers interleaved 1:1 (dense d_ff=16384) as in the published Maverick
config — that interleave is what makes 400B total / 17B active work out.
Per the paper's MoE extension (FlowPrefill §6.5), the FFN introduces two extra
fused operator boundaries: ``gate`` (router) and ``experts``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500000.0,
    num_experts=128,
    experts_per_token=1,
    moe_layer_freq=2,
    d_ff_dense=16384,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama4-tiny",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=8,
        experts_per_token=1,
        moe_layer_freq=2,
        d_ff_dense=128,
        moe_capacity_factor=8.0,   # = E/k -> provably drop-free (exactness tests)
    )
