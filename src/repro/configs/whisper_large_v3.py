"""Whisper-large-v3 — encoder-decoder, conv audio frontend (STUB). [arXiv:2212.04356; unverified]

32 encoder + 32 decoder layers, d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
The audio frontend (mel + conv downsampling) is a stub: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model). Decoder cross-attends to the
encoder output; decode_32k is lowered structurally (config-driven positions) even
though the real model caps target length at 448 — noted in EXPERIMENTS.md.
long_500k is skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    num_encoder_layers=32,
    encoder_seq=1500,
    source="[arXiv:2212.04356; unverified]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        is_encoder_decoder=True,
        num_encoder_layers=2,
        encoder_seq=32,
    )
