"""Granite-MoE-3B-A800M — 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-tiny",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        num_experts=8,
        experts_per_token=4,
        moe_capacity_factor=2.0,   # = E/k -> provably drop-free (exactness tests)
        tie_embeddings=True,
    )
