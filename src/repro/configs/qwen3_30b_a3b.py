"""Qwen3-30B-A3B — the paper's MoE generality model (FlowPrefill §6.5).

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1000000.0,
    source="[arXiv:2505.09388; hf]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-tiny",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        num_experts=8,
        experts_per_token=2,
        moe_capacity_factor=4.0,   # = E/k -> provably drop-free (exactness tests)
    )
