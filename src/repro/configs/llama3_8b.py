"""Llama3-8B — the paper's primary evaluation model (FlowPrefill §6).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    source="[arXiv:2407.21783; hf]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-tiny",
        family="dense",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
    )
