"""Model / serving / shape configuration dataclasses and the arch registry.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published configuration) and ``tiny()`` (a reduced
same-family configuration for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- attention variants -------------------------------------------------
    local_window: int = 0            # >0 -> sliding-window (local) attention
    # hybrid layer pattern, e.g. ("rglru", "rglru", "attn") repeating
    layer_pattern: Tuple[str, ...] = ()

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_freq: int = 1          # 2 -> alternate (dense, moe) layers (llama4)
    d_ff_dense: int = 0              # d_ff of interleaved dense layers (freq=2)
    moe_capacity_factor: float = 1.25
    moe_min_capacity: int = 4

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0               # N (state size per head)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256             # SSD chunk length

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # whisper: 1500 frames after conv stub

    # --- multimodal stub ------------------------------------------------------
    num_patches: int = 0             # vlm: vision patch embeddings per request

    # --- rg-lru (recurrentgemma) ----------------------------------------------
    lru_width: int = 0               # 0 -> d_model

    source: str = ""                 # provenance note, e.g. "[arXiv:...; tier]"

    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:          # attention-free (ssm)
            return self.head_dim
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token context without O(S^2) attention
        or an unbounded KV cache (SSM state / bounded local window)."""
        if self.family == "ssm":
            return True
        if self.layer_pattern and all(
            op == "rglru" or (op == "attn" and self.local_window > 0)
            for op in self.layer_pattern
        ):
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        qkv = d * (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.qkv_bias:
            qkv += (self.num_heads + 2 * self.num_kv_heads) * hd
        o = self.num_heads * hd * d
        attn = qkv + o
        if self.num_experts:
            n_moe = self.num_layers // self.moe_layer_freq
            n_dense = self.num_layers - n_moe
            per_moe = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            per_dense = 3 * d * (self.d_ff_dense or self.d_ff)
            # amortized per-layer ffn
            ffn = (n_moe * per_moe + n_dense * per_dense) // self.num_layers
        else:
            ffn = 3 * d * self.d_ff
        norms = 2 * d

        if self.family == "ssm":
            din = self.d_inner
            nh = self.ssm_heads
            in_proj = d * (2 * din + 2 * self.ssm_state + nh)
            conv = (din + 2 * self.ssm_state) * self.ssm_conv_width
            out_proj = din * d
            per_layer = in_proj + conv + out_proj + nh + nh + d  # A, D, norm
            layers = self.num_layers * per_layer
        elif self.layer_pattern:
            pat = _expanded_pattern(self)
            lw = self.lru_width or d
            rglru_layer = (
                d * 2 * lw + lw * d      # in (x,gate) + out proj
                + 4 * lw                 # conv1d width-4 depthwise (approx)
                + 2 * lw                 # recurrent gates a_param, input gate
                + ffn + 2 * d + d
            )
            attn_layer = attn + ffn + norms + d
            layers = sum(
                rglru_layer if op == "rglru" else attn_layer for op in pat
            )
        else:
            layers = self.num_layers * (attn + ffn + norms)
            if self.is_encoder_decoder:
                # encoder layers + decoder cross-attention
                enc = self.num_encoder_layers * (attn + ffn + norms)
                cross = self.num_layers * (attn + d)
                layers += enc + cross

        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d
        return layers + embed + unembed + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe = self.num_layers // self.moe_layer_freq
        ffn_all = n_moe * self.num_experts * 3 * d * self.d_ff
        ffn_active = n_moe * self.experts_per_token * 3 * d * self.d_ff
        return total - ffn_all + ffn_active


def _expanded_pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    """Expand layer_pattern repeating + truncated to num_layers."""
    if not cfg.layer_pattern:
        return tuple(["attn"] * cfg.num_layers)
    reps = (cfg.num_layers + len(cfg.layer_pattern) - 1) // len(cfg.layer_pattern)
    return tuple((cfg.layer_pattern * reps)[: cfg.num_layers])


# ---------------------------------------------------------------------------
# Input shapes (the four assigned shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "internvl2_76b",
    "recurrentgemma_9b",
    "llama4_maverick_400b",
    "granite_moe_3b",
    "llama3_2_1b",
    "qwen2_5_3b",
    "qwen2_1_5b",
    "minitron_4b",
    "mamba2_370m",
    "whisper_large_v3",
    # the paper's own evaluation models
    "llama3_8b",
    "qwen3_30b_a3b",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def get_tiny_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.tiny()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and if not, why (DESIGN.md §skips)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; full-attention arch"
    return True, ""
