"""Mamba2-370M — SSD (state-space duality), attention-free. [arXiv:2405.21060; unverified]

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128. Decode carries O(1) recurrent
state (conv window + SSM state), so the arch runs long_500k.

FlowPrefill arch-applicability note (DESIGN.md §4): the paper's operator list is
attention-specific; for SSDs the operator boundaries become
in_proj / conv / ssd / out_proj — the mechanism transfers unchanged.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="mamba2-tiny",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_conv_width=4,
        ssm_chunk=16,
        tie_embeddings=True,
    )
