"""HLO collective analysis: parse compiled/lowered HLO text and sum operand
bytes per collective kind (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute). cost_analysis() does not expose collective
traffic, so the roofline's collective term comes from here.

Shapes in post-SPMD HLO are per-device shard shapes; we report per-device
operand bytes (multiply by chip count for global traffic).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# e.g.  %all-reduce.7 = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %x), ...
_LINE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind (per device, one execution)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue                      # started op already counted
        # operand shapes are the shape tokens after the op-name paren
        paren = line.index(m.group(0)) + len(m.group(0))
        operands = line[paren - 1:]
        shapes = _SHAPE_RE.findall(operands)
        if not shapes:                    # fall back to the result shape
            shapes = _SHAPE_RE.findall(line[:paren])[:1]
        for dtype, dims in shapes:
            out[kind] += _shape_bytes(dtype, dims)
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())
