"""Logical-axis sharding (MaxText-style rules).

Model code tags tensors with *logical* dimension names via ``logical(x, *dims)``.
A rule set maps logical dims -> mesh axes. Outside a mesh context the tag is a
no-op, so the same model code runs on one CPU device and on a 512-chip mesh.

Divisibility guard: if a tensor dim is not divisible by the mapped mesh-axis
size (e.g. kv_heads=2 on a 16-way model axis), that dim silently falls back to
replication. This keeps one rule set valid across all 10 assigned architectures
(kv heads range over {0,1,2,4,8,20}).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_CTX = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    """Mapping of logical dim names to mesh axis names."""

    rules: Dict[str, AxisVal] = field(default_factory=dict)

    def get(self, name: str) -> AxisVal:
        return self.rules.get(name)

    def with_overrides(self, **kw: AxisVal) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(d)


def train_rules(multi_pod: bool = False, fsdp: bool = True) -> ShardingRules:
    """DP(+pod) over batch, FSDP over d_model param dim, TP over heads/ff/vocab,
    EP over experts."""
    batch: AxisVal = ("pod", "data") if multi_pod else "data"
    return ShardingRules({
        # --- activations ---
        "act_batch": batch,
        "act_seq": None,
        "act_d": None,
        "act_heads": "model",
        "act_ff": "model",
        "act_vocab": "model",
        "act_exp": "model",
        # --- params ---
        "d_model": "data" if fsdp else None,   # FSDP shard dim (within pod)
        "heads_x_hd": "model",                  # (H*hd) projection dim
        "kv_x_hd": None,                        # K/V proj replicated (K < TP)
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "layers": None,
        "lru": "model",
        "ssm_inner": "model",
        # --- caches ---
        "cache_batch": batch,
        "cache_seq": None,
        "cache_kv_heads": "model",
        # --- optimizer (ZeRO) ---
        "zero": "data",
    })


def serve_rules(multi_pod: bool = False, decode_seq_shard: bool = True) -> ShardingRules:
    """Serving: weight-stationary TP over 'model'; batch DP over 'data';
    decode KV caches sequence-sharded over 'model' (flash-decode style) so GQA
    kv_heads < TP degree still scales."""
    batch: AxisVal = ("pod", "data") if multi_pod else "data"
    return ShardingRules({
        "act_batch": batch,
        "act_seq": None,
        "act_d": None,
        "act_heads": "model",
        "act_ff": "model",
        "act_vocab": "model",
        "act_exp": "model",
        "d_model": None,                         # weights not FSDP-sharded when serving
        "heads_x_hd": "model",
        "kv_x_hd": None,
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "layers": None,
        "lru": "model",
        "ssm_inner": "model",
        "cache_batch": batch,
        "cache_seq": "model" if decode_seq_shard else None,
        "cache_kv_heads": None if decode_seq_shard else "model",
        "zero": None,
    })


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------


@contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _CTX.state = prev


def current() -> Optional[Tuple[Mesh, ShardingRules]]:
    return getattr(_CTX, "state", None)


def _axis_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def spec_for(shape: Tuple[int, ...], dims: Tuple[Optional[str], ...],
             mesh: Mesh, rules: ShardingRules) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    assert len(shape) == len(dims), (shape, dims)
    out = []
    used: set = set()
    for size, name in zip(shape, dims):
        ax = rules.get(name) if name else None
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            if any(a in used for a in axes):
                ax = None
            elif size % _axis_size(mesh, ax) != 0:
                ax = None
            else:
                used.update(axes)
        out.append(ax)
    return P(*out)


def logical(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """Tag an activation with logical dims; applies a sharding constraint when a
    mesh context is active, else identity."""
    state = current()
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(x.shape, dims, mesh, rules)
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(axes_tree, shapes_tree, mesh: Mesh, rules: ShardingRules):
    """Map a pytree of logical-dim tuples + matching shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda dims, shp: spec_for(tuple(shp), tuple(dims), mesh, rules),
        axes_tree, shapes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t),
    )


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules: ShardingRules):
    specs = tree_specs(axes_tree, shapes_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
