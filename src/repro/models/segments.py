"""Operator-segmented prefill execution — the TPU adaptation of FlowPrefill's
operator-level preemption (§5.1, Fig. 6).

On GPU the paper inserts cooperative preemption checks between CUDA kernel
launches. Under JAX/XLA the finest safe host-visible boundary is the dispatch
boundary of a compiled computation, so we compile the prefill as a sequence of
per-operator jitted segments over an explicit device-resident ExecState and let
the host check the preemption flag between dispatches. Suspension keeps the
state pytree alive on device (zero-copy); resume continues from the cursor.

Operator sets (paper §5.1 / §6.5 exactly):
    dense:  qkv_proj | attn | o_proj | gate_up_proj | down_proj
    moe:    qkv_proj | attn | o_proj | gate | experts
Boundary granularity is configurable (op / layer / block-k / whole) to
reproduce the paper's Fig. 12 operator-vs-layer comparison.

Supports chunked prefill (Fig. 15 interplay): `chunk_tokens > 0` splits the
prompt; each chunk runs all layers with q_offset resumption via the flash
kernel's kv_len/q_offset scalars.

Prefix-cache resumption: ``start(..., prefix_len=P, prefix_k/v=...)`` seeds
the first P cache positions with KV gathered from a shared prefix cache and
starts the chunk loop at operator offset P — the same q_offset mechanism
chunking already uses, so a P-token prefix hit is pure skipped compute
(attention still reads the seeded prefix through kv_len). P is capped at
prompt_len - 1 by callers: the last position must be computed live for the
first-token logits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.model import _project_qkv, embed_tokens, lm_head

State = Dict[str, Any]

DENSE_OPS = ("qkv_proj", "attn", "o_proj", "gate_up_proj", "down_proj")
MOE_OPS = ("qkv_proj", "attn", "o_proj", "gate", "experts")


def op_names(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.num_experts:
        if cfg.moe_layer_freq != 1:
            raise NotImplementedError(
                "segmented executor supports uniform MoE stacks (freq=1)")
        return MOE_OPS
    if cfg.family in ("dense", "vlm"):
        return DENSE_OPS
    raise NotImplementedError(
        f"segmented executor: family {cfg.family!r} not wired "
        "(mechanism generalizes; see DESIGN.md §4)")


# ---------------------------------------------------------------------------
# Per-operator functions: fn(stacked_layer_params, state, layer_idx, q_offset)
# ---------------------------------------------------------------------------


def _layer(params: Dict, l: jax.Array) -> Dict:
    return jax.tree.map(lambda x: x[l], params)


def _make_op_fns(cfg: ModelConfig, attn_impl: str) -> Dict[str, Callable]:
    K, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads

    def qkv_proj(pl_, st, l, off):
        p = _layer(pl_, l)
        x = L.rms_norm(st["h"], p["ln1"], cfg.norm_eps)
        return dict(st, tmp=_project_qkv(cfg, p, x))

    def attn(pl_, st, l, off):
        q, k, v = st["tmp"]
        B, Sc = q.shape[:2]
        positions = off + jnp.arange(Sc)[None, :]
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = lax.dynamic_update_slice(
            st["k_cache"], k[None].astype(st["k_cache"].dtype),
            (l, 0, off, 0, 0))
        vc = lax.dynamic_update_slice(
            st["v_cache"], v[None].astype(st["v_cache"].dtype),
            (l, 0, off, 0, 0))
        out = kops.prefill_attention(
            q, kc[l], vc[l], q_offset=off, kv_len=off + Sc,
            causal=True, local_window=cfg.local_window, impl=attn_impl)
        return dict(st, tmp=out.reshape(B, Sc, H * hd), k_cache=kc, v_cache=vc)

    def o_proj(pl_, st, l, off):
        p = _layer(pl_, l)
        h = st["h"] + jnp.einsum("bsq,qd->bsd", st["tmp"], p["wo"])
        return dict(st, h=h, tmp=None)

    def gate_up_proj(pl_, st, l, off):
        p = _layer(pl_, l)
        x = L.rms_norm(st["h"], p["ln2"], cfg.norm_eps)
        gu = jnp.einsum("bsd,dzf->bszf", x, p["wi"])
        return dict(st, tmp=jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :])

    def down_proj(pl_, st, l, off):
        p = _layer(pl_, l)
        h = st["h"] + jnp.einsum("bsf,fd->bsd", st["tmp"], p["wd"])
        return dict(st, h=h, tmp=None)

    def gate(pl_, st, l, off):
        p = _layer(pl_, l)
        x = L.rms_norm(st["h"], p["ln2"], cfg.norm_eps)
        w, idx, _ = L.moe_router(x, p["router"], cfg.experts_per_token)
        return dict(st, tmp=(x, w, idx))

    def experts(pl_, st, l, off):
        p = _layer(pl_, l)
        x, w, idx = st["tmp"]
        y = L.moe_apply(x, w, idx, p["wi"], p["wd"],
                        k=cfg.experts_per_token,
                        capacity_factor=cfg.moe_capacity_factor,
                        min_capacity=cfg.moe_min_capacity)
        return dict(st, h=st["h"] + y, tmp=None)

    return {"qkv_proj": qkv_proj, "attn": attn, "o_proj": o_proj,
            "gate_up_proj": gate_up_proj, "down_proj": down_proj,
            "gate": gate, "experts": experts}


# ---------------------------------------------------------------------------
# Execution plan + task
# ---------------------------------------------------------------------------


@dataclass
class PrefillTask:
    """A (possibly batched) prefill execution with device-resident state.
    The Execution Pool advances `cursor`; suspension is simply ceasing to
    dispatch — the state pytree stays alive on device."""
    state: State
    prompt_len: int
    n_chunks: int
    chunk: int
    total_segments: int
    start_offset: int = 0        # first token computed (prefix-cache hit:
                                 # positions < start_offset were seeded)
    cursor: int = 0
    logits: Optional[jax.Array] = None
    # representative output of the last dispatched segment — the Execution
    # Pool uses it to bound dispatch-ahead depth (bounded preemption latency
    # under async dispatch)
    sync_token: Optional[jax.Array] = None

    @property
    def done(self) -> bool:
        return self.cursor >= self.total_segments

    @property
    def progress(self) -> float:
        return self.cursor / max(self.total_segments, 1)


class SegmentedPrefill:
    """Preemptible prefill executor for one model instance.

    granularity: "op" (paper default) | "layer" | "block<k>" | "whole"
    chunk_tokens: 0 = no chunking (operator boundaries only), else chunked
                  prefill combined with operator preemption (paper Fig. 15).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seq: int,
                 granularity: str = "op", chunk_tokens: int = 0,
                 attn_impl: str = "xla", cache_dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.granularity = granularity
        self.chunk_tokens = chunk_tokens
        self.cache_dtype = cache_dtype
        self.ops = op_names(cfg)
        op_fns = _make_op_fns(cfg, attn_impl)

        # group ops into jitted segments according to granularity
        per_layer = [op_fns[name] for name in self.ops]
        if granularity == "op":
            groups: List[List[Callable]] = [[f] for f in per_layer]
        elif granularity == "layer":
            groups = [per_layer]
        elif granularity.startswith("block"):
            groups = [per_layer]           # layer group; block factor applied below
        elif granularity == "whole":
            groups = [per_layer]
        else:
            raise ValueError(granularity)

        self._block_layers = 1
        if granularity.startswith("block"):
            self._block_layers = int(granularity[len("block"):] or 2)
        elif granularity == "whole":
            self._block_layers = cfg.num_layers

        def make_segment(fns, n_layers):
            def seg(pl_, st, l0, off):
                for i in range(n_layers):
                    l = l0 + i
                    for f in fns:
                        st = f(pl_, st, l, off)
                return st
            return jax.jit(seg)

        self._segments = [make_segment(g, self._block_layers) for g in groups]
        self._segments_per_chunk = (
            (cfg.num_layers + self._block_layers - 1) // self._block_layers
            * len(self._segments))

        @jax.jit
        def start_fn(params_, tokens, vision_embeds=None):
            h = embed_tokens(cfg, params_, tokens)
            if cfg.family == "vlm" and vision_embeds is not None:
                P_ = vision_embeds.shape[1]
                h = h.at[:, :P_, :].set(vision_embeds.astype(h.dtype))
            return h

        @jax.jit
        def head_fn(params_, h_full, lens):
            # per-request last valid position (batched requests are padded)
            B = h_full.shape[0]
            h_last = h_full[jnp.arange(B), lens - 1][:, None, :]
            return lm_head(cfg, params_, h_last)[:, 0]

        self._start_fn = start_fn
        self._head_fn = head_fn

    # --- plan geometry -------------------------------------------------------
    def n_chunks(self, prompt_len: int, prefix_len: int = 0) -> int:
        todo = prompt_len - prefix_len
        if not self.chunk_tokens:
            return 1
        return (todo + self.chunk_tokens - 1) // self.chunk_tokens

    def segments_for(self, prompt_len: int, prefix_len: int = 0) -> int:
        return self.n_chunks(prompt_len, prefix_len) \
            * self._segments_per_chunk + 1                          # +head

    # --- lifecycle -------------------------------------------------------------
    def start(self, tokens: jax.Array, vision_embeds=None, lens=None,
              prefix_len: int = 0, prefix_k=None,
              prefix_v=None) -> PrefillTask:
        """Begin a prefill. ``prefix_len > 0`` resumes over a cached prompt
        prefix: `prefix_k`/`prefix_v` (nL, B, prefix_len, K, hd) seed the
        first positions of the KV cache and the chunk loop starts at
        operator offset `prefix_len` — suffix-only compute. Requires
        prefix_len < min(lens) (the last token's logits need a live pass)
        and no vision embeds (a VLM's vision span must be recomputed)."""
        B, S = tokens.shape
        cfgc = self.cfg
        K, hd = cfgc.num_kv_heads, cfgc.resolved_head_dim
        nL = cfgc.num_layers
        kc = jnp.zeros((nL, B, self.max_seq, K, hd), self.cache_dtype)
        vc = jnp.zeros_like(kc)
        if prefix_len:
            if vision_embeds is not None:
                raise ValueError("prefix resumption over vision embeds is "
                                 "not supported (recompute the vision span)")
            if prefix_len >= S:
                raise ValueError(f"prefix_len={prefix_len} must leave at "
                                 f"least one live token (prompt {S})")
            kc = kc.at[:, :, :prefix_len].set(
                prefix_k.astype(self.cache_dtype))
            vc = vc.at[:, :, :prefix_len].set(
                prefix_v.astype(self.cache_dtype))
        state: State = {
            "tokens": tokens,
            "lens": (jnp.full((B,), S, jnp.int32) if lens is None
                     else jnp.asarray(lens, jnp.int32)),
            "h": None,                    # set per-chunk
            "tmp": None,
            "k_cache": kc,
            "v_cache": vc,
            "h_full": jnp.zeros((B, S, cfgc.d_model), jnp.float32),
        }
        if vision_embeds is not None:
            state["vision_embeds"] = vision_embeds
        chunk = self.chunk_tokens or (S - prefix_len)
        task = PrefillTask(
            state=state, prompt_len=S, start_offset=prefix_len,
            n_chunks=self.n_chunks(S, prefix_len), chunk=chunk,
            total_segments=self.segments_for(S, prefix_len))
        return task

    def _chunk_bounds(self, task: PrefillTask, chunk_idx: int) -> Tuple[int, int]:
        lo = task.start_offset + chunk_idx * task.chunk
        hi = min(lo + task.chunk, task.prompt_len)
        return lo, hi

    def step(self, task: PrefillTask) -> bool:
        """Dispatch the next segment. Returns True when the task completed.
        This is the paper's operator boundary: the caller checks the preemption
        signal between calls."""
        if task.done:
            return True
        seg_idx = task.cursor
        spc = self._segments_per_chunk
        if seg_idx == task.total_segments - 1:              # lm_head
            task.logits = self._head_fn(self.params, task.state["h_full"],
                                        task.state["lens"])
            task.sync_token = task.logits
            task.cursor += 1
            return True

        chunk_idx, within = divmod(seg_idx, spc)
        lo, hi = self._chunk_bounds(task, chunk_idx)
        n_groups = len(self._segments)
        layer_block, group_idx = divmod(within, n_groups)
        l0 = layer_block * self._block_layers

        st = task.state
        if within == 0:                                     # chunk begins: embed slice
            tokens = st["tokens"][:, lo:hi]
            ve = st.get("vision_embeds") if chunk_idx == 0 else None
            st = dict(st, h=self._start_fn(self.params, tokens, ve))
        layer_params = (self.params["layers"])
        st = self._segments[group_idx](layer_params, st, l0, lo)
        if within == spc - 1:                               # chunk ends
            hf = lax.dynamic_update_slice(
                st["h_full"], st["h"].astype(st["h_full"].dtype), (0, lo, 0))
            st = dict(st, h_full=hf)
        task.state = st
        task.sync_token = st["h"] if st.get("h") is not None else st["h_full"]
        task.cursor += 1
        return task.done

    def run_all(self, task: PrefillTask) -> jax.Array:
        """Uninterrupted execution (baseline / tests)."""
        while not task.done:
            self.step(task)
        return task.logits
