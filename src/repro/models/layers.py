"""Model building blocks: norms, RoPE, GQA attention (full / local / blocked-flash),
SwiGLU, grouped MoE dispatch, RG-LRU, Mamba2 SSD, depthwise causal conv.

Pure-functional (params are dict pytrees). Everything here is jit- and
scan-compatible; sharding is applied by callers via NamedSharding on params and
activation sharding constraints (repro/distributed/sharding.py).
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.scan_ctl import scan as _ctl_scan

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed positional embeddings (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / (dim // 2)))
    pe = jnp.zeros((seq, dim), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _gqa_scores_einsum(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,S,K,Q,hd)  k: (B,T,K,hd) -> scores (B,K,Q,S,T)."""
    return jnp.einsum("bskqh,btkh->bkqst", q, k, preferred_element_type=jnp.float32)


def naive_attention(
    q: jax.Array,                  # (B, S, H, hd)
    k: jax.Array,                  # (B, T, K, hd)
    v: jax.Array,                  # (B, T, K, hd)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,  # absolute position of q[0] relative to k[0]
    local_window: int = 0,
    kv_len: Optional[jax.Array] = None,  # valid kv length (for caches)
    k_positions: Optional[jax.Array] = None,  # (T,) absolute positions (ring buffers)
) -> jax.Array:
    """Reference attention: materializes scores. Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    qg = q.reshape(B, S, K, H // K, hd)
    scores = _gqa_scores_einsum(qg, k) / math.sqrt(hd)     # (B,K,Q,S,T) f32

    # q_offset / kv_len accept per-row (B,) arrays (ragged decode batches);
    # scalars broadcast over the leading batch axis exactly as before
    q_pos = jnp.arange(S)[:, None] \
        + jnp.asarray(q_offset).reshape(-1, 1, 1)          # (B or 1, S, 1)
    if k_positions is not None:
        k_pos = k_positions[None, None, :]                 # (1, 1, T)
    else:
        k_pos = jnp.arange(T)[None, None, :]               # (1, 1, T)
    mask = jnp.ones((1, S, T), dtype=bool)
    if k_positions is not None:
        mask = mask & (k_pos >= 0)                         # unwritten ring slots
    if causal:
        mask = mask & (k_pos <= q_pos)
    if local_window:
        mask = mask & (k_pos > q_pos - local_window)
    if kv_len is not None:
        mask = mask & (k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1))
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkqst,btkh->bskqh", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def _flash_mask(S, block, start, q_offset, causal, local_window, kv_len):
    q_pos = jnp.arange(S)[:, None] + q_offset              # (S,1)
    k_pos = start + jnp.arange(block)[None, :]             # (1,block)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if local_window:
        mask &= k_pos > q_pos - local_window
    return mask


def _flash_fwd_impl(qg, kb_t, vb_t, q_offset, kv_len, causal, local_window,
                    block):
    """qg: (B,S,K,Q,hd) f32 unscaled; kb_t/vb_t: (nb,B,block,K,hd).
    Returns (out (B,K,Q,S,hd) f32, lse (B,K,Q,S,1))."""
    B, S, K, Q, hd = qg.shape
    scale = 1.0 / math.sqrt(hd)
    nb = kb_t.shape[0]
    starts = jnp.arange(nb) * block

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, start = inp
        s = jnp.einsum("bskqh,btkh->bkqst", qg,
                       kc.astype(jnp.float32)) * scale
        mask = _flash_mask(S, block, start, q_offset, causal, local_window,
                           kv_len)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bkqst,btkh->bkqsh", p, vc.astype(jnp.float32))
        acc_new = acc * alpha + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, Q, S, 1), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, K, Q, S, 1), dtype=jnp.float32)
    a0 = jnp.zeros((B, K, Q, S, hd), dtype=jnp.float32)
    (m, l, acc), _ = _ctl_scan(body, (m0, l0, a0), (kb_t, vb_t, starts))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    lse = jnp.where(l == 0.0, jnp.inf, m_safe + jnp.log(jnp.maximum(l, 1e-30)))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash(qg, kb_t, vb_t, q_offset, kv_len, causal, local_window, block):
    out, _ = _flash_fwd_impl(qg, kb_t, vb_t, q_offset, kv_len, causal,
                             local_window, block)
    return out


def _flash_fwd(qg, kb_t, vb_t, q_offset, kv_len, causal, local_window, block):
    out, lse = _flash_fwd_impl(qg, kb_t, vb_t, q_offset, kv_len, causal,
                               local_window, block)
    return out, (qg, kb_t, vb_t, out, lse, q_offset, kv_len)


def _flash_bwd(causal, local_window, block, res, dout):
    """Flash-attention backward: recompute p blockwise from (q,k,lse); store
    no per-block state. Residuals are O(S*hd) — this is what keeps the remat'd
    training step's peak memory bounded (EXPERIMENTS.md §Perf E3); the Pallas
    kernel implements the same algorithm in VMEM on TPU."""
    qg, kb_t, vb_t, out, lse, q_offset, kv_len = res
    B, S, K, Q, hd = qg.shape
    scale = 1.0 / math.sqrt(hd)
    nb = kb_t.shape[0]
    starts = jnp.arange(nb) * block
    dout = dout.astype(jnp.float32)                        # (B,K,Q,S,hd)
    Drow = jnp.sum(dout * out, axis=-1, keepdims=True)     # (B,K,Q,S,1)
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)

    def body(dq, inp):
        kc, vc, start = inp
        kc32 = kc.astype(jnp.float32)
        vc32 = vc.astype(jnp.float32)
        s = jnp.einsum("bskqh,btkh->bkqst", qg, kc32) * scale
        mask = _flash_mask(S, block, start, q_offset, causal, local_window,
                           kv_len)
        p = jnp.where(mask[None, None, None],
                      jnp.exp(s - lse_safe), 0.0)          # exact probs
        dv_blk = jnp.einsum("bkqst,bkqsh->btkh", p, dout)
        dp = jnp.einsum("bkqsh,btkh->bkqst", dout, vc32)
        ds = p * (dp - Drow)
        dq = dq + jnp.einsum("bkqst,btkh->bskqh", ds, kc32) * scale
        dk_blk = jnp.einsum("bkqst,bskqh->btkh", ds, qg) * scale
        return dq, (dk_blk.astype(kc.dtype), dv_blk.astype(vc.dtype))

    dq0 = jnp.zeros_like(qg)
    dq, (dk_t, dv_t) = _ctl_scan(body, dq0, (kb_t, vb_t, starts))
    return dq, dk_t, dv_t, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_attention(
    q: jax.Array,                  # (B, S, H, hd)
    k: jax.Array,                  # (B, T, K, hd)
    v: jax.Array,                  # (B, T, K, hd)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    local_window: int = 0,
    kv_len: Optional[jax.Array] = None,
    block: int = 1024,
) -> jax.Array:
    """Flash attention in pure JAX with a flash custom-VJP: lax.scan over KV
    blocks with an online softmax, O(S*block) memory in both forward AND
    backward (backward recomputes probabilities blockwise from the saved
    logsumexp). Same math as the Pallas kernel (kernels/flash_prefill.py)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    kv_len = jnp.asarray(T if kv_len is None else kv_len, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)
    if T % block:
        pad = block - T % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        T = T + pad
    nblocks = T // block
    qg = q.reshape(B, S, K, H // K, hd).astype(jnp.float32)
    kb_t = jnp.moveaxis(k.reshape(B, nblocks, block, K, hd), 1, 0)
    vb_t = jnp.moveaxis(v.reshape(B, nblocks, block, K, hd), 1, 0)
    out = _flash(qg, kb_t, vb_t, q_offset, kv_len, causal, local_window,
                 block)                                    # (B,K,Q,S,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def attention(q, k, v, *, impl: str = "auto", **kw) -> jax.Array:
    """Dispatch between implementations. 'pallas' is wired in kernels/ops.py to
    avoid a circular import; callers that want the kernel use that wrapper."""
    if impl == "auto":
        impl = "blocked" if q.shape[1] * k.shape[1] > 1 << 22 else "naive"
    if impl == "naive":
        return naive_attention(q, k, v, **kw)
    if impl == "blocked":
        kw.setdefault("block", 1024)
        return blocked_attention(q, k, v, **kw)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_in: jax.Array, w_out: jax.Array) -> jax.Array:
    """w_in: (D, 2, F) gate+up on an explicit axis (shard-aligned split);
    w_out: (F, D)."""
    gu = jnp.einsum("bsd,dzf->bszf", x, w_in)
    gate, up = gu[..., 0, :], gu[..., 1, :]
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, w_out)


def gelu_mlp(x: jax.Array, w_in, b_in, w_out, b_out) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_in) + b_in)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out


# ---------------------------------------------------------------------------
# MoE (GShard-style grouped dispatch — TPU idiomatic, dense einsums)
# ---------------------------------------------------------------------------


def moe_router(x: jax.Array, w_router: jax.Array, k: int):
    """x: (B,S,D) -> (weights (B,S,k) f32, indices (B,S,k) i32, logits)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), w_router.astype(jnp.float32))
    weights, idx = lax.top_k(logits, k)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights, idx, logits


def moe_apply(
    x: jax.Array,                  # (B, S, D)
    weights: jax.Array,            # (B, S, k) routing weights (from moe_router)
    idx: jax.Array,                # (B, S, k) expert indices
    w_gate_up: jax.Array,          # (E, D, 2F)
    w_down: jax.Array,             # (E, F, D)
    *,
    k: int,
    capacity_factor: float = 1.25,
    min_capacity: int = 4,
    group_size: int = 0,
) -> jax.Array:
    """Expert computation given routing decisions (the paper's `experts`
    fused operator; `gate` = moe_router). GShard-style capacity dispatch:
    tokens are processed in groups so dispatch/combine einsum FLOPs stay
    ~O(tokens * group * D) rather than O(tokens^2 * D). Overflowing tokens are
    dropped (standard capacity semantics); the residual preserves them."""
    B, S, D = x.shape
    E = w_gate_up.shape[0]

    if group_size:
        g = min(B * S, group_size)
    else:
        # dispatch/combine einsum FLOPs scale as 2*2*g*k*cf*D per token while
        # expert compute is 6*k*D*F — small-F experts need small groups or the
        # dispatch dominates (EXPERIMENTS.md §Perf E2). Capacity stays >= 128
        # rows for MXU alignment at these sizes.
        F = w_gate_up.shape[-1] // 2
        g = min(B * S, 512 if F < 2048 else 4096)
    n_groups = (B * S) // g if (B * S) % g == 0 else 0
    if n_groups == 0:                                     # fall back: one group
        g, n_groups = B * S, 1
    xg = x.reshape(n_groups, g, D)
    wg = weights.reshape(n_groups, g, k)
    ig = idx.reshape(n_groups, g, k)

    cap = min(max(int(g * k * capacity_factor / E), min_capacity, 1), g)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(ig, E, dtype=jnp.int32)        # (G,g,k,E)
    flat = onehot.reshape(n_groups, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                     # (G,g*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(n_groups, g, k)
    keep = pos < cap
    wg = wg * keep.astype(wg.dtype)

    # dispatch tensor (G, g, E, cap) — boolean product of expert + slot one-hots
    disp = (
        jax.nn.one_hot(ig, E, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype)[..., None, :-1]
    ).sum(axis=2)                                          # (G,g,E,cap)
    # weighted combine tensor: routing weight of token s for slot (e, c)
    wslot = (
        jax.nn.one_hot(ig, E, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=jnp.float32)[..., None, :-1]
        * wg[..., None, None]
    ).sum(axis=2)                                          # (G,g,E,cap) f32

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)            # (G,E,cap,D)
    gu = jnp.einsum("gecd,edf->gecf", xe, w_gate_up)
    gate, up = jnp.split(gu, 2, axis=-1)
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, w_down)
    y = jnp.einsum("gsec,gecd->gsd", wslot.astype(ye.dtype), ye)
    return y.reshape(B, S, D)


def moe_ffn(
    x: jax.Array,                  # (B, S, D)
    w_router: jax.Array,           # (D, E)
    w_gate_up: jax.Array,          # (E, D, 2F)
    w_down: jax.Array,             # (E, F, D)
    *,
    k: int,
    capacity_factor: float = 1.25,
    min_capacity: int = 4,
    group_size: int = 0,
) -> jax.Array:
    """Top-k MoE FFN = moe_router (`gate`) + moe_apply (`experts`)."""
    weights, idx, _ = moe_router(x, w_router, k)
    return moe_apply(x, weights, idx, w_gate_up, w_down, k=k,
                     capacity_factor=capacity_factor,
                     min_capacity=min_capacity, group_size=group_size)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (mamba2 / rg-lru)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                  state: Optional[jax.Array] = None):
    """x: (B, S, C); w: (C, W) depthwise; state: (B, W-1, C) trailing context.
    Returns (y (B,S,C), new_state (B, W-1, C))."""
    B, S, C = x.shape
    W = w.shape[-1]
    if state is None:
        state = jnp.zeros((B, W - 1, C), dtype=x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, S+W-1, C)
    idx = jnp.arange(S)[:, None] + jnp.arange(W)[None, :]  # (S, W)
    windows = xp[:, idx]                                   # (B, S, W, C)
    y = jnp.einsum("bswc,cw->bsc", windows, w)
    if b is not None:
        y = y + b
    new_state = xp[:, S:]                                  # last W-1 positions
    return y, new_state


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) — linear recurrence via associative scan
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru(x: jax.Array, a_param: jax.Array, w_rg: jax.Array, w_ig: jax.Array,
          h0: Optional[jax.Array] = None):
    """Real-Gated Linear Recurrent Unit.
        r_t = sigmoid(x_t @ w_rg);  i_t = sigmoid(x_t @ w_ig)
        log a_t = -c * softplus(a_param) * r_t
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    x: (B, S, C). h0: (B, C). Returns (y (B,S,C), h_last (B,C)).
    """
    B, S, C = x.shape
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsc,cd->bsd", x32, w_rg.astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("bsc,cd->bsd", x32, w_ig.astype(jnp.float32)))
    log_a = -_RGLRU_C * jax.nn.softplus(a_param.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)

    if h0 is None:
        h0 = jnp.zeros((B, C), dtype=jnp.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    aseq = jnp.moveaxis(a, 1, 0)                           # (S, B, C)
    bseq = jnp.moveaxis(gated, 1, 0)
    a_cum, b_cum = lax.associative_scan(combine, (aseq, bseq), axis=0)
    h = a_cum * h0[None] + b_cum                           # (S, B, C)
    y = jnp.moveaxis(h, 0, 1)
    return y.astype(x.dtype), h[-1]


def rglru_step(x_t: jax.Array, a_param, w_rg, w_ig, h: jax.Array):
    """Single-token recurrent step. x_t: (B, C); h: (B, C) f32."""
    x32 = x_t.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ w_rg.astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ w_ig.astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(a_param.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (i * x32)
    return h_new.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)   values
    dt: jax.Array,       # (B, S, H)      softplus'd step sizes (>0)
    A: jax.Array,        # (H,)           negative decay rates (A < 0 semantics: a = exp(A*dt))
    Bm: jax.Array,       # (B, S, N)      input projection (1 group)
    Cm: jax.Array,       # (B, S, N)      output projection
    *,
    chunk: int = 256,
    h0: Optional[jax.Array] = None,      # (B, H, P, N)
):
    """Chunked SSD: y_t = C_t^T h_t,  h_t = exp(A*dt_t) h_{t-1} + dt_t * B_t x_t^T.

    Standard Mamba2 minimal algorithm: intra-chunk quadratic term + inter-chunk
    recurrence on chunk states. Returns (y (B,S,H,P), h_last (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    dA = dtc * A.astype(jnp.float32)[None, None, None, :]  # (B,nc,L,H) log-decay
    dA_cs = jnp.cumsum(dA, axis=2)                         # cumulative within chunk

    # intra-chunk: Y_intra[t] = sum_{s<=t} C_t.B_s exp(dA_cs[t]-dA_cs[s]) dt_s x_s
    # (mask in log-domain: exp of the upper triangle overflows before masking)
    L = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (B,nc,L,L,H)
    seg = jnp.where(L[None, None, :, :, None], seg, -jnp.inf)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)          # (B,nc,L,L)
    gate = scores[..., None] * jnp.exp(seg)
    y_intra = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", gate, dtc, xc)

    # chunk states: h_chunk = sum_s exp(dA_cs[last]-dA_cs[s]) dt_s B_s x_s^T
    decay_last = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)       # (B,nc,L,H)
    states = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn",
                        decay_last, dtc, Bc, xc)             # (B,nc,H,P,N)

    # inter-chunk recurrence over chunk boundary states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (B,nc,H)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_seq = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,B,H)
    s_seq = jnp.moveaxis(states, 1, 0)                       # (nc,B,H,P,N)
    a_cum, s_cum = lax.associative_scan(combine, (a_seq, s_seq), axis=0)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
    s_cum = s_cum + a_cum[..., None, None] * h0[None]
    h_last = s_cum[-1]
    # state entering each chunk (shift by one)
    h_in = jnp.concatenate([h0[None], s_cum[:-1]], axis=0)   # (nc,B,H,P,N)
    h_in = jnp.moveaxis(h_in, 0, 1)                          # (B,nc,H,P,N)

    # inter-chunk contribution: y_inter[t] = C_t . (exp(dA_cs[t]) h_in)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc, jnp.exp(dA_cs), h_in)
    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), h_last


def ssd_step(x_t, dt_t, A, B_t, C_t, h):
    """Single-token SSD recurrence.
    x_t: (B,H,P); dt_t: (B,H); B_t/C_t: (B,N); h: (B,H,P,N) f32.
    """
    a = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None])  # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t.astype(jnp.float32),
                     B_t.astype(jnp.float32), x_t.astype(jnp.float32))
    h_new = h * a[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), h_new)
    return y.astype(x_t.dtype), h_new
