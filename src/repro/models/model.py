"""Unified model definitions for all assigned architectures.

One functional model family with per-family layer bodies, all scanned over
stacked layer params (compile time independent of depth — required for the
80-layer dry-runs). Entry points:

    init_params(cfg, rng, dtype)      -> params pytree
    param_axes(cfg)                   -> same-structure pytree of logical dims
    forward(params, cfg, batch, ...)  -> logits          (training)
    prefill(params, cfg, batch, ...)  -> (logits, cache) (serving prefill)
    init_cache(cfg, batch, max_seq)   -> cache pytree    (zeros)
    cache_axes(cfg)                   -> logical dims for the cache
    decode_step(params, cfg, tokens, cache) -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, _expanded_pattern
from repro.distributed.sharding import logical
from repro.models import layers as L
from repro.models.scan_ctl import scan as _ctl_scan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


def _attn_layer_shapes(cfg: ModelConfig, cross: bool = False,
                       moe: Optional[bool] = None) -> Dict[str, Tuple[int, ...]]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    if moe is None:
        moe = bool(cfg.num_experts)
    # separate q/k/v projections: fused QKV splits at non-shard-aligned
    # boundaries under TP and GSPMD realigns with collective-permutes
    # (EXPERIMENTS.md §Perf E1)
    s: Dict[str, Tuple[int, ...]] = {
        "ln1": (d,),
        "wq": (d, H * hd),
        "wk": (d, K * hd),
        "wv": (d, K * hd),
        "wo": (H * hd, d),
    }
    if cfg.qkv_bias:
        s["bq"] = (H * hd,)
        s["bk"] = (K * hd,)
        s["bv"] = (K * hd,)
    if cfg.family == "audio":
        s["ln1_b"] = (d,)
    if cross:
        s["ln_x"] = (d,)
        s["ln_x_b"] = (d,)
        s["wq_x"] = (d, H * hd)
        s["wk_x"] = (d, K * hd)
        s["wv_x"] = (d, K * hd)
        s["wo_x"] = (H * hd, d)
    # FFN
    if moe:
        s["router"] = (d, cfg.num_experts)
        s["wi"] = (cfg.num_experts, d, 2 * cfg.d_ff)
        s["wd"] = (cfg.num_experts, cfg.d_ff, d)
        s["ln2"] = (d,)
    elif cfg.family == "audio":
        s.update({"ln2": (d,), "ln2_b": (d,), "wi": (d, cfg.d_ff), "bi": (cfg.d_ff,),
                  "wd": (cfg.d_ff, d), "bd": (d,)})
    else:
        ff = cfg.d_ff_dense if (cfg.num_experts and cfg.d_ff_dense) else cfg.d_ff
        # gate|up as an explicit (2, F) axis so the split is shard-aligned
        s.update({"ln2": (d,), "wi": (d, 2, ff), "wd": (ff, d)})
    return s


def _attn_layer_axes(cfg: ModelConfig, cross: bool = False,
                     moe: Optional[bool] = None) -> Dict[str, Tuple]:
    if moe is None:
        moe = bool(cfg.num_experts)
    ax: Dict[str, Tuple] = {
        "ln1": (None,),
        "wq": ("d_model", "heads_x_hd"),
        # K/V projections replicate when kv_heads < TP degree (small params;
        # avoids mid-head sharding reshards)
        "wk": ("d_model", "kv_x_hd"),
        "wv": ("d_model", "kv_x_hd"),
        "wo": ("heads_x_hd", "d_model"),
    }
    if cfg.qkv_bias:
        ax["bq"] = ("heads_x_hd",)
        ax["bk"] = ("kv_x_hd",)
        ax["bv"] = ("kv_x_hd",)
    if cfg.family == "audio":
        ax["ln1_b"] = (None,)
    if cross:
        ax.update({"ln_x": (None,), "ln_x_b": (None,),
                   "wq_x": ("d_model", "heads_x_hd"),
                   "wk_x": ("d_model", "kv_x_hd"),
                   "wv_x": ("d_model", "kv_x_hd"),
                   "wo_x": ("heads_x_hd", "d_model")})
    if moe:
        ax.update({"router": ("d_model", None),
                   "wi": ("experts", "d_model", "ff"),
                   "wd": ("experts", "ff", "d_model"),
                   "ln2": (None,)})
    elif cfg.family == "audio":
        ax.update({"ln2": (None,), "ln2_b": (None,), "wi": ("d_model", "ff"),
                   "bi": ("ff",), "wd": ("ff", "d_model"), "bd": (None,)})
    else:
        ax.update({"ln2": (None,), "wi": ("d_model", None, "ff"),
                   "wd": ("ff", "d_model")})
    return ax


def _rglru_layer_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, lw = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "ln1": (d,),
        "w_x": (d, lw), "w_gate": (d, lw),
        "conv_w": (lw, cfg.ssm_conv_width), "conv_b": (lw,),
        "a_param": (lw,), "w_rg": (lw, lw), "w_ig": (lw, lw),
        "w_y": (lw, d),
        "ln2": (d,), "wi": (d, 2, cfg.d_ff), "wd": (cfg.d_ff, d),
    }


def _rglru_layer_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    return {
        "ln1": (None,),
        "w_x": ("d_model", "lru"), "w_gate": ("d_model", "lru"),
        "conv_w": ("lru", None), "conv_b": ("lru",),
        "a_param": ("lru",), "w_rg": ("lru", "lru"), "w_ig": ("lru", "lru"),
        "w_y": ("lru", "d_model"),
        "ln2": (None,), "wi": ("d_model", None, "ff"), "wd": ("ff", "d_model"),
    }


def _ssm_layer_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, din, N, nh, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.ssm_heads, cfg.ssm_conv_width)
    return {
        "ln": (d,),
        "in_proj": (d, 2 * din + 2 * N + nh),
        "conv_w": (din + 2 * N, W), "conv_b": (din + 2 * N,),
        "A_log": (nh,), "Dp": (nh,), "dt_bias": (nh,),
        "norm_w": (din,),
        "out_proj": (din, d),
    }


def _ssm_layer_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    return {
        "ln": (None,),
        "in_proj": ("d_model", "ssm_inner"),
        "conv_w": ("ssm_inner", None), "conv_b": ("ssm_inner",),
        "A_log": (None,), "Dp": (None,), "dt_bias": (None,),
        "norm_w": ("ssm_inner",),
        "out_proj": ("ssm_inner", "d_model"),
    }


def _stack_shapes(shapes: Dict[str, Tuple[int, ...]], n: int):
    return {k: (n,) + v for k, v in shapes.items()}


def _stack_axes(axes: Dict[str, Tuple], n_name: str = "layers"):
    return {k: (n_name,) + v for k, v in axes.items()}


def _hybrid_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(#scanned triples, #tail rglru layers) such that the expanded pattern
    (rglru, rglru, attn)* truncated to num_layers is realized exactly."""
    pat = _expanded_pattern(cfg)
    n_tri = len(pat) // 3
    tail = len(pat) - 3 * n_tri
    assert all(p == "rglru" for p in pat[3 * n_tri:]), "tail must be rglru layers"
    return n_tri, tail


def model_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    out: Dict[str, Any] = {"embed": (cfg.vocab_size, d), "final_norm": (d,)}
    if not cfg.tie_embeddings:
        out["unembed"] = (d, cfg.vocab_size)

    if cfg.family == "ssm":
        out["layers"] = _stack_shapes(_ssm_layer_shapes(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        n_tri, tail = _hybrid_counts(cfg)
        tri = {"r1": _rglru_layer_shapes(cfg), "r2": _rglru_layer_shapes(cfg),
               "attn": _attn_layer_shapes(cfg)}
        out["blocks"] = jax.tree.map(lambda s: (n_tri,) + s, tri,
                                     is_leaf=lambda x: isinstance(x, tuple))
        if tail:
            out["tail"] = _stack_shapes(_rglru_layer_shapes(cfg), tail)
    elif cfg.family == "audio":
        out["enc_final_norm"] = (d,)
        out["enc_final_norm_b"] = (d,)
        out["final_norm_b"] = (d,)
        out["enc_layers"] = _stack_shapes(_attn_layer_shapes(cfg), cfg.num_encoder_layers)
        out["layers"] = _stack_shapes(_attn_layer_shapes(cfg, cross=True), cfg.num_layers)
    elif cfg.num_experts and cfg.moe_layer_freq == 2:
        n_pairs = cfg.num_layers // 2
        pair = {"dense": _attn_layer_shapes(cfg, moe=False),
                "moe": _attn_layer_shapes(cfg, moe=True)}
        out["pairs"] = jax.tree.map(lambda s: (n_pairs,) + s, pair,
                                    is_leaf=lambda x: isinstance(x, tuple))
    else:  # dense / moe(freq=1) / vlm
        out["layers"] = _stack_shapes(_attn_layer_shapes(cfg), cfg.num_layers)
    return out


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    out: Dict[str, Any] = {"embed": ("vocab", "d_model"), "final_norm": (None,)}
    if not cfg.tie_embeddings:
        out["unembed"] = ("d_model", "vocab")
    if cfg.family == "ssm":
        out["layers"] = _stack_axes(_ssm_layer_axes(cfg))
    elif cfg.family == "hybrid":
        tri = {"r1": _rglru_layer_axes(cfg), "r2": _rglru_layer_axes(cfg),
               "attn": _attn_layer_axes(cfg)}
        out["blocks"] = jax.tree.map(lambda a: ("layers",) + a, tri,
                                     is_leaf=lambda x: isinstance(x, tuple))
        if _hybrid_counts(cfg)[1]:
            out["tail"] = _stack_axes(_rglru_layer_axes(cfg))
    elif cfg.family == "audio":
        out["enc_final_norm"] = (None,)
        out["enc_final_norm_b"] = (None,)
        out["final_norm_b"] = (None,)
        out["enc_layers"] = _stack_axes(_attn_layer_axes(cfg))
        out["layers"] = _stack_axes(_attn_layer_axes(cfg, cross=True))
    elif cfg.num_experts and cfg.moe_layer_freq == 2:
        pair = {"dense": _attn_layer_axes(cfg, moe=False),
                "moe": _attn_layer_axes(cfg, moe=True)}
        out["pairs"] = jax.tree.map(lambda a: ("layers",) + a, pair,
                                    is_leaf=lambda x: isinstance(x, tuple))
    else:
        out["layers"] = _stack_axes(_attn_layer_axes(cfg))
    return out


def init_params(cfg: ModelConfig, rng: jax.Array, dtype=jnp.float32) -> Params:
    shapes = model_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    rngs = jax.random.split(rng, len(leaves))

    def init_one(shape, key):
        if len(shape) >= 3 and shape[-2] == 2:     # (.., d, 2, F) gate|up
            fan_in = shape[-3]
        elif len(shape) >= 2:
            fan_in = shape[-2]
        else:
            fan_in = shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        if len(shape) == 1 or shape[-1] == 1:
            return jnp.zeros(shape, dtype=dtype)
        return _init(key, shape, scale, dtype)

    params = treedef.unflatten([init_one(s, k) for s, k in zip(leaves, rngs)])

    # family-specific non-zero inits
    def fix(layer):
        if "A_log" in layer:
            nh = layer["A_log"].shape[-1]
            a0 = jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32))
            layer["A_log"] = jnp.broadcast_to(a0, layer["A_log"].shape).astype(dtype)
            layer["dt_bias"] = jnp.full_like(layer["dt_bias"], 0.5)
            layer["Dp"] = jnp.ones_like(layer["Dp"])
        if "a_param" in layer:
            layer["a_param"] = jnp.full_like(layer["a_param"], 0.7)
        return layer

    if cfg.family == "ssm":
        params["layers"] = fix(params["layers"])
    elif cfg.family == "hybrid":
        params["blocks"]["r1"] = fix(params["blocks"]["r1"])
        params["blocks"]["r2"] = fix(params["blocks"]["r2"])
        if "tail" in params:
            params["tail"] = fix(params["tail"])
    return params


def param_count(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    """Three shard-aligned projections (see §Perf E1)."""
    B, S = x.shape[:2]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, K, hd),
            v.reshape(B, S, K, hd))


def attn_block(cfg: ModelConfig, p: Params, h: jax.Array, *, positions,
               attn_impl: str = "auto", window: int = 0,
               use_rope: bool = True, causal: bool = True):
    """Pre-norm attention block. Returns (h, (k, v)) — k/v for cache collection."""
    if cfg.family == "audio":
        x = L.layer_norm(h, p["ln1"], p["ln1_b"], cfg.norm_eps)
    else:
        x = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, x)
    if use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    q = logical(q, "act_batch", "act_seq", "act_heads", None)
    k = logical(k, "act_batch", "act_seq", "act_heads", None)
    o = L.attention(q, k, v, impl=attn_impl, causal=causal, local_window=window)
    o = o.reshape(h.shape[0], h.shape[1], -1)
    h = h + jnp.einsum("bsq,qd->bsd", o, p["wo"])
    return logical(h, "act_batch", "act_seq", "act_d"), (k, v)


def ffn_block(cfg: ModelConfig, p: Params, h: jax.Array):
    """FFN variant dispatches on the param keys of the layer (supports
    interleaved dense/MoE stacks where cfg alone is ambiguous)."""
    if "ln2_b" in p:                     # audio: LayerNorm + GELU MLP
        x = L.layer_norm(h, p["ln2"], p["ln2_b"], cfg.norm_eps)
        y = L.gelu_mlp(x, p["wi"], p["bi"], p["wd"], p["bd"])
    elif "router" in p:                  # MoE
        x = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        y = L.moe_ffn(x, p["router"], p["wi"], p["wd"],
                      k=cfg.experts_per_token,
                      capacity_factor=cfg.moe_capacity_factor,
                      min_capacity=cfg.moe_min_capacity)
    else:                                # SwiGLU
        x = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        y = L.swiglu(x, p["wi"], p["wd"])
    return logical(h + y, "act_batch", "act_seq", "act_d")


def rglru_block(cfg: ModelConfig, p: Params, h: jax.Array, *,
                conv_state=None, h_state=None):
    """Griffin recurrent block + MLP. Returns (h, (h_last, conv_state))."""
    x = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dc->bsc", x, p["w_gate"]))
    xb = jnp.einsum("bsd,dc->bsc", x, p["w_x"])
    xb, conv_state = L.causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)
    y, h_last = L.rglru(xb, p["a_param"], p["w_rg"], p["w_ig"], h_state)
    h = h + jnp.einsum("bsc,cd->bsd", y * gate, p["w_y"])
    h = ffn_block(cfg, p, h)
    return logical(h, "act_batch", "act_seq", "act_d"), (h_last, conv_state)


def ssm_block(cfg: ModelConfig, p: Params, h: jax.Array, *,
              conv_state=None, ssm_state=None):
    """Mamba2 block. Returns (h, (ssm_state, conv_state))."""
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_ = cfg.ssm_head_dim
    x = L.rms_norm(h, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    # layout: z (din) | xBC (din + 2N, the conv input x|B|C) | dt (nh)
    z, xBC, dt = jnp.split(proj, [din, 2 * din + 2 * N], axis=-1)
    xBC_conv, conv_state = L.causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC_conv = jax.nn.silu(xBC_conv)
    xs, Bm, Cm = jnp.split(xBC_conv, [din, din + N], axis=-1)
    Bsz, S = h.shape[:2]
    xs = xs.reshape(Bsz, S, nh, P_)
    dt_sp = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssm_state = L.ssd_chunked(xs, dt_sp, A, Bm, Cm, chunk=cfg.ssm_chunk, h0=ssm_state)
    y = y + xs * p["Dp"][None, None, :, None]
    y = y.reshape(Bsz, S, din)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    h = h + jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    return logical(h, "act_batch", "act_seq", "act_d"), (ssm_state, conv_state)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens]
    return logical(h, "act_batch", "act_seq", "act_d")


def lm_head(cfg: ModelConfig, params: Params, h: jax.Array,
            norm_key: str = "final_norm") -> jax.Array:
    if cfg.family == "audio":
        h = L.layer_norm(h, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    else:
        h = L.rms_norm(h, params[norm_key], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    return logical(logits, "act_batch", "act_seq", "act_vocab")


def _merge_vision(cfg: ModelConfig, h: jax.Array, vision_embeds: jax.Array):
    """Replace the leading num_patches positions with patch embeddings."""
    P_ = vision_embeds.shape[1]
    return h.at[:, :P_, :].set(vision_embeds.astype(h.dtype))


# ---------------------------------------------------------------------------
# Forward (training) — full sequence, scan over layers
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "auto", remat: str = "none") -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    if cfg.family == "audio":
        return _forward_audio(params, cfg, batch, attn_impl=attn_impl, remat=remat)

    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    h = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        h = _merge_vision(cfg, h, batch["vision_embeds"])

    if cfg.family == "ssm":
        def body(carry, p_l):
            y, _ = ssm_block(cfg, p_l, carry)
            return y, None
        h, _ = _ctl_scan(_remat(body, remat), h, params["layers"])
    elif cfg.family == "hybrid":
        def tri_body(carry, p_t):
            y, _ = rglru_block(cfg, p_t["r1"], carry)
            y, _ = rglru_block(cfg, p_t["r2"], y)
            y, _ = attn_block(cfg, p_t["attn"], y, positions=positions,
                              attn_impl=attn_impl, window=cfg.local_window)
            y = ffn_block(cfg, p_t["attn"], y)
            return y, None
        h, _ = _ctl_scan(_remat(tri_body, remat), h, params["blocks"])
        if "tail" in params:
            def tail_body(carry, p_l):
                y, _ = rglru_block(cfg, p_l, carry)
                return y, None
            h, _ = _ctl_scan(_remat(tail_body, remat), h, params["tail"])
    elif "pairs" in params:
        def pair_body(carry, p_p):
            y = carry
            for sub in ("dense", "moe"):
                y, _ = attn_block(cfg, p_p[sub], y, positions=positions,
                                  attn_impl=attn_impl, window=cfg.local_window)
                y = ffn_block(cfg, p_p[sub], y)
            return y, None
        h, _ = _ctl_scan(_remat(pair_body, remat), h, params["pairs"])
    else:
        def body(carry, p_l):
            y, _ = attn_block(cfg, p_l, carry, positions=positions,
                              attn_impl=attn_impl, window=cfg.local_window)
            y = ffn_block(cfg, p_l, y)
            return y, None
        h, _ = _ctl_scan(_remat(body, remat), h, params["layers"])

    return lm_head(cfg, params, h)


def _forward_audio(params, cfg, batch, *, attn_impl="auto", remat="none"):
    frames = batch["frames"]                       # (B, Tenc, D) stub embeddings
    tokens = batch["tokens"]                       # (B, S)
    B, Tenc = frames.shape[:2]
    S = tokens.shape[1]

    # --- encoder (bidirectional) ---
    h = frames + L.sinusoidal_positions(Tenc, cfg.d_model)[None].astype(frames.dtype)
    h = logical(h, "act_batch", "act_seq", "act_d")
    enc_pos = jnp.arange(Tenc)[None, :]

    def enc_body(carry, p_l):
        y, _ = attn_block(cfg, p_l, carry, positions=enc_pos, attn_impl=attn_impl,
                          use_rope=False, causal=False)
        y = ffn_block(cfg, p_l, y)
        return y, None
    h, _ = _ctl_scan(_remat(enc_body, remat), h, params["enc_layers"])
    enc_out = L.layer_norm(h, params["enc_final_norm"], params["enc_final_norm_b"],
                           cfg.norm_eps)

    # --- decoder (causal self-attn + cross-attn) ---
    hd_ = embed_tokens(cfg, params, tokens)
    hd_ = hd_ + L.sinusoidal_positions(S, cfg.d_model)[None].astype(hd_.dtype)
    dec_pos = jnp.arange(S)[None, :]
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.num_heads

    def dec_body(carry, p_l):
        y, _ = attn_block(cfg, p_l, carry, positions=dec_pos, attn_impl=attn_impl,
                          use_rope=False, causal=True)
        # cross attention
        x = L.layer_norm(y, p_l["ln_x"], p_l["ln_x_b"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", x, p_l["wq_x"]).reshape(B, S, H, hd)
        xk = jnp.einsum("btd,dq->btq", enc_out, p_l["wk_x"]).reshape(B, Tenc, K, hd)
        xv = jnp.einsum("btd,dq->btq", enc_out, p_l["wv_x"]).reshape(B, Tenc, K, hd)
        o = L.attention(q, xk, xv, impl=attn_impl, causal=False)
        y = y + jnp.einsum("bsq,qd->bsd", o.reshape(B, S, -1), p_l["wo_x"])
        y = ffn_block(cfg, p_l, y)
        return y, None

    hd_, _ = _ctl_scan(_remat(dec_body, remat), hd_, params["layers"])
    return lm_head(cfg, params, hd_)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                 dtype=jnp.bfloat16) -> Dict[str, Tuple]:
    """Returns dict name -> (shape, dtype)."""
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    out: Dict[str, Tuple] = {"pos": ((), jnp.int32)}
    if cfg.family == "ssm":
        din, N, nh, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
        out["ssm"] = ((cfg.num_layers, batch, nh, cfg.ssm_head_dim, N), jnp.float32)
        out["conv"] = ((cfg.num_layers, batch, W - 1, din + 2 * N), dtype)
    elif cfg.family == "hybrid":
        n_tri, tail = _hybrid_counts(cfg)
        lw, W = cfg.lru_width or cfg.d_model, cfg.ssm_conv_width
        win = min(cfg.local_window or max_seq, max_seq)
        out["k"] = ((n_tri, batch, win, K, hd), dtype)
        out["v"] = ((n_tri, batch, win, K, hd), dtype)
        out["h1"] = ((n_tri, batch, lw), jnp.float32)
        out["h2"] = ((n_tri, batch, lw), jnp.float32)
        out["conv1"] = ((n_tri, batch, W - 1, lw), dtype)
        out["conv2"] = ((n_tri, batch, W - 1, lw), dtype)
        if tail:
            out["h_tail"] = ((tail, batch, lw), jnp.float32)
            out["conv_tail"] = ((tail, batch, W - 1, lw), dtype)
    elif cfg.family == "audio":
        out["k"] = ((cfg.num_layers, batch, max_seq, K, hd), dtype)
        out["v"] = ((cfg.num_layers, batch, max_seq, K, hd), dtype)
        out["xk"] = ((cfg.num_layers, batch, cfg.encoder_seq, K, hd), dtype)
        out["xv"] = ((cfg.num_layers, batch, cfg.encoder_seq, K, hd), dtype)
    else:
        lead = ((cfg.num_layers // 2, 2) if cfg.num_experts and cfg.moe_layer_freq == 2
                else (cfg.num_layers,))
        out["k"] = (lead + (batch, max_seq, K, hd), dtype)
        out["v"] = (lead + (batch, max_seq, K, hd), dtype)
    return out


def cache_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    ax: Dict[str, Tuple] = {"pos": ()}
    if cfg.family == "ssm":
        ax["ssm"] = ("layers", "cache_batch", None, "ssm_inner", None)
        ax["conv"] = ("layers", "cache_batch", None, "ssm_inner")
    elif cfg.family == "hybrid":
        ax["k"] = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
        ax["v"] = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
        for k in ("h1", "h2"):
            ax[k] = ("layers", "cache_batch", "lru")
        for k in ("conv1", "conv2"):
            ax[k] = ("layers", "cache_batch", None, "lru")
        if _hybrid_counts(cfg)[1]:
            ax["h_tail"] = ("layers", "cache_batch", "lru")
            ax["conv_tail"] = ("layers", "cache_batch", None, "lru")
    elif cfg.family == "audio":
        for k in ("k", "v", "xk", "xv"):
            ax[k] = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    else:
        pairs = cfg.num_experts and cfg.moe_layer_freq == 2
        for k in ("k", "v"):
            ax[k] = (("layers", None) if pairs else ("layers",)) + (
                "cache_batch", "cache_seq", "cache_kv_heads", None)
    return ax


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return {k: jnp.zeros(s, d) for k, (s, d) in
            cache_shapes(cfg, batch, max_seq, dtype).items()}


# ---------------------------------------------------------------------------
# Prefill — full prompt, returns last-token logits + populated cache
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            max_seq: int = 0, attn_impl: str = "auto",
            cache_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    positions = jnp.arange(S)[None, :]

    if cfg.family == "audio":
        return _prefill_audio(params, cfg, batch, max_seq=max_seq,
                              attn_impl=attn_impl, cache_dtype=cache_dtype)

    h = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        h = _merge_vision(cfg, h, batch["vision_embeds"])
    cache = init_cache(cfg, B, max_seq, cache_dtype)

    if cfg.family == "ssm":
        def body(carry, p_l):
            y, (ssm_s, conv_s) = ssm_block(cfg, p_l, carry)
            return y, (ssm_s, conv_s.astype(cache_dtype))
        h, (ssm_s, conv_s) = _ctl_scan(body, h, params["layers"])
        cache["ssm"], cache["conv"] = ssm_s, conv_s
    elif cfg.family == "hybrid":
        win = cache["k"].shape[2]

        def tri_body(carry, p_t):
            y, (h1, c1) = rglru_block(cfg, p_t["r1"], carry)
            y, (h2, c2) = rglru_block(cfg, p_t["r2"], y)
            y, (k, v) = attn_block(cfg, p_t["attn"], y, positions=positions,
                                   attn_impl=attn_impl, window=cfg.local_window)
            y = ffn_block(cfg, p_t["attn"], y)
            # keep only the trailing window in the ring cache (ring start = S % win)
            kw = _last_window(k, win).astype(cache_dtype)
            vw = _last_window(v, win).astype(cache_dtype)
            return y, (h1, h2, c1.astype(cache_dtype), c2.astype(cache_dtype), kw, vw)
        h, (h1, h2, c1, c2, kw, vw) = _ctl_scan(tri_body, h, params["blocks"])
        cache.update(h1=h1, h2=h2, conv1=c1, conv2=c2, k=kw, v=vw)
        if "tail" in params:
            def tail_body(carry, p_l):
                y, (hl, cl) = rglru_block(cfg, p_l, carry)
                return y, (hl, cl.astype(cache_dtype))
            h, (ht, ct) = _ctl_scan(tail_body, h, params["tail"])
            cache["h_tail"], cache["conv_tail"] = ht, ct
    elif "pairs" in params:
        def pair_body(carry, p_p):
            y = carry
            kvs = []
            for sub in ("dense", "moe"):
                y, (k, v) = attn_block(cfg, p_p[sub], y, positions=positions,
                                       attn_impl=attn_impl, window=cfg.local_window)
                y = ffn_block(cfg, p_p[sub], y)
                kvs.append((_pad_to(k, max_seq).astype(cache_dtype),
                            _pad_to(v, max_seq).astype(cache_dtype)))
            return y, (jnp.stack([kvs[0][0], kvs[1][0]]),
                       jnp.stack([kvs[0][1], kvs[1][1]]))
        h, (ks, vs) = _ctl_scan(pair_body, h, params["pairs"])
        cache["k"], cache["v"] = ks, vs
    else:
        def body(carry, p_l):
            y, (k, v) = attn_block(cfg, p_l, carry, positions=positions,
                                   attn_impl=attn_impl, window=cfg.local_window)
            y = ffn_block(cfg, p_l, y)
            kp = _pad_to(k, max_seq).astype(cache_dtype)
            vp = _pad_to(v, max_seq).astype(cache_dtype)
            return y, (kp, vp)
        h, (ks, vs) = _ctl_scan(body, h, params["layers"])
        cache["k"], cache["v"] = ks, vs

    cache["pos"] = jnp.asarray(S, jnp.int32)
    logits = lm_head(cfg, params, h[:, -1:, :])
    return logits[:, 0], cache


def _pad_to(k: jax.Array, max_seq: int) -> jax.Array:
    S = k.shape[1]
    if S == max_seq:
        return k
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, max_seq - S)
    return jnp.pad(k, pad)


def _last_window(k: jax.Array, win: int) -> jax.Array:
    """Trailing `win` positions arranged as a ring buffer with slot = pos % win."""
    S = k.shape[1]
    if S <= win:
        return _pad_to(k, win)
    tail = k[:, S - win:]                                  # abs positions S-win..S-1
    # slot of absolute position p is p % win; roll so tail[i] lands at slot
    shift = (S - win) % win
    return jnp.roll(tail, shift, axis=1)


def _prefill_audio(params, cfg, batch, *, max_seq, attn_impl, cache_dtype):
    """Whisper: 'prefill' = run the encoder + project cross K/V; decoder self-cache
    starts empty (generation starts from BOS tokens in batch['tokens'])."""
    frames = batch["frames"]
    B, Tenc = frames.shape[:2]
    h = frames + L.sinusoidal_positions(Tenc, cfg.d_model)[None].astype(frames.dtype)
    enc_pos = jnp.arange(Tenc)[None, :]

    def enc_body(carry, p_l):
        y, _ = attn_block(cfg, p_l, carry, positions=enc_pos, attn_impl=attn_impl,
                          use_rope=False, causal=False)
        y = ffn_block(cfg, p_l, y)
        return y, None
    h, _ = _ctl_scan(enc_body, h, params["enc_layers"])
    enc_out = L.layer_norm(h, params["enc_final_norm"], params["enc_final_norm_b"],
                           cfg.norm_eps)

    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache = init_cache(cfg, B, max_seq, cache_dtype)

    def cross_kv(carry, p_l):
        xk = jnp.einsum("btd,dq->btq", enc_out, p_l["wk_x"])
        xv = jnp.einsum("btd,dq->btq", enc_out, p_l["wv_x"])
        return carry, (xk.reshape(B, Tenc, K, hd).astype(cache_dtype),
                       xv.reshape(B, Tenc, K, hd).astype(cache_dtype))
    _, (xk, xv) = _ctl_scan(cross_kv, 0, params["layers"])
    cache["xk"], cache["xv"] = xk, xv
    cache["pos"] = jnp.asarray(0, jnp.int32)

    # first decoder token logits from BOS
    logits = jnp.zeros((B, cfg.vocab_size), frames.dtype)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode step — one new token against the cache
# ---------------------------------------------------------------------------


def _decode_attn_sublayer(cfg: ModelConfig, p_l: Params, y: jax.Array,
                          k_l, v_l, pos, *, attn_impl: str,
                          xk_l=None, xv_l=None):
    """One decode attention layer (self-attn + optional cross-attn + FFN).
    Returns (y, k_l, v_l) with the cache slice updated at `pos`."""
    B = y.shape[0]
    K, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    if cfg.family == "audio":
        x = L.layer_norm(y, p_l["ln1"], p_l["ln1_b"], cfg.norm_eps)
    else:
        x = L.rms_norm(y, p_l["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p_l, x)
    if cfg.family != "audio":
        rp = pos[None, None] + jnp.zeros((1, 1), jnp.int32)
        q = L.apply_rope(q, rp, cfg.rope_theta)
        k = L.apply_rope(k, rp, cfg.rope_theta)
    k_l = lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype), pos, axis=1)
    v_l = lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype), pos, axis=1)
    k_l = logical(k_l, "cache_batch", "cache_seq", "cache_kv_heads", None)
    v_l = logical(v_l, "cache_batch", "cache_seq", "cache_kv_heads", None)
    o = L.attention(q, k_l, v_l, impl=attn_impl, causal=True, q_offset=pos)
    y = y + jnp.einsum("bsq,qd->bsd", o.reshape(B, 1, -1), p_l["wo"])
    if xk_l is not None:
        x = L.layer_norm(y, p_l["ln_x"], p_l["ln_x_b"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dq->bsq", x, p_l["wq_x"]).reshape(B, 1, H, hd)
        ox = L.attention(qx, xk_l, xv_l, impl=attn_impl, causal=False)
        y = y + jnp.einsum("bsq,qd->bsd", ox.reshape(B, 1, -1), p_l["wo_x"])
    y = ffn_block(cfg, p_l, y)
    return y, k_l, v_l


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, *, attn_impl: str = "naive") -> Tuple[jax.Array, Dict]:
    """tokens: (B,) int32 — the token generated at position cache['pos'].
    Returns (logits (B, V), updated cache)."""
    pos = cache["pos"]
    h = embed_tokens(cfg, params, tokens[:, None])         # (B, 1, D)

    if cfg.family == "ssm":
        return _decode_ssm(params, cfg, h, cache)
    if cfg.family == "hybrid":
        return _decode_hybrid(params, cfg, h, cache, attn_impl=attn_impl)
    if cfg.family == "audio":
        h = h + L.sinusoidal_positions(1, cfg.d_model)[None].astype(h.dtype)

    if "pairs" in params:
        def body(carry, xs):
            y = carry
            p_p, k_l, v_l = xs                             # k_l: (2, B, S, K, hd)
            y, k0, v0 = _decode_attn_sublayer(cfg, p_p["dense"], y, k_l[0], v_l[0],
                                              pos, attn_impl=attn_impl)
            y, k1, v1 = _decode_attn_sublayer(cfg, p_p["moe"], y, k_l[1], v_l[1],
                                              pos, attn_impl=attn_impl)
            return y, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        h, (k_new, v_new) = _ctl_scan(body, h, (params["pairs"], cache["k"], cache["v"]))
    elif cfg.family == "audio":
        def body(carry, xs):
            p_l, k_l, v_l, xk_l, xv_l = xs
            y, k_l, v_l = _decode_attn_sublayer(cfg, p_l, carry, k_l, v_l, pos,
                                                attn_impl=attn_impl,
                                                xk_l=xk_l, xv_l=xv_l)
            return y, (k_l, v_l)
        h, (k_new, v_new) = _ctl_scan(
            body, h, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
    else:
        def body(carry, xs):
            p_l, k_l, v_l = xs
            y, k_l, v_l = _decode_attn_sublayer(cfg, p_l, carry, k_l, v_l, pos,
                                                attn_impl=attn_impl)
            return y, (k_l, v_l)
        h, (k_new, v_new) = _ctl_scan(body, h, (params["layers"], cache["k"], cache["v"]))

    cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    logits = lm_head(cfg, params, h)
    return logits[:, 0], cache


def supports_ragged_decode(cfg: ModelConfig) -> bool:
    """Families whose decode cache is a dense per-layer K/V stack with a
    single position pointer — the shapes the batched paged decode runtime
    (`decode_step_ragged` + PagedKVCache) handles. Recurrent-state families
    (ssm/hybrid), encoder-decoder audio, and the interleaved MoE pair layout
    stay on the single-stream `decode_step` path."""
    if cfg.family in ("ssm", "hybrid", "audio"):
        return False
    if cfg.num_experts and cfg.moe_layer_freq == 2:
        return False
    return True


def _ragged_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             kv_lens: jax.Array, attn_impl: str) -> jax.Array:
    """q: (B, H, hd); k/v: (B, T, K, hd); kv_lens: (B,) valid key counts.
    Row b attends to keys [0, kv_lens[b]) of its own KV view."""
    if attn_impl in ("pallas", "pallas_interpret"):
        # runtime import: kernels.ops imports models.layers; importing it at
        # module scope from here would tie the model to the kernel package
        from repro.kernels.ops import decode_attention
        return decode_attention(q, k, v, kv_lens, impl=attn_impl)
    out = L.naive_attention(q[:, None], k, v, causal=False, kv_len=kv_lens)
    return out[:, 0]


def decode_step_ragged(params: Params, cfg: ModelConfig, tokens: jax.Array,
                       k_gathered: jax.Array, v_gathered: jax.Array,
                       kv_lens: jax.Array, *, attn_impl: str = "naive"):
    """One continuous-batching decode step over B resident streams.

    tokens: (B,) int32 — each stream's current token; k_gathered/v_gathered:
    (L, B, T, K, hd) dense per-stream KV views (PagedKVCache.gather_batch),
    padded to a common T; kv_lens: (B,) int32 — stream b's context length,
    which is also the position its new K/V belongs at (padding slots carry
    kv_len 0 and their outputs are discarded by the caller).

    Returns (logits (B, V), k_new (L, B, K, hd), v_new (L, B, K, hd)): the
    new per-layer K/V are handed back for the caller to scatter into the
    paged pool (PagedKVCache.write_tokens) — the whole step is ONE jitted
    program per (B, T) shape bucket, one batched cache write per token,
    instead of per-stream O(pool) functional updates.
    """
    if not supports_ragged_decode(cfg):
        raise NotImplementedError(
            f"batched ragged decode unsupported for family={cfg.family!r} "
            f"(moe_layer_freq={cfg.moe_layer_freq}); use decode_step")
    B = tokens.shape[0]
    pos = kv_lens.astype(jnp.int32)
    rows = jnp.arange(B)
    h = embed_tokens(cfg, params, tokens[:, None])          # (B, 1, D)

    def body(carry, xs):
        p_l, k_l, v_l = xs                                  # k_l: (B,T,K,hd)
        y = carry
        x = L.rms_norm(y, p_l["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, p_l, x)                 # (B, 1, ·, hd)
        rp = pos[:, None]                                   # (B, 1) positions
        q = L.apply_rope(q, rp, cfg.rope_theta)
        k = L.apply_rope(k, rp, cfg.rope_theta)
        # batched scatter of the new token into the gathered views so
        # attention sees prefix + self; the pool write happens in the caller
        k_full = k_l.at[rows, pos].set(k[:, 0].astype(k_l.dtype))
        v_full = v_l.at[rows, pos].set(v[:, 0].astype(v_l.dtype))
        o = _ragged_decode_attention(q[:, 0], k_full, v_full, pos + 1,
                                     attn_impl)             # (B, H, hd)
        y = y + jnp.einsum("bsq,qd->bsd", o.reshape(B, 1, -1), p_l["wo"])
        y = ffn_block(cfg, p_l, y)
        return y, (k[:, 0], v[:, 0])

    h, (k_new, v_new) = _ctl_scan(
        body, h, (params["layers"], k_gathered, v_gathered))
    logits = lm_head(cfg, params, h)
    return logits[:, 0], k_new, v_new


def _ragged_verify_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             kv_lens: jax.Array, attn_impl: str) -> jax.Array:
    """q: (B, S, H, hd) — S consecutive query positions starting at
    kv_lens[b] per row; k/v: (B, T, K, hd) with the S draft K/V already
    scattered in. Per-row causal masking: query s of row b attends keys
    [0, kv_lens[b] + s]."""
    if attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import verify_attention
        return verify_attention(q, k, v, kv_lens, impl=attn_impl)
    return L.naive_attention(q, k, v, causal=True, q_offset=kv_lens)


def decode_verify_ragged(params: Params, cfg: ModelConfig, tokens: jax.Array,
                         k_gathered: jax.Array, v_gathered: jax.Array,
                         kv_lens: jax.Array, *, attn_impl: str = "naive"):
    """Speculative-verify sibling of `decode_step_ragged`: score S = k + 1
    consecutive positions per stream in ONE jitted step.

    tokens: (B, S) int32 — row b holds [current token, draft_0 .. draft_{k-1}]
    (short drafts padded arbitrarily; padded columns simply produce logits the
    caller never accepts). kv_lens: (B,) — the committed context length of
    row b, i.e. the position tokens[b, 0] is written at. Returns
    (logits (B, S, V), k_new (L, B, S, K, hd), v_new (L, B, S, K, hd)).

    Greedy acceptance contract: because column s attends exactly the keys a
    plain step at position kv_lens[b] + s would see (committed prefix + the
    s earlier draft keys, masked identically), logits[:, s] is bit-equal to
    what `decode_step_ragged` would produce after committing those s tokens
    — so accepting the longest draft prefix matching argmax(logits) yields
    output bit-identical to plain greedy decoding.
    """
    if not supports_ragged_decode(cfg):
        raise NotImplementedError(
            f"speculative verify unsupported for family={cfg.family!r} "
            f"(moe_layer_freq={cfg.moe_layer_freq}); use decode_step")
    B, S = tokens.shape
    pos = kv_lens.astype(jnp.int32)
    rows = jnp.arange(B)
    h = embed_tokens(cfg, params, tokens)                   # (B, S, D)

    def body(carry, xs):
        p_l, k_l, v_l = xs                                  # k_l: (B,T,K,hd)
        y = carry
        x = L.rms_norm(y, p_l["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, p_l, x)                 # (B, S, ·, hd)
        rp = pos[:, None] + jnp.arange(S)[None, :]          # (B, S) positions
        q = L.apply_rope(q, rp, cfg.rope_theta)
        k = L.apply_rope(k, rp, cfg.rope_theta)
        # scatter the whole draft span into the gathered views; the causal
        # per-row mask in the attention below keeps column s blind to the
        # later draft keys, so rejected positions never leak into accepted
        # logits. The pool write (and the commit/rollback decision) happens
        # in the caller.
        k_full = k_l.at[rows[:, None], rp].set(k.astype(k_l.dtype))
        v_full = v_l.at[rows[:, None], rp].set(v.astype(v_l.dtype))
        o = _ragged_verify_attention(q, k_full, v_full, pos, attn_impl)
        y = y + jnp.einsum("bsq,qd->bsd", o.reshape(B, S, -1), p_l["wo"])
        y = ffn_block(cfg, p_l, y)
        return y, (k, v)

    h, (k_new, v_new) = _ctl_scan(
        body, h, (params["layers"], k_gathered, v_gathered))
    logits = lm_head(cfg, params, h)
    return logits, k_new, v_new


def _decode_ssm(params, cfg, h, cache):
    B = h.shape[0]
    din, N, nh, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    P_ = cfg.ssm_head_dim

    def body(carry, xs):
        y = carry
        p_l, ssm_s, conv_s = xs
        x = L.rms_norm(y, p_l["ln"], cfg.norm_eps)
        proj = jnp.einsum("bsd,dp->bsp", x, p_l["in_proj"])
        z, xBC, dt = jnp.split(proj, [din, 2 * din + 2 * N], axis=-1)
        # rolling conv state: append, convolve last position
        window = jnp.concatenate([conv_s.astype(xBC.dtype), xBC], axis=1)  # (B,W,C)
        conv_out = jnp.einsum("bwc,cw->bc", window, p_l["conv_w"]) + p_l["conv_b"]
        conv_out = jax.nn.silu(conv_out)
        xs_, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)
        dt_sp = jax.nn.softplus(dt[:, 0] + p_l["dt_bias"])
        A = -jnp.exp(p_l["A_log"].astype(jnp.float32))
        yv, ssm_s = L.ssd_step(xs_.reshape(B, nh, P_), dt_sp, A, Bm, Cm, ssm_s)
        yv = yv + xs_.reshape(B, nh, P_) * p_l["Dp"][None, :, None]
        yv = yv.reshape(B, 1, din)
        yv = L.rms_norm(yv * jax.nn.silu(z), p_l["norm_w"], cfg.norm_eps)
        y = y + jnp.einsum("bsc,cd->bsd", yv, p_l["out_proj"])
        return y, (ssm_s, window[:, 1:].astype(conv_s.dtype))

    h, (ssm_new, conv_new) = _ctl_scan(
        body, h, (params["layers"], cache["ssm"], cache["conv"]))
    cache = dict(cache, ssm=ssm_new, conv=conv_new, pos=cache["pos"] + 1)
    logits = lm_head(cfg, params, h)
    return logits[:, 0], cache


def _decode_hybrid(params, cfg, h, cache, *, attn_impl="naive"):
    B = h.shape[0]
    pos = cache["pos"]
    win = cache["k"].shape[2]

    def rglru_step_block(p_l, y, h_s, conv_s):
        x = L.rms_norm(y, p_l["ln1"], cfg.norm_eps)
        gate = jax.nn.gelu(jnp.einsum("bsd,dc->bsc", x, p_l["w_gate"]))
        xb = jnp.einsum("bsd,dc->bsc", x, p_l["w_x"])
        window = jnp.concatenate([conv_s.astype(xb.dtype), xb], axis=1)
        conv_out = (jnp.einsum("bwc,cw->bc", window, p_l["conv_w"]) + p_l["conv_b"])
        yv, h_s = L.rglru_step(conv_out, p_l["a_param"], p_l["w_rg"], p_l["w_ig"], h_s)
        y = y + jnp.einsum("bsc,cd->bsd", yv[:, None] * gate, p_l["w_y"])
        y = ffn_block(cfg, p_l, y)
        return y, h_s, window[:, 1:].astype(conv_s.dtype)

    def tri_body(carry, xs):
        y = carry
        p_t, k_l, v_l, h1, h2, c1, c2 = xs
        y, h1, c1 = rglru_step_block(p_t["r1"], y, h1, c1)
        y, h2, c2 = rglru_step_block(p_t["r2"], y, h2, c2)
        # local attention over ring buffer
        p_l = p_t["attn"]
        x = L.rms_norm(y, p_l["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, p_l, x)
        rp = pos[None, None] + jnp.zeros((1, 1), jnp.int32)
        q = L.apply_rope(q, rp, cfg.rope_theta)
        k = L.apply_rope(k, rp, cfg.rope_theta)
        slot = pos % win
        k_l = lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype), slot, axis=1)
        v_l = lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype), slot, axis=1)
        # absolute position of each ring slot j: pos - ((pos - j) mod win)
        j = jnp.arange(win)
        k_pos = pos - ((pos - j) % win)
        o = L.naive_attention(q, k_l, v_l, causal=True, q_offset=pos,
                              local_window=cfg.local_window, k_positions=k_pos)
        y = y + jnp.einsum("bsq,qd->bsd", o.reshape(B, 1, -1), p_l["wo"])
        y = ffn_block(cfg, p_l, y)
        return y, (k_l, v_l, h1, h2, c1, c2)

    xs = (params["blocks"], cache["k"], cache["v"], cache["h1"], cache["h2"],
          cache["conv1"], cache["conv2"])
    h, (k_n, v_n, h1_n, h2_n, c1_n, c2_n) = _ctl_scan(tri_body, h, xs)
    cache = dict(cache, k=k_n, v=v_n, h1=h1_n, h2=h2_n, conv1=c1_n, conv2=c2_n)
    if "tail" in params:
        def tail_body(carry, xs_):
            y = carry
            p_l, h_s, c_s = xs_
            y, h_s, c_s = rglru_step_block(p_l, y, h_s, c_s)
            return y, (h_s, c_s)
        h, (ht_n, ct_n) = _ctl_scan(
            tail_body, h, (params["tail"], cache["h_tail"], cache["conv_tail"]))
        cache = dict(cache, h_tail=ht_n, conv_tail=ct_n)
    cache = dict(cache, pos=pos + 1)
    logits = lm_head(cfg, params, h)
    return logits[:, 0], cache
