"""Scan control for cost-accounting.

XLA's cost_analysis counts a while-loop body ONCE, not x trip-count, so any
lax.scan (layer stacks, blocked-attention KV loops) is undercounted. The
dry-run's shallow cost probes flip `set_unroll(True)` so every scan fully
unrolls and FLOPs/bytes/collectives are counted exactly; production lowering
keeps rolled scans (compact HLO, fast compile).
"""
from __future__ import annotations

from contextlib import contextmanager

from jax import lax

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def unrolling() -> bool:
    return _UNROLL


@contextmanager
def unrolled_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan(body, carry, xs, **kw):
    if _UNROLL:
        kw["unroll"] = True
    return lax.scan(body, carry, xs, **kw)
