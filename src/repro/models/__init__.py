from repro.models.model import (
    cache_axes,
    cache_shapes,
    decode_step,
    forward,
    init_cache,
    init_params,
    model_shapes,
    param_axes,
    param_count,
    prefill,
)
