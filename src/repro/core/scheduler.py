"""Event-driven scheduling core (FlowPrefill §5.2) — pure policy logic.

This module is deliberately free of threads and devices: the same functions
drive BOTH the real serving runtime (repro/serving/prefill_instance.py,
repro/serving/decode_instance.py) and the discrete-event simulator (repro/sim/)
so the evaluated policy is the deployed policy.

Prefill side (paper-faithful):
  * S-EDF priority (Eq. 3):  priority = sgn(slack) / deadline,
    slack = deadline - now - TTFT_hat
  * SLO-aware batching (Algorithm 1)
  * The per-event scheduling round of Algorithm 2 (returns control commands;
    the Execution Pool carries them out)
  * Ablation policies (Fig. 10): naive EDF and D-EDF; plus FCFS for the
    DistServe baseline.

Decode side (the paper's core idea — decoupling preemption granularity from
scheduling frequency — generalized to the second serving phase):
  * `DecodeSchedulerCore` ranks decode candidates by TBT-deadline slack
    (`decode_sedf_priority`: slack = decode_deadline - now - remaining_tokens
    * t_step_hat) and selects the continuous batch under a slot cap, optionally
    displacing slack-rich residents at token boundaries (decode preemption).
  * FCFS admission is kept as the baseline (and is what the paper's
    deliberately-plain decode stage does).

Policy-by-policy rationale and the figures that demonstrate each live in
docs/SCHEDULING.md.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import (Callable, List, Optional, Sequence, Set, Tuple)

import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.core.request import Request

PriorityFn = Callable[[Request, float, Callable[[float], float]], float]


# ---------------------------------------------------------------------------
# Priority policies
# ---------------------------------------------------------------------------


def _sgn(x: float) -> float:
    return 1.0 if x >= 0.0 else -1.0


def sedf_priority(req: Request, now: float, predict) -> float:
    """Slack-aware EDF (the paper's policy, Eq. 3)."""
    slack = req.deadline - now - predict(req.remaining_tokens())
    return _sgn(slack) / max(req.deadline, 1e-9)


def dedf_priority(req: Request, now: float, predict) -> float:
    """Deadline-aware EDF ablation: numerator sgn(deadline - now)."""
    return _sgn(req.deadline - now) / max(req.deadline, 1e-9)


def edf_priority(req: Request, now: float, predict) -> float:
    """Naive EDF: earliest deadline first, no feasibility awareness."""
    return 1.0 / max(req.deadline, 1e-9)


def fcfs_priority(req: Request, now: float, predict) -> float:
    return -req.arrival


POLICIES = {
    "s-edf": sedf_priority,
    "d-edf": dedf_priority,
    "edf": edf_priority,
    "fcfs": fcfs_priority,
}


# ---------------------------------------------------------------------------
# SLO-aware batching — Algorithm 1
# ---------------------------------------------------------------------------


def slo_aware_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
    now: float,
    predict: Callable[[float], float],
) -> Tuple[Request, List[Request]]:
    """Paper Algorithm 1. Returns (H with updated aggregate tokens, batch list
    including H). Candidates are admitted while H's remaining time covers the
    predicted latency of the aggregate batch and the token budget holds."""
    batch = [H]
    t_remain = H.deadline - now
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        n_new = n + r.num_tokens
        latency = predict(n_new)
        if t_remain > latency and n_new < budget:
            batch.append(r)
            n = n_new
    H.batch_tokens = n
    return H, batch


def greedy_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
) -> Tuple[Request, List[Request]]:
    """Token-budget-only batching (vLLM/Sarathi continuous-batching semantics,
    used by the DistServe-CP baselines): pack while under budget, no deadline
    feasibility check."""
    batch = [H]
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        if n + r.num_tokens < budget:
            batch.append(r)
            n += r.num_tokens
    H.batch_tokens = n
    return H, batch


# ---------------------------------------------------------------------------
# Scheduling round — Algorithm 2 (one event = one round)
# ---------------------------------------------------------------------------


class Action(enum.Enum):
    NOOP = "noop"
    SUBMIT = "submit"          # new batch starts (H was waiting)
    RESUME = "resume"          # H was preempted
    # preemption of the running task is orthogonal and recorded separately


@dataclass
class Decision:
    action: Action
    batch: List[Request] = field(default_factory=list)    # for SUBMIT
    target: Optional[Request] = None                      # H (SUBMIT/RESUME)
    preempt: Optional[Request] = None                     # E to suspend first

    @property
    def is_noop(self) -> bool:
        return self.action == Action.NOOP and self.preempt is None


@dataclass
class SchedulerCore:
    """State-free policy engine. The runtime owns the queues and passes views."""
    predictor: TTFTPredictor
    policy: str = "s-edf"
    batch_budget: int = 4096              # G, tokens (Fig. 11 sweeps this)
    enable_batching: bool = True
    batching_mode: str = "slo"            # "slo" (Alg. 1) | "greedy" (baselines)
    batch_running: bool = False           # paper Alg.2 line 14 admits E into C;
                                          # default off: re-batching the running
                                          # task would discard its progress

    def priority(self, req: Request, now: float) -> float:
        return POLICIES[self.policy](req, now, self.predictor.predict)

    def _priorities_vec(self, requests: Sequence[Request],
                        now: float) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(priorities, deadlines) arrays, elementwise bit-identical to
        `priority` — the per-round ranking is THE scheduler hot path (every
        event re-ranks the whole queue), so the per-request predict/property
        calls are batched. Returns None for policies without a batched form
        or predictors without `predict_many`."""
        n = len(requests)
        dl = np.fromiter((r.arrival + r.slo for r in requests),
                         np.float64, n)
        if self.policy == "s-edf":
            if not hasattr(self.predictor, "predict_many"):
                return None
            rem = np.fromiter((r.remaining_tokens() for r in requests),
                              np.float64, n)
            slack = dl - now - self.predictor.predict_many(rem)
            pri = np.where(slack >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "d-edf":
            pri = np.where(dl - now >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "edf":
            pri = 1.0 / np.maximum(dl, 1e-9)
        elif self.policy == "fcfs":
            pri = -np.fromiter((r.arrival for r in requests), np.float64, n)
        else:
            return None
        return pri, dl

    def rank(self, requests: Sequence[Request], now: float) -> List[Request]:
        """Descending priority; deterministic tie-break (deadline, rid)."""
        if len(requests) <= 1:
            return list(requests)
        vec = self._priorities_vec(requests, now)
        if vec is None:
            return sorted(requests, key=lambda r: (-self.priority(r, now),
                                                   r.deadline, r.rid))
        pri, dl = vec
        rid = np.fromiter((r.rid for r in requests), np.int64, len(requests))
        # lexsort keys are applied last-first: (-pri, deadline, rid) — rid is
        # unique, so the order matches the scalar tuple sort exactly
        order = np.lexsort((rid, dl, -pri))
        return [requests[i] for i in order]

    def schedule_round(
        self,
        now: float,
        waiting: Sequence[Request],
        preempted: Sequence[Request],
        running: Optional[Request],
    ) -> Decision:
        """One event-triggered round of Algorithm 2 (lines 7–26)."""
        q_all: List[Request] = list(waiting) + list(preempted)
        if running is not None:
            q_all.append(running)
        if not q_all:
            return Decision(Action.NOOP)

        ranked = self.rank(q_all, now)
        H = ranked[0]

        batch = [H]
        waiting_ids = {r.rid for r in waiting}
        if H.rid in waiting_ids and self.enable_batching:
            cands = [r for r in ranked
                     if r.rid != H.rid and r.rid in waiting_ids]
            if self.batch_running and running is not None:
                cands.append(running)
            if self.batching_mode == "greedy":
                H, batch = greedy_batching(H, cands, self.batch_budget)
            else:
                H, batch = slo_aware_batching(
                    H, cands, self.batch_budget, now, self.predictor.predict)

        if running is not None and H.rid == running.rid:
            return Decision(Action.NOOP)                   # already optimal

        preempt = running                                  # may be None
        if H.rid in waiting_ids:
            return Decision(Action.SUBMIT, batch=batch, target=H,
                            preempt=preempt)
        return Decision(Action.RESUME, target=H, preempt=preempt)


# ---------------------------------------------------------------------------
# Decode-side scheduling: TBT-slack-aware batch admission (S-EDF for decode)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodeEntry:
    """One decode candidate as the decode scheduler sees it — owner-agnostic,
    so the SAME ranking drives the fluid `DecodeSim` and the threaded
    `DecodeInstance` (the repo's evaluated-is-deployed rule)."""
    key: int                       # owner handle (request rid)
    remaining_tokens: float        # output tokens still to decode
    deadline: float                # Request.decode_deadline (inf = no TBT SLO)
    order: int                     # admission order (FCFS / deterministic tie)


def decode_sedf_priority(entry: DecodeEntry, now: float,
                         t_step: float) -> float:
    """S-EDF ported to decode (the paper's Eq. 3 with TBT semantics):

        slack    = decode_deadline - now - remaining_tokens * t_step_hat
        priority = sgn(slack) / decode_deadline

    `t_step_hat` is the predicted per-token step time of the batch the entry
    would decode in (DecodeCostModel.step_time via a DecodeStepPredictor).
    Feasible-but-urgent decodes rank first; already-doomed ones (negative
    slack) rank below every feasible candidate, exactly like prefill S-EDF —
    a doomed stream must not displace one that can still meet its TBT SLO.
    Requests without a TBT SLO have an infinite deadline: priority 0, between
    the feasible (positive) and the doomed (negative)."""
    if not math.isfinite(entry.deadline):
        return 0.0
    slack = entry.deadline - now - entry.remaining_tokens * t_step
    return _sgn(slack) / max(entry.deadline, 1e-9)


@dataclass
class DecodeSchedulerCore:
    """Batch-admission policy for one decode instance.

    A decode instance runs a continuous batch of at most `max_batch` streams
    (KV-memory slot cap; <= 0 means unbounded, which degenerates to the
    paper's plain processor-sharing decode). On every join/leave event the
    owner calls `select_batch` with ALL candidates (current residents plus
    queued decodes); the returned batch is the new resident set.

    * ``fcfs``  — admission in arrival order; residents are never displaced
      (an earlier order always outranks a later one).
    * ``s-edf`` — candidates ranked by `decode_sedf_priority`; with
      ``preempt`` (the default) the top-`max_batch` BY PRIORITY become the
      batch, so a near-deadline queued decode displaces a slack-rich resident
      — the decode analogue of operator-level preemption, effective at the
      next token boundary. With ``preempt=False`` residents keep their slots
      and only free slots are filled by rank (admission-only S-EDF).
    """
    policy: str = "s-edf"              # "s-edf" | "fcfs"
    preempt: bool = True

    def priority(self, entry: DecodeEntry, now: float, t_step: float) -> float:
        if self.policy == "fcfs":
            return -float(entry.order)
        return decode_sedf_priority(entry, now, t_step)

    def rank(self, entries: Sequence[DecodeEntry], now: float,
             t_step: float) -> List[DecodeEntry]:
        """Descending priority; deterministic tie-break (deadline, order)."""
        if self.policy == "fcfs":
            return sorted(entries, key=lambda e: e.order)
        return sorted(entries,
                      key=lambda e: (-decode_sedf_priority(e, now, t_step),
                                     e.deadline, e.order))

    def select_batch(self, entries: Sequence[DecodeEntry],
                     resident: Set[int], max_batch: int, now: float,
                     t_step: float) -> Tuple[List[int], List[int]]:
        """Pick the new resident batch from `entries` (residents + queued).

        Returns ``(batch_keys, preempted_keys)``: the keys to run (in rank
        order) and the previously-resident keys displaced by the decision.
        ``max_batch <= 0`` = unbounded: everything is admitted, nothing is
        ever preempted (the plain processor-sharing decode)."""
        ranked = self.rank(entries, now, t_step)
        if max_batch <= 0 or len(entries) <= max_batch:
            return [e.key for e in ranked], []
        if self.preempt:
            batch = [e.key for e in ranked[:max_batch]]
        else:
            keep = [e for e in ranked if e.key in resident]
            free = max_batch - len(keep)
            fill = [e for e in ranked if e.key not in resident][:max(free, 0)]
            batch = [e.key for e in self.rank(keep + fill, now, t_step)]
        chosen = set(batch)
        preempted = [e.key for e in ranked
                     if e.key in resident and e.key not in chosen]
        return batch, preempted
