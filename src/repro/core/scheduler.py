"""Event-driven scheduling core (FlowPrefill §5.2) — pure policy logic.

This module is deliberately free of threads and devices: the same functions
drive BOTH the real serving runtime (repro/serving/prefill_instance.py) and the
discrete-event simulator (repro/sim/) so the evaluated policy is the deployed
policy.

Implements, paper-faithfully:
  * S-EDF priority (Eq. 3):  priority = sgn(slack) / deadline,
    slack = deadline - now - TTFT_hat
  * SLO-aware batching (Algorithm 1)
  * The per-event scheduling round of Algorithm 2 (returns control commands;
    the Execution Pool carries them out)
Ablation policies (Fig. 10): naive EDF and D-EDF; plus FCFS for the DistServe
baseline.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.core.request import Request

PriorityFn = Callable[[Request, float, Callable[[float], float]], float]


# ---------------------------------------------------------------------------
# Priority policies
# ---------------------------------------------------------------------------


def _sgn(x: float) -> float:
    return 1.0 if x >= 0.0 else -1.0


def sedf_priority(req: Request, now: float, predict) -> float:
    """Slack-aware EDF (the paper's policy, Eq. 3)."""
    slack = req.deadline - now - predict(req.remaining_tokens())
    return _sgn(slack) / max(req.deadline, 1e-9)


def dedf_priority(req: Request, now: float, predict) -> float:
    """Deadline-aware EDF ablation: numerator sgn(deadline - now)."""
    return _sgn(req.deadline - now) / max(req.deadline, 1e-9)


def edf_priority(req: Request, now: float, predict) -> float:
    """Naive EDF: earliest deadline first, no feasibility awareness."""
    return 1.0 / max(req.deadline, 1e-9)


def fcfs_priority(req: Request, now: float, predict) -> float:
    return -req.arrival


POLICIES = {
    "s-edf": sedf_priority,
    "d-edf": dedf_priority,
    "edf": edf_priority,
    "fcfs": fcfs_priority,
}


# ---------------------------------------------------------------------------
# SLO-aware batching — Algorithm 1
# ---------------------------------------------------------------------------


def slo_aware_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
    now: float,
    predict: Callable[[float], float],
) -> Tuple[Request, List[Request]]:
    """Paper Algorithm 1. Returns (H with updated aggregate tokens, batch list
    including H). Candidates are admitted while H's remaining time covers the
    predicted latency of the aggregate batch and the token budget holds."""
    batch = [H]
    t_remain = H.deadline - now
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        n_new = n + r.num_tokens
        latency = predict(n_new)
        if t_remain > latency and n_new < budget:
            batch.append(r)
            n = n_new
    H.batch_tokens = n
    return H, batch


def greedy_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
) -> Tuple[Request, List[Request]]:
    """Token-budget-only batching (vLLM/Sarathi continuous-batching semantics,
    used by the DistServe-CP baselines): pack while under budget, no deadline
    feasibility check."""
    batch = [H]
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        if n + r.num_tokens < budget:
            batch.append(r)
            n += r.num_tokens
    H.batch_tokens = n
    return H, batch


# ---------------------------------------------------------------------------
# Scheduling round — Algorithm 2 (one event = one round)
# ---------------------------------------------------------------------------


class Action(enum.Enum):
    NOOP = "noop"
    SUBMIT = "submit"          # new batch starts (H was waiting)
    RESUME = "resume"          # H was preempted
    # preemption of the running task is orthogonal and recorded separately


@dataclass
class Decision:
    action: Action
    batch: List[Request] = field(default_factory=list)    # for SUBMIT
    target: Optional[Request] = None                      # H (SUBMIT/RESUME)
    preempt: Optional[Request] = None                     # E to suspend first

    @property
    def is_noop(self) -> bool:
        return self.action == Action.NOOP and self.preempt is None


@dataclass
class SchedulerCore:
    """State-free policy engine. The runtime owns the queues and passes views."""
    predictor: TTFTPredictor
    policy: str = "s-edf"
    batch_budget: int = 4096              # G, tokens (Fig. 11 sweeps this)
    enable_batching: bool = True
    batching_mode: str = "slo"            # "slo" (Alg. 1) | "greedy" (baselines)
    batch_running: bool = False           # paper Alg.2 line 14 admits E into C;
                                          # default off: re-batching the running
                                          # task would discard its progress

    def priority(self, req: Request, now: float) -> float:
        return POLICIES[self.policy](req, now, self.predictor.predict)

    def _priorities_vec(self, requests: Sequence[Request],
                        now: float) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(priorities, deadlines) arrays, elementwise bit-identical to
        `priority` — the per-round ranking is THE scheduler hot path (every
        event re-ranks the whole queue), so the per-request predict/property
        calls are batched. Returns None for policies without a batched form
        or predictors without `predict_many`."""
        n = len(requests)
        dl = np.fromiter((r.arrival + r.slo for r in requests),
                         np.float64, n)
        if self.policy == "s-edf":
            if not hasattr(self.predictor, "predict_many"):
                return None
            rem = np.fromiter((r.remaining_tokens() for r in requests),
                              np.float64, n)
            slack = dl - now - self.predictor.predict_many(rem)
            pri = np.where(slack >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "d-edf":
            pri = np.where(dl - now >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "edf":
            pri = 1.0 / np.maximum(dl, 1e-9)
        elif self.policy == "fcfs":
            pri = -np.fromiter((r.arrival for r in requests), np.float64, n)
        else:
            return None
        return pri, dl

    def rank(self, requests: Sequence[Request], now: float) -> List[Request]:
        """Descending priority; deterministic tie-break (deadline, rid)."""
        if len(requests) <= 1:
            return list(requests)
        vec = self._priorities_vec(requests, now)
        if vec is None:
            return sorted(requests, key=lambda r: (-self.priority(r, now),
                                                   r.deadline, r.rid))
        pri, dl = vec
        rid = np.fromiter((r.rid for r in requests), np.int64, len(requests))
        # lexsort keys are applied last-first: (-pri, deadline, rid) — rid is
        # unique, so the order matches the scalar tuple sort exactly
        order = np.lexsort((rid, dl, -pri))
        return [requests[i] for i in order]

    def schedule_round(
        self,
        now: float,
        waiting: Sequence[Request],
        preempted: Sequence[Request],
        running: Optional[Request],
    ) -> Decision:
        """One event-triggered round of Algorithm 2 (lines 7–26)."""
        q_all: List[Request] = list(waiting) + list(preempted)
        if running is not None:
            q_all.append(running)
        if not q_all:
            return Decision(Action.NOOP)

        ranked = self.rank(q_all, now)
        H = ranked[0]

        batch = [H]
        waiting_ids = {r.rid for r in waiting}
        if H.rid in waiting_ids and self.enable_batching:
            cands = [r for r in ranked
                     if r.rid != H.rid and r.rid in waiting_ids]
            if self.batch_running and running is not None:
                cands.append(running)
            if self.batching_mode == "greedy":
                H, batch = greedy_batching(H, cands, self.batch_budget)
            else:
                H, batch = slo_aware_batching(
                    H, cands, self.batch_budget, now, self.predictor.predict)

        if running is not None and H.rid == running.rid:
            return Decision(Action.NOOP)                   # already optimal

        preempt = running                                  # may be None
        if H.rid in waiting_ids:
            return Decision(Action.SUBMIT, batch=batch, target=H,
                            preempt=preempt)
        return Decision(Action.RESUME, target=H, preempt=preempt)
