"""Event-driven scheduling core (FlowPrefill §5.2) — pure policy logic.

This module is deliberately free of threads and devices: the same functions
drive BOTH the real serving runtime (repro/serving/prefill_instance.py,
repro/serving/decode_instance.py) and the discrete-event simulator (repro/sim/)
so the evaluated policy is the deployed policy.

Prefill side (paper-faithful):
  * S-EDF priority (Eq. 3):  priority = sgn(slack) / deadline,
    slack = deadline - now - TTFT_hat
  * SLO-aware batching (Algorithm 1)
  * The per-event scheduling round of Algorithm 2 (returns control commands;
    the Execution Pool carries them out)
  * Ablation policies (Fig. 10): naive EDF and D-EDF; plus FCFS for the
    DistServe baseline.

Decode side (the paper's core idea — decoupling preemption granularity from
scheduling frequency — generalized to the second serving phase):
  * `DecodeSchedulerCore` ranks decode candidates by TBT-deadline slack
    (`decode_sedf_priority`: slack = decode_deadline - now - remaining_tokens
    * t_step_hat) and selects the continuous batch under a slot cap, optionally
    displacing slack-rich residents at token boundaries (decode preemption).
  * FCFS admission is kept as the baseline (and is what the paper's
    deliberately-plain decode stage does).

Policy-by-policy rationale and the figures that demonstrate each live in
docs/SCHEDULING.md.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import (Callable, List, Mapping, Optional, Sequence, Set, Tuple)

import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.core.request import Request

PriorityFn = Callable[[Request, float, Callable[[float], float]], float]


# ---------------------------------------------------------------------------
# Priority policies
# ---------------------------------------------------------------------------


def _sgn(x: float) -> float:
    return 1.0 if x >= 0.0 else -1.0


def sedf_priority(req: Request, now: float, predict) -> float:
    """Slack-aware EDF (the paper's policy, Eq. 3)."""
    slack = req.deadline - now - predict(req.remaining_tokens())
    return _sgn(slack) / max(req.deadline, 1e-9)


def dedf_priority(req: Request, now: float, predict) -> float:
    """Deadline-aware EDF ablation: numerator sgn(deadline - now)."""
    return _sgn(req.deadline - now) / max(req.deadline, 1e-9)


def edf_priority(req: Request, now: float, predict) -> float:
    """Naive EDF: earliest deadline first, no feasibility awareness."""
    return 1.0 / max(req.deadline, 1e-9)


def fcfs_priority(req: Request, now: float, predict) -> float:
    return -req.arrival


POLICIES = {
    "s-edf": sedf_priority,
    "d-edf": dedf_priority,
    "edf": edf_priority,
    "fcfs": fcfs_priority,
}


# ---------------------------------------------------------------------------
# SLO-aware batching — Algorithm 1
# ---------------------------------------------------------------------------


def slo_aware_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
    now: float,
    predict: Callable[[float], float],
) -> Tuple[Request, List[Request]]:
    """Paper Algorithm 1. Returns (H with updated aggregate tokens, batch list
    including H). Candidates are admitted while H's remaining time covers the
    predicted latency of the aggregate batch and the token budget holds."""
    batch = [H]
    t_remain = H.deadline - now
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        n_new = n + r.num_tokens
        latency = predict(n_new)
        if t_remain > latency and n_new < budget:
            batch.append(r)
            n = n_new
    H.batch_tokens = n
    return H, batch


def greedy_batching(
    H: Request,
    candidates: Sequence[Request],
    budget: int,
) -> Tuple[Request, List[Request]]:
    """Token-budget-only batching (vLLM/Sarathi continuous-batching semantics,
    used by the DistServe-CP baselines): pack while under budget, no deadline
    feasibility check."""
    batch = [H]
    n = H.num_tokens
    for r in candidates:
        if r.rid == H.rid:
            continue
        if n + r.num_tokens < budget:
            batch.append(r)
            n += r.num_tokens
    H.batch_tokens = n
    return H, batch


# ---------------------------------------------------------------------------
# Scheduling round — Algorithm 2 (one event = one round)
# ---------------------------------------------------------------------------


class Action(enum.Enum):
    NOOP = "noop"
    SUBMIT = "submit"          # new batch starts (H was waiting)
    RESUME = "resume"          # H was preempted
    # preemption of the running task is orthogonal and recorded separately


@dataclass
class Decision:
    action: Action
    batch: List[Request] = field(default_factory=list)    # for SUBMIT
    target: Optional[Request] = None                      # H (SUBMIT/RESUME)
    preempt: Optional[Request] = None                     # E to suspend first

    @property
    def is_noop(self) -> bool:
        return self.action == Action.NOOP and self.preempt is None


@dataclass
class SchedulerCore:
    """State-free policy engine. The runtime owns the queues and passes views."""
    predictor: TTFTPredictor
    policy: str = "s-edf"
    batch_budget: int = 4096              # G, tokens (Fig. 11 sweeps this)
    enable_batching: bool = True
    batching_mode: str = "slo"            # "slo" (Alg. 1) | "greedy" (baselines)
    batch_running: bool = False           # paper Alg.2 line 14 admits E into C;
                                          # default off: re-batching the running
                                          # task would discard its progress

    def priority(self, req: Request, now: float) -> float:
        return POLICIES[self.policy](req, now, self.predictor.predict)

    def _priorities_vec(self, requests: Sequence[Request],
                        now: float) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(priorities, deadlines) arrays, elementwise bit-identical to
        `priority` — the per-round ranking is THE scheduler hot path (every
        event re-ranks the whole queue), so the per-request predict/property
        calls are batched. Returns None for policies without a batched form
        or predictors without `predict_many`."""
        n = len(requests)
        dl = np.fromiter((r.arrival + r.slo for r in requests),
                         np.float64, n)
        if self.policy == "s-edf":
            if not hasattr(self.predictor, "predict_many"):
                return None
            rem = np.fromiter((r.remaining_tokens() for r in requests),
                              np.float64, n)
            slack = dl - now - self.predictor.predict_many(rem)
            pri = np.where(slack >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "d-edf":
            pri = np.where(dl - now >= 0.0, 1.0, -1.0) / np.maximum(dl, 1e-9)
        elif self.policy == "edf":
            pri = 1.0 / np.maximum(dl, 1e-9)
        elif self.policy == "fcfs":
            pri = -np.fromiter((r.arrival for r in requests), np.float64, n)
        else:
            return None
        return pri, dl

    def rank(self, requests: Sequence[Request], now: float) -> List[Request]:
        """Descending priority; deterministic tie-break (deadline, rid)."""
        if len(requests) <= 1:
            return list(requests)
        vec = self._priorities_vec(requests, now)
        if vec is None:
            return sorted(requests, key=lambda r: (-self.priority(r, now),
                                                   r.deadline, r.rid))
        pri, dl = vec
        rid = np.fromiter((r.rid for r in requests), np.int64, len(requests))
        # lexsort keys are applied last-first: (-pri, deadline, rid) — rid is
        # unique, so the order matches the scalar tuple sort exactly
        order = np.lexsort((rid, dl, -pri))
        return [requests[i] for i in order]

    def schedule_round(
        self,
        now: float,
        waiting: Sequence[Request],
        preempted: Sequence[Request],
        running: Optional[Request],
    ) -> Decision:
        """One event-triggered round of Algorithm 2 (lines 7–26)."""
        q_all: List[Request] = list(waiting) + list(preempted)
        if running is not None:
            q_all.append(running)
        if not q_all:
            return Decision(Action.NOOP)

        ranked = self.rank(q_all, now)
        H = ranked[0]

        batch = [H]
        waiting_ids = {r.rid for r in waiting}
        if H.rid in waiting_ids and self.enable_batching:
            cands = [r for r in ranked
                     if r.rid != H.rid and r.rid in waiting_ids]
            if self.batch_running and running is not None:
                cands.append(running)
            if self.batching_mode == "greedy":
                H, batch = greedy_batching(H, cands, self.batch_budget)
            else:
                H, batch = slo_aware_batching(
                    H, cands, self.batch_budget, now, self.predictor.predict)

        if running is not None and H.rid == running.rid:
            return Decision(Action.NOOP)                   # already optimal

        preempt = running                                  # may be None
        if H.rid in waiting_ids:
            return Decision(Action.SUBMIT, batch=batch, target=H,
                            preempt=preempt)
        return Decision(Action.RESUME, target=H, preempt=preempt)


# ---------------------------------------------------------------------------
# Decode-side scheduling: TBT-slack-aware batch admission (S-EDF for decode)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodeEntry:
    """One decode candidate as the decode scheduler sees it — owner-agnostic,
    so the SAME ranking drives the fluid `DecodeSim` and the threaded
    `DecodeInstance` (the repo's evaluated-is-deployed rule)."""
    key: int                       # owner handle (request rid)
    remaining_tokens: float        # output tokens still to decode
    deadline: float                # Request.decode_deadline (inf = no TBT SLO)
    order: int                     # admission order (FCFS / deterministic tie)


def decode_sedf_priority(entry: DecodeEntry, now: float,
                         t_step: float) -> float:
    """S-EDF ported to decode (the paper's Eq. 3 with TBT semantics):

        slack    = decode_deadline - now - remaining_tokens * t_step_hat
        priority = sgn(slack) / decode_deadline

    `t_step_hat` is the predicted per-token step time of the batch the entry
    would decode in (DecodeCostModel.step_time via a DecodeStepPredictor).
    Feasible-but-urgent decodes rank first; already-doomed ones (negative
    slack) rank below every feasible candidate, exactly like prefill S-EDF —
    a doomed stream must not displace one that can still meet its TBT SLO.
    Requests without a TBT SLO have an infinite deadline: priority 0, between
    the feasible (positive) and the doomed (negative)."""
    if not math.isfinite(entry.deadline):
        return 0.0
    slack = entry.deadline - now - entry.remaining_tokens * t_step
    return _sgn(slack) / max(entry.deadline, 1e-9)


@dataclass
class DecodeSchedulerCore:
    """Batch-admission policy for one decode instance.

    A decode instance runs a continuous batch of at most `max_batch` streams
    (KV-memory slot cap; <= 0 means unbounded, which degenerates to the
    paper's plain processor-sharing decode). On every join/leave event the
    owner calls `select_batch` with ALL candidates (current residents plus
    queued decodes); the returned batch is the new resident set.

    * ``fcfs``  — admission in arrival order; residents are never displaced
      (an earlier order always outranks a later one).
    * ``s-edf`` — candidates ranked by `decode_sedf_priority`; with
      ``preempt`` (the default) the top-`max_batch` BY PRIORITY become the
      batch, so a near-deadline queued decode displaces a slack-rich resident
      — the decode analogue of operator-level preemption, effective at the
      next token boundary. With ``preempt=False`` residents keep their slots
      and only free slots are filled by rank (admission-only S-EDF).
    """
    policy: str = "s-edf"              # "s-edf" | "fcfs"
    preempt: bool = True

    def priority(self, entry: DecodeEntry, now: float, t_step: float) -> float:
        if self.policy == "fcfs":
            return -float(entry.order)
        return decode_sedf_priority(entry, now, t_step)

    def rank(self, entries: Sequence[DecodeEntry], now: float,
             t_step: float) -> List[DecodeEntry]:
        """Descending priority; deterministic tie-break (deadline, order)."""
        if self.policy == "fcfs":
            return sorted(entries, key=lambda e: e.order)
        return sorted(entries,
                      key=lambda e: (-decode_sedf_priority(e, now, t_step),
                                     e.deadline, e.order))

    def select_batch(self, entries: Sequence[DecodeEntry],
                     resident: Set[int], max_batch: int, now: float,
                     t_step: float) -> Tuple[List[int], List[int]]:
        """Pick the new resident batch from `entries` (residents + queued).

        Returns ``(batch_keys, preempted_keys)``: the keys to run (in rank
        order) and the previously-resident keys displaced by the decision.
        ``max_batch <= 0`` = unbounded: everything is admitted, nothing is
        ever preempted (the plain processor-sharing decode)."""
        ranked = self.rank(entries, now, t_step)
        if max_batch <= 0 or len(entries) <= max_batch:
            return [e.key for e in ranked], []
        if self.preempt:
            batch = [e.key for e in ranked[:max_batch]]
        else:
            keep = [e for e in ranked if e.key in resident]
            free = max_batch - len(keep)
            fill = [e for e in ranked if e.key not in resident][:max(free, 0)]
            batch = [e.key for e in self.rank(keep + fill, now, t_step)]
        chosen = set(batch)
        preempted = [e.key for e in ranked
                     if e.key in resident and e.key not in chosen]
        return batch, preempted


# ---------------------------------------------------------------------------
# Hybrid (colocated prefill + decode) scheduling: one token-budget step
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrefillSlice:
    """One prefill admission in a hybrid step: `n_tokens` of request `key`'s
    prompt, starting at token `offset` (the request's resume point — a
    preempted prefill continues exactly where its last admitted slice ended,
    which the executor maps to an operator offset)."""
    key: int
    offset: int
    n_tokens: int


@dataclass
class HybridStepPlan:
    """What one budget-capped hybrid step runs: the resident decode batch
    (one token each) plus the prefill chunk slices that fit in the remaining
    budget. ``budget_used = len(decode_keys) + sum(slice tokens)`` and never
    exceeds the configured token budget."""
    decode_keys: List[int] = field(default_factory=list)
    preempted_decode: List[int] = field(default_factory=list)
    prefill_slices: List[PrefillSlice] = field(default_factory=list)
    budget_used: int = 0

    @property
    def empty(self) -> bool:
        return not self.decode_keys and not self.prefill_slices


@dataclass
class HybridSchedulerCore:
    """Token-budget colocation scheduler: packs all admitted decode tokens
    plus operator-bounded prefill chunk slices into ONE budget-capped step
    (the nano-vLLM / Sarathi chunked-prefill shape — decode first, prefill
    fills the rest — upgraded with S-EDF deadlines on both phases).

    COMPOSES the two standalone policy cores rather than reimplementing
    them: decode admission is `DecodeSchedulerCore.select_batch` verbatim,
    prefill ordering is `SchedulerCore.rank` verbatim — so with
    ``policy="fcfs"`` and ``token_budget <= 0`` (unbounded) the hybrid plan
    is bit-identical to what the standalone engines would run, which
    tests/test_hybrid.py asserts property-style.

    Per `plan_step`:

    1. *Decode first* — every resident/queued decode stream costs one budget
       token. The slot cap is ``min(decode_max_batch, budget)``; when the
       BUDGET (not the slot cap) is binding, streams squeezed out are
       recorded and admitted ahead of rank next step, so a resident decode
       row is never skipped two consecutive steps (guaranteed whenever the
       skipped set itself fits the budget, i.e. candidates <= 2x budget).
    2. *Prefill fills the remainder* — waiting prefills ranked by the
       prefill core's policy (S-EDF by default) each get one chunk-sized
       slice starting at their resume offset; the last admitted slice is
       truncated to the remaining budget (the executor rounds truncation to
       an operator boundary; the budget bound still holds in tokens).

    Preemption falls out of admission: a prefill not sliced this step simply
    does not run (its offset — and therefore its operator cursor — is
    untouched), and a decode not selected keeps its KV and progress. Both
    are the zero-copy preemption semantics of the standalone engines.
    """
    prefill: SchedulerCore
    decode: DecodeSchedulerCore = field(default_factory=DecodeSchedulerCore)
    token_budget: int = 4096          # G: tokens per hybrid step (<= 0: inf)
    chunk_tokens: int = 512           # prefill slice quantum (<= 0: whole)
    decode_max_batch: int = 0         # decode slot cap (<= 0: unbounded)
    # decode keys the budget squeezed out of the previous step's batch
    # (resident rows owed an admission — see the fairness rule above)
    _owed: Set[int] = field(default_factory=set)

    def _select_decode(self, entries: Sequence[DecodeEntry],
                       resident: Set[int], now: float,
                       t_step: float) -> Tuple[List[int], List[int]]:
        """Decode admission under min(slot cap, token budget). Delegates to
        the standalone `select_batch` whenever the slot cap (or nothing) is
        binding — bit-identical batches; only a binding BUDGET engages the
        owed-rows carry."""
        budget = self.token_budget
        cap = self.decode_max_batch
        budget_binding = budget > 0 and (cap <= 0 or budget < cap) \
            and len(entries) > budget
        if not budget_binding:
            self._owed = set()
            return self.decode.select_batch(entries, resident, cap, now,
                                            t_step)
        owed = [e for e in entries if e.key in self._owed]
        owed = self.decode.rank(owed, now, t_step)[:budget]
        owed_keys = {e.key for e in owed}
        rest_cap = budget - len(owed)
        rest = [e for e in entries if e.key not in owed_keys]
        fill: List[int] = []
        if rest_cap > 0 and rest:
            fill, _ = self.decode.select_batch(
                rest, resident - owed_keys, rest_cap, now, t_step)
            fill = fill[:rest_cap]
        batch = [e.key for e in owed] + fill
        chosen = set(batch)
        preempted = [e.key for e in entries
                     if e.key in resident and e.key not in chosen]
        self._owed = {e.key for e in entries
                      if e.key in resident and e.key not in chosen}
        return batch, preempted

    def plan_step(self, now: float, *,
                  prefill: Sequence[Request],
                  prefill_done: Mapping[int, int],
                  decode_entries: Sequence[DecodeEntry],
                  decode_resident: Set[int],
                  t_step: float = 0.0,
                  decode_cost: float = 1.0) -> HybridStepPlan:
        """Plan one hybrid step. ``prefill`` are the waiting/partial prefill
        requests; ``prefill_done[rid]`` is how many prompt tokens of each are
        already computed (the resume offset). ``decode_entries`` covers
        resident AND queued decode streams; ``decode_resident`` the current
        slot holders; ``t_step`` the predicted per-token decode latency the
        decode S-EDF ranks with. ``decode_cost`` is E[tokens a decode stream
        commits this step] (speculative decoding's accept-rate surface;
        1.0 = plain): each admitted stream consumes that many budget tokens,
        so prefill admission prices the decode side's REAL device work — a
        fully-accepting draft pipeline eats k+1 budget tokens per stream,
        exactly the extra positions its verify pass scores."""
        plan = HybridStepPlan()
        budget = self.token_budget if self.token_budget > 0 else 0
        cost = max(float(decode_cost), 1.0)
        if decode_entries:
            plan.decode_keys, plan.preempted_decode = self._select_decode(
                decode_entries, decode_resident, now, t_step)
        used = len(plan.decode_keys) * cost
        left = (budget - used) if budget else float("inf")
        if prefill and left > 0:
            quantum = self.chunk_tokens
            for req in self.prefill.rank(prefill, now):
                if left <= 0:
                    break
                done = int(prefill_done.get(req.rid, 0))
                remaining = int(req.num_tokens) - done
                if remaining <= 0:
                    continue
                n = remaining if quantum <= 0 else min(quantum, remaining)
                n = int(min(n, left))
                plan.prefill_slices.append(
                    PrefillSlice(key=req.rid, offset=done, n_tokens=n))
                used += n
                left -= n
        plan.budget_used = int(round(used))
        return plan
