"""Cooperative operator-level preemption protocol (FlowPrefill §5.1, Fig. 7).

The Scheduler sets a signal and waits for an ACK; the execution runtime checks
the signal at every operator boundary (a lock-free flag read — "simple
concurrency primitive operations, incurring negligible overhead"), and on a set
signal it unsets it, ACKs, and suspends after the in-flight operator completes.

`SyncCounter` implements the paper's tensor-parallel safety mechanism: workers
may only suspend when all of them have reached the same iteration counter, so
nobody stops inside a collective. Under single-controller JAX one dispatch is
SPMD across the mesh and boundaries are globally synchronized by construction;
SyncCounter is used on the multi-process (multi-pod) runtime path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional


class PreemptionSignal:
    """Signal / ACK pair with blocking-time accounting."""

    def __init__(self):
        self._flag = threading.Event()
        self._ack = threading.Event()
        self._lock = threading.Lock()
        self._signal_time: Optional[float] = None
        self.blocking_times: List[float] = []

    # --- scheduler side -----------------------------------------------------
    def request_preemption(self) -> None:
        with self._lock:
            self._ack.clear()
            self._signal_time = time.monotonic()
            self._flag.set()

    def wait_ack(self, timeout: Optional[float] = None) -> bool:
        """Blocks until the runtime acknowledges suspension. Returns False on
        timeout (runtime finished without needing to preempt)."""
        return self._ack.wait(timeout)

    def cancel(self) -> None:
        """Withdraw an un-acknowledged signal (e.g. task completed first)."""
        with self._lock:
            self._flag.clear()
            self._signal_time = None

    # --- runtime side (called at every operator boundary) --------------------
    def check(self) -> bool:
        """Lock-free fast path: no signal -> proceed immediately."""
        return self._flag.is_set()

    def consume_and_ack(self) -> float:
        """Unset the signal, record blocking time, ACK. Returns blocking dt."""
        with self._lock:
            self._flag.clear()
            dt = 0.0
            if self._signal_time is not None:
                dt = time.monotonic() - self._signal_time
                self.blocking_times.append(dt)
                self._signal_time = None
        self._ack.set()
        return dt


@dataclass
class BlockingStats:
    samples: List[float] = field(default_factory=list)

    def record(self, dt: float) -> None:
        self.samples.append(dt)

    def extend(self, dts) -> None:
        self.samples.extend(dts)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    @property
    def p99(self) -> float:
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        return s[min(int(0.99 * len(s)), len(s) - 1)]

    @property
    def max(self) -> float:
        return max(self.samples) if self.samples else 0.0


class SyncCounter:
    """Synchronized iteration counter across tensor-parallel workers.

    Workers call `step()` after each operator; `safe_to_suspend(c)` is true
    only when every worker has reached counter c, guaranteeing no worker is
    inside (or about to enter) a collective the others abandoned.
    """

    def __init__(self, num_workers: int):
        self.num_workers = num_workers
        self._counters = [0] * num_workers
        self._cond = threading.Condition()

    def step(self, worker: int) -> int:
        with self._cond:
            self._counters[worker] += 1
            self._cond.notify_all()
            return self._counters[worker]

    def min_counter(self) -> int:
        with self._cond:
            return min(self._counters)

    def safe_to_suspend(self, at_counter: int) -> bool:
        with self._cond:
            return all(c >= at_counter for c in self._counters)

    def wait_all(self, at_counter: int, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not all(c >= at_counter for c in self._counters):
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(remaining)
            return True
