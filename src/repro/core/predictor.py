"""Lightweight TTFT predictor (FlowPrefill §6.4, Fig. 13).

A polynomial fitted to offline prefill profiles: x = token count, y = prefill
latency. Degree 2 captures the linear GEMM term plus the quadratic attention
term; in the PD-disaggregated setting prefill latency is undisturbed by decode,
so this simple fit suffices (validated in benchmarks/fig13_predictor.py).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass
class TTFTPredictor:
    coeffs: np.ndarray                   # np.polyval order (highest first)
    floor: float = 0.0                   # minimum latency (dispatch overhead)

    @classmethod
    def fit(cls, tokens: Sequence[float], latencies: Sequence[float],
            degree: int = 2) -> "TTFTPredictor":
        tokens = np.asarray(tokens, dtype=np.float64)
        latencies = np.asarray(latencies, dtype=np.float64)
        coeffs = np.polyfit(tokens, latencies, degree)
        floor = float(max(latencies.min() * 0.5, 0.0))
        return cls(coeffs=coeffs, floor=floor)

    @classmethod
    def from_cost_model(cls, cost_fn, max_tokens: int = 65536,
                        n_points: int = 64, degree: int = 2) -> "TTFTPredictor":
        """Fit against an analytic cost model (sim calibration path)."""
        xs = np.linspace(64, max_tokens, n_points)
        ys = np.array([cost_fn(int(x)) for x in xs])
        return cls.fit(xs, ys, degree)

    def predict(self, num_tokens: float) -> float:
        y = float(np.polyval(self.coeffs, max(float(num_tokens), 0.0)))
        return max(y, self.floor)

    def __call__(self, num_tokens: float) -> float:
        return self.predict(num_tokens)

    # --- persistence (offline fit shipped with a deployment) ---------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"coeffs": self.coeffs.tolist(), "floor": self.floor}, f)

    @classmethod
    def load(cls, path: str) -> "TTFTPredictor":
        with open(path) as f:
            d = json.load(f)
        return cls(coeffs=np.asarray(d["coeffs"]), floor=d["floor"])
