"""Lightweight TTFT predictor (FlowPrefill §6.4, Fig. 13).

A polynomial fitted to offline prefill profiles: x = token count, y = prefill
latency. Degree 2 captures the linear GEMM term plus the quadratic attention
term; in the PD-disaggregated setting prefill latency is undisturbed by decode,
so this simple fit suffices (validated in benchmarks/fig13_predictor.py).

`predict` is THE scheduler/dispatch hot path (hundreds of calls per arrival at
cluster scale: S-EDF feasibility + competing-work pricing), so it evaluates
the polynomial with an inline Horner loop over cached float coefficients —
the exact IEEE operation sequence of np.polyval, ~20x faster on scalars.

`OnlineTTFTPredictor` adds predictor feedback (ROADMAP dispatch extension):
it starts from an offline prior and refits the polynomial from a sliding
window of observed (tokens, latency) pairs, so a mis-calibrated prior — e.g.
a predictor fitted on one hardware generation deployed on another in a
heterogeneous pool — converges to the instance's true cost curve.

`DecodeStepPredictor` is the decode-phase counterpart: the decode S-EDF
scheduler (core/scheduler.py `DecodeSchedulerCore`) needs predicted per-token
step times to compute TBT-deadline slack. The prior is analytic
(`DecodeCostModel.step_time(batch, mean_context)` — decode is memory-bound, so
the two-term weights+KV model is accurate), and observed per-token latencies
calibrate a single multiplicative scale via an EMA, so slack estimates track
the real hardware the way OnlineTTFTPredictor tracks prefill speed.
"""
from __future__ import annotations

import json
import threading
import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


def expected_accept_tokens(accept_rate: float, draft_k: int) -> float:
    """Analytic E[tokens/step] of greedy draft-then-verify with per-token
    accept probability ``a`` and draft length ``k``: the step commits the
    current token plus the longest accepted draft prefix, so

        E = 1 + a + a^2 + ... + a^k = (1 - a^{k+1}) / (1 - a)

    This is the shared accept-rate surface: `DecodeSim` advances streams by
    it, trace generators stamp per-task accept rates with it in mind, and
    the runtime's EMA estimate converges to it — evaluated-is-deployed."""
    a = min(max(float(accept_rate), 0.0), 1.0)
    k = max(int(draft_k), 0)
    if k == 0:
        return 1.0
    if a >= 1.0:
        return float(k + 1)
    return float((1.0 - a ** (k + 1)) / (1.0 - a))


@dataclass
class TTFTPredictor:
    coeffs: np.ndarray                   # np.polyval order (highest first)
    floor: float = 0.0                   # minimum latency (dispatch overhead)

    # Horner cache: (source array, coefficients as python floats). Rebuilt
    # whenever `coeffs` is rebound (online refit), checked by identity.
    _horner: Optional[Tuple[np.ndarray, Tuple[float, ...]]] = \
        field(default=None, repr=False, compare=False)

    @classmethod
    def fit(cls, tokens: Sequence[float], latencies: Sequence[float],
            degree: int = 2) -> "TTFTPredictor":
        tokens = np.asarray(tokens, dtype=np.float64)
        latencies = np.asarray(latencies, dtype=np.float64)
        coeffs = np.polyfit(tokens, latencies, degree)
        floor = float(max(latencies.min() * 0.5, 0.0))
        return cls(coeffs=coeffs, floor=floor)

    @classmethod
    def from_cost_model(cls, cost_fn, max_tokens: int = 65536,
                        n_points: int = 64, degree: int = 2) -> "TTFTPredictor":
        """Fit against an analytic cost model (sim calibration path)."""
        xs = np.linspace(64, max_tokens, n_points)
        ys = np.array([cost_fn(int(x)) for x in xs])
        return cls.fit(xs, ys, degree)

    def _coeff_tuple(self) -> Tuple[float, ...]:
        cached = self._horner
        if cached is None or cached[0] is not self.coeffs:
            cached = (self.coeffs, tuple(float(c) for c in self.coeffs))
            self._horner = cached
        return cached[1]

    def predict(self, num_tokens: float) -> float:
        x = max(float(num_tokens), 0.0)
        y = 0.0
        for c in self._coeff_tuple():    # Horner — np.polyval's op sequence
            y = y * x + c
        return max(y, self.floor)

    def predict_many(self, num_tokens: np.ndarray) -> np.ndarray:
        """Vectorized predict over an array of token counts (elementwise
        identical to `predict`: same Horner recurrence, same floor clamp)."""
        x = np.maximum(np.asarray(num_tokens, dtype=np.float64), 0.0)
        y = np.zeros_like(x)
        for c in self._coeff_tuple():
            y = y * x + c
        return np.maximum(y, self.floor)

    def __call__(self, num_tokens: float) -> float:
        return self.predict(num_tokens)

    # --- persistence (offline fit shipped with a deployment) ---------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"coeffs": self.coeffs.tolist(), "floor": self.floor}, f)

    @classmethod
    def load(cls, path: str) -> "TTFTPredictor":
        with open(path) as f:
            d = json.load(f)
        return cls(coeffs=np.asarray(d["coeffs"]), floor=d["floor"])


@dataclass
class OnlineTTFTPredictor(TTFTPredictor):
    """TTFT predictor with online refit from observed prefill latencies.

    Serves predictions from the prior until `min_points` observations arrive,
    then refits the polynomial over a sliding window every `refit_every`
    observations. The refit degree is capped by the number of distinct token
    counts seen, so early refits with clustered observations stay
    well-conditioned instead of extrapolating a wild quadratic.

    `observe` is thread-safe: the real Proxy feeds it from every prefill
    instance's scheduler thread. `predict` stays lock-free — it reads one
    rebound `coeffs` reference, which is atomic.
    """
    window: int = 256                    # observations kept for refitting
    min_points: int = 8                  # observations before the first refit
    refit_every: int = 8                 # refit cadence (in observations)
    degree: int = 2

    _obs_x: List[float] = field(default_factory=list, repr=False,
                                compare=False)
    _obs_y: List[float] = field(default_factory=list, repr=False,
                                compare=False)
    _obs_lock: threading.Lock = field(default_factory=threading.Lock,
                                      repr=False, compare=False)
    _since_refit: int = field(default=0, repr=False, compare=False)
    n_observed: int = field(default=0, repr=False, compare=False)
    n_refits: int = field(default=0, repr=False, compare=False)

    @classmethod
    def from_predictor(cls, base: TTFTPredictor,
                       **kwargs) -> "OnlineTTFTPredictor":
        return cls(coeffs=np.array(base.coeffs, copy=True), floor=base.floor,
                   **kwargs)

    def observe(self, num_tokens: float, latency: float) -> None:
        """Feed one observed (tokens, prefill latency) pair; refits lazily."""
        if num_tokens <= 0 or latency <= 0:
            return
        with self._obs_lock:
            self._obs_x.append(float(num_tokens))
            self._obs_y.append(float(latency))
            if len(self._obs_x) > self.window:
                del self._obs_x[0], self._obs_y[0]
            self.n_observed += 1
            self._since_refit += 1
            if len(self._obs_x) >= self.min_points and \
                    self._since_refit >= self.refit_every:
                self._refit()

    def _refit(self) -> None:
        # caller holds _obs_lock
        self._since_refit = 0
        deg = min(self.degree, len(set(self._obs_x)) - 1)
        if deg < 1:
            return                       # degenerate window (one token count)
        xs = np.asarray(self._obs_x)
        ys = np.asarray(self._obs_y)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # poorly conditioned early fits
            self.coeffs = np.polyfit(xs, ys, deg)
        self.floor = float(max(ys.min() * 0.5, 0.0))
        self.n_refits += 1


@dataclass
class MeasuredStepTime:
    """Measured decode step-time surface — the PROFILED prior for
    `DecodeStepPredictor`, replacing the purely analytic seed.

    Decode latency has the two-term memory-bound structure of
    `DecodeCostModel.step_time` (a fixed weight-stream + per-launch cost,
    plus a per-stream KV-stream term), so the surface

        t(B, ctx) = c0 + c1 * B + c2 * B * ctx

    fitted by least squares over profiled ``(batch, mean_context, seconds)``
    samples (`repro.serving.decode_instance.profile_step_times` measures them
    from the real jitted batched step) captures the deployed hardware's
    actual curve — including host/dispatch overheads the analytic model can
    only approximate. Negative slope terms (a noisy profile can fit c1/c2
    below zero) are clamped to zero AT FIT TIME with the intercept refit, so
    the surface stays monotone non-decreasing in batch and context — a
    latency model claiming bigger batches are faster would invert every
    S-EDF slack ranking built on it.
    """
    c0: float
    c1: float
    c2: float
    n_samples: int = 0
    floor: float = 1e-9

    @classmethod
    def fit(cls, samples: Sequence[Tuple[int, float, float]]
            ) -> "MeasuredStepTime":
        """samples: [(batch_size, mean_context, seconds_per_step)]."""
        pts = [(float(b), float(c), float(t)) for b, c, t in samples]
        if not pts:
            raise ValueError("MeasuredStepTime.fit needs >= 1 sample")
        y = np.array([t for _, _, t in pts])
        cols = [np.ones(len(pts)),
                np.array([b for b, _, _ in pts]),
                np.array([b * c for b, c, _ in pts])]
        keep = [0, 1, 2]
        coef = np.zeros(3)
        while True:
            A = np.stack([cols[i] for i in keep], axis=1)
            sol, *_ = np.linalg.lstsq(A, y, rcond=None)
            coef = np.zeros(3)
            coef[keep] = sol
            # clamp negative slope terms and refit the rest (active-set
            # style): monotone non-decreasing in B and ctx by construction
            neg = [i for i in keep if i != 0 and coef[i] < 0.0]
            if not neg:
                break
            keep = [i for i in keep if i not in neg]
        floor = float(max(y.min() * 0.25, 1e-9))
        return cls(c0=float(coef[0]), c1=float(coef[1]), c2=float(coef[2]),
                   n_samples=len(pts), floor=floor)

    def __call__(self, batch_size: int, mean_context: float) -> float:
        t = self.c0 + self.c1 * batch_size \
            + self.c2 * batch_size * max(mean_context, 0.0)
        return max(t, self.floor)

    def rel_err(self, samples: Sequence[Tuple[int, float, float]]) -> float:
        """Mean relative error of the fitted surface over `samples` (fit
        quality / holdout agreement — the fig21 gate metric)."""
        errs = [abs(self(b, c) - t) / max(t, 1e-12) for b, c, t in samples]
        return float(np.mean(errs)) if errs else 0.0


@dataclass
class DecodeStepPredictor:
    """Per-token decode step-time predictor (decode S-EDF's latency model).

    Wraps a prior ``(batch_size, mean_context) -> seconds`` — the analytic
    `DecodeCostModel.step_time`, or a `MeasuredStepTime` surface profiled
    from the real batched step (`from_profile`) — and calibrates it with a
    single multiplicative scale learned from observed per-token latencies via
    an EMA: decode latency is dominated by one memory-bandwidth term, so a
    scale on the prior curve absorbs most hardware mis-calibration — a full
    refit like OnlineTTFTPredictor's polynomial is unnecessary here.

    With no observations the predictor IS the prior (scale 1.0): the fluid
    simulator uses it un-calibrated so scheduling decisions stay bit-aligned
    with the cost model it is evaluated against; the threaded DecodeInstance
    feeds `observe` from its own worker, one predictor per instance.
    """
    prior: Callable[[int, float], float]
    ema_alpha: float = 0.1               # EMA weight of a new observation
    scale: float = 1.0
    n_observed: int = 0

    # --- speculative-decoding accept-rate surface --------------------------
    # Under speculation a stream commits 1..k+1 tokens per step, so the
    # honest per-ACCEPTED-token service time is step_time / E[tokens/step].
    # Both an aggregate and a per-stream EMA of observed tokens/step are
    # kept: S-EDF slack and migration gating price a specific stream (its
    # own accept behaviour), while batch-level budgets use the aggregate.
    accept_alpha: float = 0.25           # EMA weight for tokens/step updates
    _tps_all: float = field(default=0.0, repr=False, compare=False)
    _tps_by_key: dict = field(default_factory=dict, repr=False, compare=False)

    @classmethod
    def from_profile(cls, samples: Sequence[Tuple[int, float, float]],
                     **kwargs) -> "DecodeStepPredictor":
        """Build a predictor whose prior is a `MeasuredStepTime` surface
        fitted to profiled ``(batch, mean_context, seconds)`` samples from
        the real jitted step (see
        `repro.serving.decode_instance.profile_step_times`)."""
        return cls(prior=MeasuredStepTime.fit(samples), **kwargs)

    def step_time(self, batch_size: int, mean_context: float) -> float:
        return self.prior(batch_size, mean_context) * self.scale

    def observe(self, batch_size: int, mean_context: float,
                measured: float) -> None:
        """Feed one measured per-token step latency for calibration."""
        base = self.prior(batch_size, mean_context)
        if base <= 0.0 or measured <= 0.0:
            return
        ratio = measured / base
        self.scale += self.ema_alpha * (ratio - self.scale)
        self.n_observed += 1

    def observe_accept(self, key: int, tokens_committed: float) -> None:
        """Feed the number of tokens one decode step committed for stream
        ``key`` (1 = draft fully rejected or no draft; k+1 = fully
        accepted). Updates the per-stream and aggregate tokens/step EMAs."""
        t = float(tokens_committed)
        if t < 1.0:
            return
        prev = self._tps_by_key.get(key)
        self._tps_by_key[key] = t if prev is None \
            else prev + self.accept_alpha * (t - prev)
        self._tps_all = t if self._tps_all <= 0.0 \
            else self._tps_all + self.accept_alpha * (t - self._tps_all)

    def expected_tokens_per_step(self, key: Optional[int] = None) -> float:
        """E[tokens committed per decode step] — per-stream EMA when `key`
        has been observed, else the aggregate; 1.0 (plain decoding) before
        any observation. Never below 1.0: a step always commits the current
        token."""
        if key is not None:
            v = self._tps_by_key.get(key)
            if v is not None:
                return max(v, 1.0)
        return max(self._tps_all, 1.0) if self._tps_all > 0.0 else 1.0

    def forget_stream(self, key: int) -> None:
        """Drop a finished/migrated stream's accept-rate state."""
        self._tps_by_key.pop(key, None)
