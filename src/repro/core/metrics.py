"""SLO attainment and goodput metrics (FlowPrefill §6.1).

Goodput = maximum sustainable request rate at an SLO-attainment goal (90%).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.request import Request


def slo_attainment(requests: Sequence[Request]) -> float:
    done = [r for r in requests if r.arrival is not None]
    if not done:
        return 1.0
    return sum(1 for r in done if r.slo_met) / len(done)


def attainment_by_task(requests: Sequence[Request]) -> Dict[str, float]:
    by: Dict[str, List[Request]] = {}
    for r in requests:
        by.setdefault(r.task_type, []).append(r)
    return {t: slo_attainment(rs) for t, rs in by.items()}


def ttft_stats(requests: Sequence[Request]) -> Dict[str, float]:
    ts = [r.ttft for r in requests if r.ttft is not None]
    if not ts:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    a = np.asarray(ts)
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)), "max": float(a.max())}


def max_goodput(rates: Sequence[float], attainments: Sequence[float],
                target: float = 0.9) -> float:
    """Largest rate whose attainment >= target, with linear interpolation to
    the crossing point (the vertical lines in the paper's Fig. 9)."""
    rates = np.asarray(rates, dtype=np.float64)
    att = np.asarray(attainments, dtype=np.float64)
    order = np.argsort(rates)
    rates, att = rates[order], att[order]
    if att[0] < target:
        return 0.0
    best = rates[0]
    for i in range(1, len(rates)):
        if att[i] >= target:
            best = rates[i]
        else:
            # interpolate crossing between i-1 and i
            r0, r1, a0, a1 = rates[i - 1], rates[i], att[i - 1], att[i]
            if a0 != a1:
                best = r0 + (a0 - target) * (r1 - r0) / (a0 - a1)
            break
    return float(best)


def min_slo_scale(scales: Sequence[float], attainments: Sequence[float],
                  target: float = 0.9) -> float:
    """Smallest SLO scale whose attainment >= target (paper Fig. 9 row 2)."""
    scales = np.asarray(scales, dtype=np.float64)
    att = np.asarray(attainments, dtype=np.float64)
    order = np.argsort(scales)
    scales, att = scales[order], att[order]
    for s, a in zip(scales, att):
        if a >= target:
            return float(s)
    return float("inf")
