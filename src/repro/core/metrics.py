"""SLO attainment, percentile, and goodput metrics (FlowPrefill §6.1).

Two goodput notions coexist (docs/BENCHMARKS.md, docs/TRACES.md):

  * ``max_goodput`` — attainment-gated: the maximum sustainable rate at an
    SLO-*attainment* goal (90% of requests meet their SLO). This is the
    paper's Fig. 9 definition and what fig9/18/19/20/22 gate on.
  * ``percentile_goodput`` — tail-gated: the maximum rate whose p99
    SLO-normalized latency still meets the SLO (p99(latency/SLO) <= 1).
    Production SLOs are written against tails, not means, and mean- vs
    p99-gated comparisons can ORDER policies differently ("Optimal
    Scheduling Algorithms for LLM Inference", PAPERS.md) — fig23 gates the
    stress-scenario suite on this one.

Percentile families report p50/p90/p99 for TTFT and TBT, per task class and
aggregate. Unfinished requests contribute +inf to normalized-latency
percentiles — a request that never produced its first token can never
improve a tail statistic.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.request import Request

PERCENTILES = (50.0, 90.0, 99.0)


def slo_attainment(requests: Sequence[Request]) -> float:
    """Fraction of requests meeting their TTFT SLO, over ALL submitted
    requests: an unfinished or dropped request counts as a violation (it
    stays in the denominator with ``slo_met == False``), so mid-run or
    partial reports can never inflate attainment by shrinking the
    denominator. (An earlier version filtered on ``arrival is not None`` —
    dead code, ``arrival`` is a float — which read as if unfinished work
    were excluded; it never was, and now the contract is explicit.)"""
    if not requests:
        return 1.0
    return sum(1 for r in requests if r.slo_met) / len(requests)


def attainment_by_task(requests: Sequence[Request]) -> Dict[str, float]:
    by: Dict[str, List[Request]] = {}
    for r in requests:
        by.setdefault(r.task_type, []).append(r)
    return {t: slo_attainment(rs) for t, rs in by.items()}


def percentile_stats(values: Sequence[float]) -> Dict[str, float]:
    """{mean, p50, p90, p99, max} of a latency sample (zeros when empty)."""
    if len(values) == 0:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}
    a = np.asarray(values, dtype=np.float64)
    out = {"mean": float(a.mean()), "max": float(a.max())}
    for q in PERCENTILES:
        out[f"p{q:.0f}"] = float(np.percentile(a, q))
    return out


def ttft_stats(requests: Sequence[Request]) -> Dict[str, float]:
    """TTFT percentile family over FINISHED requests (unfinished requests
    have no TTFT sample; they are violations in `slo_attainment` and +inf in
    `slo_frac_percentile`, which is where tail gating should look)."""
    return percentile_stats([r.ttft for r in requests if r.ttft is not None])


def tbt_stats(requests: Sequence[Request]) -> Dict[str, float]:
    """Mean-TPOT (TBT) percentile family over requests that decoded."""
    return percentile_stats([r.mean_tpot for r in requests
                             if r.output_tokens > 0
                             and r.mean_tpot is not None])


def stats_by_task(requests: Sequence[Request],
                  phase: str = "ttft") -> Dict[str, Dict[str, float]]:
    """Per-task-class percentile families: {task: {mean, p50, p90, p99,
    max}}. ``phase`` is "ttft" or "tbt"."""
    fn = ttft_stats if phase == "ttft" else tbt_stats
    by: Dict[str, List[Request]] = {}
    for r in requests:
        by.setdefault(r.task_type, []).append(r)
    return {t: fn(rs) for t, rs in sorted(by.items())}


def slo_frac_percentile(requests: Sequence[Request], q: float = 99.0,
                        phase: str = "ttft") -> float:
    """Percentile of SLO-NORMALIZED latency: ttft/slo ("ttft"), mean-TPOT /
    tbt_slo ("tbt"), or the per-request max of both ("e2e"). <= 1.0 means
    that percentile of requests met the SLO. Normalizing makes the statistic
    comparable across the heterogeneous per-task SLOs of the QwenTrace mix —
    a raw-seconds p99 would just be the slowest task class's tail.

    Unfinished requests contribute +inf (a missing first token IS a tail
    event); requests with no decode phase contribute nothing to "tbt" and
    only their TTFT fraction to "e2e". Returns 0.0 on an empty sample."""
    fracs: List[float] = []
    for r in requests:
        parts: List[float] = []
        if phase in ("ttft", "e2e"):
            parts.append(r.ttft / r.slo if r.ttft is not None else np.inf)
        if phase in ("tbt", "e2e") and r.output_tokens > 0 \
                and np.isfinite(r.tbt_slo) and r.tbt_slo > 0:
            parts.append(r.mean_tpot / r.tbt_slo
                         if r.mean_tpot is not None else np.inf)
        if parts:
            fracs.append(max(parts))
    if not fracs:
        return 0.0
    a = np.asarray(fracs, dtype=np.float64)
    if np.isinf(a).any():
        # linear interpolation between two +inf order statistics is nan;
        # fall back to the nearest actual sample, which keeps the result
        # inf exactly when the percentile position lands in the inf tail
        return float(np.percentile(a, q, method="lower"))
    return float(np.percentile(a, q))


def max_goodput(rates: Sequence[float], attainments: Sequence[float],
                target: float = 0.9) -> float:
    """Largest rate whose attainment >= target, with linear interpolation to
    the crossing point (the vertical lines in the paper's Fig. 9)."""
    rates = np.asarray(rates, dtype=np.float64)
    att = np.asarray(attainments, dtype=np.float64)
    order = np.argsort(rates)
    rates, att = rates[order], att[order]
    if att[0] < target:
        return 0.0
    best = rates[0]
    for i in range(1, len(rates)):
        if att[i] >= target:
            best = rates[i]
        else:
            # interpolate crossing between i-1 and i
            r0, r1, a0, a1 = rates[i - 1], rates[i], att[i - 1], att[i]
            if a0 != a1:
                best = r0 + (a0 - target) * (r1 - r0) / (a0 - a1)
            break
    return float(best)


def percentile_goodput(rates: Sequence[float], p99_fracs: Sequence[float],
                       target: float = 1.0) -> float:
    """Largest rate whose p99 SLO-normalized latency (`slo_frac_percentile`)
    still meets the SLO (<= target), interpolating to the crossing point —
    the tail-gated counterpart of `max_goodput` (values here are
    lower-is-better, so the crossing is upward). Infinite tail values
    (unfinished requests) clamp the crossing to the last feasible measured
    rate: there is nothing meaningful to interpolate toward."""
    rates = np.asarray(rates, dtype=np.float64)
    vals = np.asarray(p99_fracs, dtype=np.float64)
    order = np.argsort(rates)
    rates, vals = rates[order], vals[order]
    if vals[0] > target:
        return 0.0
    best = rates[0]
    for i in range(1, len(rates)):
        if vals[i] <= target:
            best = rates[i]
        else:
            r0, r1 = rates[i - 1], rates[i]
            v0, v1 = vals[i - 1], vals[i]
            if np.isfinite(v1) and v0 != v1:
                best = r0 + (target - v0) * (r1 - r0) / (v1 - v0)
            break
    return float(best)


def min_slo_scale(scales: Sequence[float], attainments: Sequence[float],
                  target: float = 0.9) -> float:
    """Smallest SLO scale whose attainment >= target (paper Fig. 9 row 2)."""
    scales = np.asarray(scales, dtype=np.float64)
    att = np.asarray(attainments, dtype=np.float64)
    order = np.argsort(scales)
    scales, att = scales[order], att[order]
    for s, a in zip(scales, att):
        if a >= target:
            return float(s)
    return float("inf")


def percentile_report(requests: Sequence[Request],
                      by_task: bool = True) -> dict:
    """The full percentile family as one nested dict — the shape shared by
    `ClusterResult.percentiles()` and `Proxy.report()['percentiles']`:

        {"ttft": {...}, "tbt": {...},
         "ttft_p99_norm": float, "tbt_p99_norm": float, "e2e_p99_norm": float,
         "by_task": {task: {"ttft": {...}, "tbt": {...}}}}
    """
    out: dict = {
        "ttft": ttft_stats(requests),
        "tbt": tbt_stats(requests),
        "ttft_p99_norm": slo_frac_percentile(requests, 99.0, "ttft"),
        "tbt_p99_norm": slo_frac_percentile(requests, 99.0, "tbt"),
        "e2e_p99_norm": slo_frac_percentile(requests, 99.0, "e2e"),
    }
    if by_task:
        ttft_by = stats_by_task(requests, "ttft")
        tbt_by = stats_by_task(requests, "tbt")
        out["by_task"] = {t: {"ttft": ttft_by[t], "tbt": tbt_by[t]}
                          for t in ttft_by}
    return out
