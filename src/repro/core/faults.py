"""Deterministic, seedable fault plans — the failure model shared by
`ClusterSim` and the runtime chaos harness (evaluated-is-deployed, like the
dispatch policies: the SAME `FaultPlan` object drives simulator instance
churn and real thread-pool fault injection).

A `FaultPlan` is an ordered tuple of `FaultEvent`s, each scheduling one
fault on one instance:

  * ``crash``    — the instance dies instantly: queued + running prefills
                   (or resident decodes, ``target="decode"``) are stranded
                   and their KV is lost; rejoins after ``duration``.
  * ``hang``     — the instance stops making progress but does not die;
                   detected by the watchdog after its deadline, then treated
                   as a crash (strand + re-dispatch). Rejoins after
                   ``duration``.
  * ``slowdown`` — every operation on the instance takes ``factor``x as
                   long for ``duration`` seconds (gray failure: the
                   instance stays up and keeps completing work, slowly).
  * ``spot``     — spot preemption with ``notice`` seconds of warning: the
                   instance stops ACCEPTING dispatch at ``time`` (draining)
                   and dies at ``time + notice``; rejoins ``duration``
                   after the kill.
  * ``kv_link``  — the prefill->decode KV transfer link into the instance
                   drops for ``duration`` seconds: in-flight handoffs
                   (DECODE_JOIN) are lost and must be retried elsewhere.

Plans are deterministic: `generate` expands a seed into a reproducible
schedule, presets name the benchmark scenarios (fig26), and
`to_json`/`from_json` round-trip a plan for `--chaos <file>` replay.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "hang", "slowdown", "spot", "kv_link")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on one instance (times relative to run start)."""
    time: float                      # when the fault fires
    instance: int                    # index within the targeted pool
    kind: str = "crash"              # one of FAULT_KINDS
    duration: float = math.inf       # until rejoin/recovery (inf = never)
    notice: float = 0.0              # spot: drain warning before the kill
    factor: float = 1.0              # slowdown multiplier (>1 = slower)
    target: str = "prefill"          # "prefill" | "decode"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.target not in ("prefill", "decode"):
            raise ValueError(f"unknown fault target {self.target!r}")
        if self.time < 0 or self.notice < 0:
            raise ValueError("fault time/notice must be >= 0")
        if self.duration <= 0:
            raise ValueError("fault duration must be > 0")
        if self.kind == "slowdown" and self.factor <= 1.0:
            raise ValueError("slowdown needs factor > 1")

    @property
    def down_at(self) -> float:
        """When the instance actually stops serving (spot waits out the
        drain notice; everything else is immediate)."""
        return self.time + (self.notice if self.kind == "spot" else 0.0)

    @property
    def up_at(self) -> float:
        """When the instance rejoins the pool (inf = never)."""
        return self.down_at + self.duration


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted schedule of `FaultEvent`s."""
    events: Tuple[FaultEvent, ...] = field(default_factory=tuple)
    seed: Optional[int] = None       # provenance when generated

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.time, e.instance))))

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def for_target(self, target: str) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.target == target)

    def max_instance(self, target: str = "prefill") -> int:
        evs = self.for_target(target)
        return max((e.instance for e in evs), default=-1)

    # ------------------------------------------------------------ builders
    @classmethod
    def generate(cls, seed: int, n_instances: int, duration: float, *,
                 rate: float = 0.02,
                 kinds: Sequence[str] = ("crash", "hang", "slowdown", "spot"),
                 mean_outage: float = 8.0,
                 target: str = "prefill") -> "FaultPlan":
        """Expand a seed into a reproducible random schedule: Poisson fault
        arrivals at `rate` faults/sec over `duration`, uniform over
        instances and `kinds`, exponential outage lengths (so most faults
        rejoin within the run — the interesting regime for recovery)."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / max(rate, 1e-9)))
            if t >= duration:
                break
            kind = str(rng.choice(list(kinds)))
            out = float(rng.exponential(mean_outage)) + 0.5
            events.append(FaultEvent(
                time=round(t, 3),
                instance=int(rng.integers(0, n_instances)),
                kind=kind,
                duration=round(out, 3),
                notice=round(float(rng.uniform(0.5, 2.0)), 3)
                if kind == "spot" else 0.0,
                factor=round(float(rng.uniform(2.0, 6.0)), 3)
                if kind == "slowdown" else 1.0,
                target=target))
        return cls(events=tuple(events), seed=seed)

    @classmethod
    def preset(cls, name: str, *, n_instances: int = 4,
               duration: float = 40.0) -> "FaultPlan":
        """Named benchmark scenarios (fig26 / --chaos):

          * ``churn``     — kill 1 of `n_instances` mid-trace, rejoin later
          * ``spot-wave`` — two staggered spot preemptions with notice
          * ``gray``      — one hang + one slowdown (gray failures)
        """
        third = duration / 3.0
        if name == "churn":
            return cls(events=(
                FaultEvent(time=round(third, 3), instance=1, kind="crash",
                           duration=round(third, 3)),
            ))
        if name == "spot-wave":
            return cls(events=(
                FaultEvent(time=round(0.25 * duration, 3), instance=0,
                           kind="spot", notice=1.0,
                           duration=round(0.35 * duration, 3)),
                FaultEvent(time=round(0.45 * duration, 3),
                           instance=min(2, n_instances - 1), kind="spot",
                           notice=1.0, duration=round(0.3 * duration, 3)),
            ))
        if name == "gray":
            return cls(events=(
                FaultEvent(time=round(0.25 * duration, 3), instance=0,
                           kind="hang", duration=round(0.25 * duration, 3)),
                FaultEvent(time=round(0.5 * duration, 3),
                           instance=min(1, n_instances - 1),
                           kind="slowdown", factor=4.0,
                           duration=round(0.25 * duration, 3)),
            ))
        raise ValueError(f"unknown fault preset {name!r}; "
                         f"known: churn, spot-wave, gray")

    # --------------------------------------------------------- persistence
    def to_json(self) -> str:
        def enc(e: FaultEvent) -> dict:
            d = asdict(e)
            if math.isinf(d["duration"]):
                d["duration"] = None          # JSON has no inf
            return d
        return json.dumps({"seed": self.seed,
                           "events": [enc(e) for e in self.events]},
                          indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        events = []
        for e in d.get("events", []):
            if e.get("duration") is None:
                e = dict(e, duration=math.inf)
            events.append(FaultEvent(**e))
        return cls(events=tuple(events), seed=d.get("seed"))

    @classmethod
    def from_spec(cls, spec: str, *, n_instances: int = 4,
                  duration: float = 40.0) -> "FaultPlan":
        """Resolve a CLI ``--chaos`` spec: a preset name (``churn``,
        ``spot-wave``, ``gray``), ``seed:<int>`` for a generated plan, or a
        path to a JSON file written by `to_json`."""
        if spec.startswith("seed:"):
            return cls.generate(int(spec[5:]), n_instances, duration)
        try:
            return cls.preset(spec, n_instances=n_instances,
                              duration=duration)
        except ValueError:
            pass
        try:
            with open(spec) as f:
                return cls.from_json(f.read())
        except OSError:
            raise ValueError(
                f"--chaos spec {spec!r} is neither a preset "
                f"(churn, spot-wave, gray), a seed:<int>, nor a readable "
                f"JSON plan file")


def merge_plans(plans: Iterable[FaultPlan]) -> FaultPlan:
    """Union several plans into one time-sorted schedule."""
    events: List[FaultEvent] = []
    for p in plans:
        events.extend(p.events)
    return FaultPlan(events=tuple(events))
