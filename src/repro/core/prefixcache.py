"""Prefix-sharing block accounting: refcounts, a prefix trie over block
hashes, and LRU reuse of unreferenced cached blocks.

Production prompts share massive prefixes (system prompts, few-shot
templates, multi-turn resubmission), so a prompt's KV cache blocks are
content-addressable: block i of a prompt is identified by the HASH CHAIN
``key_i = H(key_{i-1}, tokens of block i)`` — equal chains mean equal
leading tokens, so a block written once can back every later prompt that
starts the same way. This module is the POLICY half of that idea, shared by
two owners ("evaluated is deployed", docs/ARCHITECTURE.md):

  * `repro.serving.kvcache.PagedKVCache` (``prefix_share=True``) pairs it
    with the real jnp block pools — `block_keys` hashes actual token ids;
  * `repro.sim.cluster.ClusterSim` uses it bare as each prefill instance's
    cache-residency model — keys come from `Request.prefix_hash`, populated
    by the trace generator.

Block lifecycle (the refcount lifecycle the leak test pins):

    FREE --acquire--> LIVE (refcount >= 1)
    LIVE --release--> FREE            (unregistered: content unreachable)
    LIVE --release--> CACHED          (registered in the trie, refcount 0:
                                       reusable by a later probe, evictable)
    CACHED --probe hit/acquire--> LIVE  (refcount bumps back up)
    CACHED --LRU eviction--> FREE       (capacity pressure only)

Eviction NEVER touches a block with refcount > 0 — a prompt mid-prefill or
mid-decode pins its blocks. Evicting a chain's parent before its child
merely truncates future probes at the hole (probe walks from the root and
stops at the first miss); the orphaned child ages out of the LRU on its own.

Conservation invariant (`check` — asserted by the hypothesis properties in
tests/test_prefix_cache.py and by the end-to-end leak tests): free +
distinct live + cached == num_blocks, with the three sets disjoint.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# chain root: the parent "key" of block 0 (any fixed value works; a non-zero
# constant keeps an all-zero token block from mapping to key 0)
_ROOT_KEY = 0x9E3779B9


def block_keys(tokens, block_size: int) -> Tuple[int, ...]:
    """Hash chain over the FULL blocks of a token-id sequence.

    Partial trailing blocks get no key: only full blocks are shareable
    (a partial block's future content depends on the suffix that completes
    it). crc32 over the raw int32 bytes, chained through the previous key,
    is deterministic across processes/versions — unlike `hash()`, which is
    salted for some types — and fast enough for the admission path.
    """
    arr = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32).reshape(-1))
    n_full = len(arr) // block_size
    keys: List[int] = []
    prev = _ROOT_KEY
    for i in range(n_full):
        blk = arr[i * block_size:(i + 1) * block_size]
        prev = zlib.crc32(blk.tobytes(), prev & 0xFFFFFFFF)
        keys.append(prev)
    return tuple(keys)


def chain_extend(parent: Sequence[int], materials: Sequence[int],
                 salt: int = 0) -> Tuple[int, ...]:
    """Extend a hash chain with synthetic per-block materials (the trace
    generator's key source — sim requests have no token ids). Deterministic
    integer mixing only; equal (parent, materials, salt) -> equal chain."""
    keys = list(parent)
    prev = keys[-1] if keys else _ROOT_KEY
    for m in materials:
        prev = zlib.crc32(np.int64(m ^ (salt << 17)).tobytes(),
                          prev & 0xFFFFFFFF)
        keys.append(prev)
    return tuple(keys)


class PrefixBlockManager:
    """Refcounted abstract block pool with a prefix trie and LRU reuse.

    Blocks are opaque ids ``0..num_blocks-1``; whatever data they name lives
    with the owner. A sequence acquires a *chain* of blocks: the longest
    registered prefix of its key chain is pinned (shared — refcount
    incremented), the rest come fresh from the free list, falling back to
    evicting least-recently-used CACHED (refcount-0, registered) blocks.
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks))
        self._ref: Dict[int, int] = {}                 # live block -> refcount
        self._trie: Dict[int, int] = {}                # chain key -> block
        self._key_of: Dict[int, int] = {}              # block -> chain key
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # cached blocks
        self._held: Dict[int, List[int]] = {}          # seq -> blocks in order
        self.hits = 0                                  # blocks served shared
        self.misses = 0                                # blocks computed fresh
        self.evictions = 0

    # ------------------------------------------------------------- inventory
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained in the trie (reusable, evictable)."""
        return len(self._lru)

    @property
    def live_blocks(self) -> int:
        return len(self._ref)

    def available(self) -> int:
        """Blocks an allocation could obtain: free + evictable."""
        return len(self._free) + len(self._lru)

    def holds(self, seq_id: int) -> bool:
        return seq_id in self._held

    def blocks_of(self, seq_id: int) -> List[int]:
        return list(self._held[seq_id])

    def grow(self, extra_blocks: int) -> None:
        if extra_blocks <= 0:
            return
        self._free.extend(range(self.num_blocks,
                                self.num_blocks + extra_blocks))
        self.num_blocks += extra_blocks

    def check(self) -> None:
        """Assert the conservation invariant (tests; cheap enough to call
        after every operation in the hypothesis properties)."""
        live = set(self._ref)
        free = set(self._free)
        cached = set(self._lru)
        assert len(free) == len(self._free), "free list duplicate"
        assert not (live & free) and not (live & cached) \
            and not (free & cached), "block in two states"
        assert len(free) + len(live) + len(cached) == self.num_blocks, (
            f"leak: {len(free)} free + {len(live)} live + "
            f"{len(cached)} cached != {self.num_blocks}")
        for keys_b, b in self._trie.items():
            assert self._key_of.get(b) == keys_b, "trie/key_of out of sync"
        held_all = [b for bs in self._held.values() for b in bs]
        from collections import Counter
        counts = Counter(held_all)
        assert dict(counts) == self._ref, "refcounts != held references"

    # ------------------------------------------------------------------ trie
    def probe(self, keys: Sequence[int]) -> List[int]:
        """Block ids of the longest registered chain prefix of `keys`.
        Read-only except for LRU recency (a probe is a touch)."""
        out: List[int] = []
        for k in keys:
            b = self._trie.get(k)
            if b is None:
                break
            out.append(b)
            if b in self._lru:
                self._lru.move_to_end(b)
        return out

    def probe_len(self, keys: Sequence[int]) -> int:
        return len(self.probe(keys))

    # ------------------------------------------------------------ allocation
    def _incref(self, b: int) -> None:
        if b in self._lru:
            del self._lru[b]                     # cached -> live
        self._ref[b] = self._ref.get(b, 0) + 1

    def _decref(self, b: int) -> None:
        self._ref[b] -= 1
        if self._ref[b] == 0:
            del self._ref[b]
            if b in self._key_of:
                self._lru[b] = None              # live -> cached (MRU end)
            else:
                self._free.append(b)             # live -> free

    def _take_block(self) -> Optional[int]:
        """A writable fresh block: free list first, then LRU eviction of a
        cached block (its trie entry is dropped — the content is gone)."""
        if self._free:
            return self._free.pop()
        if self._lru:
            b, _ = self._lru.popitem(last=False)
            del self._trie[self._key_of.pop(b)]
            self.evictions += 1
            return b
        return None

    def acquire(self, seq_id: int, keys: Sequence[int],
                total_blocks: int) -> int:
        """Pin the longest cached chain prefix of `keys` and allocate fresh
        blocks up to `total_blocks`. Returns the hit length in blocks.
        Raises MemoryError (with every pin rolled back) when the fresh part
        cannot be satisfied even after LRU eviction."""
        if seq_id in self._held:
            raise ValueError(f"seq {seq_id} already holds blocks")
        hit = self.probe(keys)[:total_blocks]
        for b in hit:
            self._incref(b)
        fresh: List[int] = []
        for _ in range(total_blocks - len(hit)):
            b = self._take_block()
            if b is None:
                for fb in fresh:
                    del self._ref[fb]        # rollback: live -> free, not both
                    self._free.append(fb)
                for hb in reversed(hit):
                    self._decref(hb)
                raise MemoryError(
                    f"prefix pool exhausted: need {total_blocks - len(hit)} "
                    f"fresh blocks, {self.available()} obtainable")
            fresh.append(b)
            self._ref[b] = 1
        self._held[seq_id] = hit + fresh
        self.hits += len(hit)
        self.misses += len(fresh)
        return len(hit)

    def lock_prefix(self, seq_id: int, keys: Sequence[int],
                    max_blocks: Optional[int] = None) -> int:
        """Pin ONLY the cached hit (no fresh allocation) — the simulator's
        arrival-time step: the hit must survive until the prefill that
        depends on it completes. Returns hit length in blocks."""
        if seq_id in self._held:
            raise ValueError(f"seq {seq_id} already holds blocks")
        hit = self.probe(keys)
        if max_blocks is not None:
            hit = hit[:max_blocks]
        for b in hit:
            self._incref(b)
        self._held[seq_id] = list(hit)
        self.hits += len(hit)
        return len(hit)

    def extend_seq(self, seq_id: int, n_blocks: int = 1) -> List[int]:
        """Append fresh blocks to a held chain (decode growth / suffix
        allocation at completion). Raises MemoryError when unobtainable."""
        got: List[int] = []
        for _ in range(n_blocks):
            b = self._take_block()
            if b is None:
                for fb in got:
                    self._free.append(fb)
                    self._held[seq_id].remove(fb)
                    del self._ref[fb]
                raise MemoryError("prefix pool exhausted on extend")
            self._ref[b] = 1
            self._held[seq_id].append(b)
            got.append(b)
        return got

    def make_private(self, seq_id: int, index: int) -> Tuple[int, bool]:
        """Copy-on-divergence: make block `index` of the seq's chain safely
        writable. Shared (refcount > 1) -> swap in a fresh block (returns
        ``(new_block, True)`` — the owner must copy the data over);
        exclusively held but registered -> unregister (the cached content is
        about to change); already private -> no-op. Returns
        ``(block, copied)``."""
        blocks = self._held[seq_id]
        b = blocks[index]
        if self._ref[b] == 1:
            if b in self._key_of:
                del self._trie[self._key_of.pop(b)]
                if b in self._lru:               # unreachable: live, not LRU
                    del self._lru[b]
            return b, False
        nb = self._take_block()
        if nb is None:
            raise MemoryError("prefix pool exhausted on copy-on-divergence")
        self._ref[nb] = 1
        self._decref(b)
        blocks[index] = nb
        return nb, True

    def register(self, seq_id: int, keys: Sequence[int]) -> int:
        """Insert the seq's leading blocks into the trie under `keys` (the
        completion-time step: the chain's content now exists). Keys already
        registered — the pinned hit, or a concurrent identical prompt — keep
        their existing mapping. Returns blocks newly registered."""
        blocks = self._held[seq_id]
        added = 0
        for k, b in zip(keys, blocks):
            if k in self._trie or b in self._key_of:
                continue
            self._trie[k] = b
            self._key_of[b] = k
            added += 1
        return added

    def commit(self, seq_id: int, keys: Sequence[int]) -> int:
        """Simulator completion path for a `lock_prefix`-ed seq: allocate a
        residency block for each still-unregistered tail key and register it
        DIRECTLY under that key (best-effort — stop when nothing is
        obtainable), then release every pin. Keys another chain registered
        meanwhile (a twin, or a surviving orphan of an evicted parent) are
        skipped without consuming a block — registration is per-key, never
        a positional zip, so a skipped middle key cannot shift later keys
        onto the wrong block. Returns blocks newly added to the cache."""
        held = self._held[seq_id]
        hit = len(held)                           # aligned with keys[:hit]
        added = 0
        for k in keys[hit:]:
            if k in self._trie:
                continue              # that position is already served
            b = self._take_block()
            if b is None:
                break                             # capacity: cache what fits
            self._ref[b] = 1
            held.append(b)
            self._trie[k] = b
            self._key_of[b] = k
            added += 1
        self.release(seq_id)
        return added

    def release(self, seq_id: int) -> None:
        """Drop every reference the seq holds: refcount-0 registered blocks
        park in the LRU cache, unregistered ones return to the free list."""
        for b in self._held.pop(seq_id):
            self._decref(b)
