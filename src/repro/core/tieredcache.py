"""Tiered prefix-cache residency: HBM -> host -> disk block offload.

`PrefixBlockManager` (repro.core.prefixcache) drops a refcount-0 block's
content at LRU capacity — every eviction is a future recompute. At
millions-of-users scale the shared-prefix working set dwarfs device memory,
so `TieredBlockManager` DEMOTES instead: an evicted block's chain key moves
to a host-memory tier (and, overflowing that, to an optional disk tier);
the HBM block itself is still reused immediately. A later probe that walks
past the warm (HBM-resident) run into a cold tier reports tier-tagged hit
lengths, and the owner decides whether to PROMOTE — reserve fresh HBM
blocks, copy the KV back, re-register the keys — priced against the
recompute the hit would otherwise save (the same transfer-vs-recompute
shape as cost-gated decode migration).

Like the parent class this is the POLICY half, shared by two owners
("evaluated is deployed"):

  * `repro.serving.kvcache.PagedKVCache` (``host_cache_blocks > 0``) pairs
    it with real jnp pools: demotion snapshots the block's K/V through the
    async `BlockCopyEngine` into checksummed host numpy storage (spilling
    to ``.npz`` files on disk), promotion verifies the checksum and
    scatters the data back — a corrupt or lost copy falls back to
    recompute, never serves stale KV;
  * `repro.sim.cluster.ClusterSim` (``host_cache_blocks > 0``) uses it bare
    as the tier-aware residency model: state moves are instantaneous and
    the promotion latency is priced by `PrefillCostModel.promote_time`
    (a delayed-arrival event).

Tier lifecycle (state machine; docs/ARCHITECTURE.md has the diagram):

    FREE / LIVE / CACHED                       (HBM — parent lifecycle)
    CACHED --LRU evict--> HOST                 (key demoted; block reused)
    HOST --host LRU overflow--> DISK           (disk_blocks > 0, else drop)
    DISK --disk LRU overflow--> dropped
    HOST|DISK --promote_begin--> IN_FLIGHT     (an HBM block is reserved)
    IN_FLIGHT --promote_commit--> CACHED       (copy landed, re-registered)
    IN_FLIGHT --promote_abort--> FREE          (+ key restored to its tier,
                                                or dropped when corrupt)

Tier-adjusted conservation (`check` — asserted by the hypothesis/fallback
property suites in tests/test_property.py and tests/test_tiered_kv.py):

    free + live + cached + in_flight == num_blocks      (HBM, disjoint)
    a chain key resides in AT MOST one place: trie (warm), in-flight,
    host, or disk; len(host) <= host_blocks, len(disk) <= disk_blocks.
    (One legal transient: a twin prompt registering a key whose promotion
    is still in flight — `promote_commit` resolves it by freeing the
    reserved block.)

Pinned (refcount > 0) blocks are never demoted: demotion's only source is
the LRU of refcount-0 CACHED blocks, exactly like the parent's eviction.
"""
from __future__ import annotations

import queue
import threading
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# tier tags (also the order of degradation: lower = hotter)
TIER_HBM, TIER_HOST, TIER_DISK = 0, 1, 2
TIER_NAMES = {TIER_HBM: "hbm", TIER_HOST: "host", TIER_DISK: "disk"}

from repro.core.prefixcache import PrefixBlockManager

__all__ = ["TIER_HBM", "TIER_HOST", "TIER_DISK", "TIER_NAMES", "TierHit",
           "TieredBlockManager", "BlockCopyEngine", "CopyJob",
           "TierDataError", "block_checksum"]


class TierDataError(Exception):
    """A stored tier copy is corrupt or lost (checksum mismatch, missing
    host entry, unreadable disk file). The promotion must abort-with-drop
    and the prefill falls back to recompute — stale KV is never served."""


def block_checksum(*arrays) -> int:
    """crc32 over the raw bytes of the block's K/V arrays — cheap integrity
    tag computed at demotion and verified at promotion (a host copy that
    rotted or was lost must fall back to recompute, never into the pool)."""
    crc = 0
    for a in arrays:
        crc = zlib.crc32(memoryview(a).cast("B"), crc)
    return crc & 0xFFFFFFFF


@dataclass(frozen=True)
class TierHit:
    """Tier-tagged probe result, in BLOCKS: the warm (HBM trie) run, then
    the contiguous cold run split by tier. ``host_blocks`` includes keys
    whose promotion is already in flight (they will be warm by the time a
    dependent prefill resumes)."""
    hbm_blocks: int = 0
    host_blocks: int = 0
    disk_blocks: int = 0

    @property
    def cold_blocks(self) -> int:
        return self.host_blocks + self.disk_blocks

    @property
    def total_blocks(self) -> int:
        return self.hbm_blocks + self.cold_blocks


class TieredBlockManager(PrefixBlockManager):
    """`PrefixBlockManager` whose LRU eviction demotes through host/disk
    tiers instead of dropping content, plus an explicit three-step
    promotion protocol (begin -> commit | abort) so an async copy engine
    can move the data while the reserved HBM block sits IN_FLIGHT.

    ``host_blocks == 0`` disables tiering entirely — every code path then
    reduces exactly to the parent (pinned by tests/test_tiered_kv.py), so
    the single-tier default stays bit-identical.

    Owner hooks (both optional; the sim uses neither):
      * ``on_demote(key, block, tier)`` — fires BEFORE the demoted HBM
        block is handed out for reuse (tier == TIER_HOST, block is the id
        whose data must be snapshotted now) and when a host entry spills
        to disk (tier == TIER_DISK, block is None — the owner moves its
        host copy);
      * ``on_drop(key, tier)`` — a cold-tier entry aged out; the owner
        frees its stored data.
    """

    def __init__(self, num_blocks: int, *, host_blocks: int = 0,
                 disk_blocks: int = 0,
                 on_demote: Optional[Callable[[int, Optional[int], int],
                                              None]] = None,
                 on_drop: Optional[Callable[[int, int], None]] = None):
        super().__init__(num_blocks)
        self.host_capacity = host_blocks
        self.disk_capacity = disk_blocks
        self.on_demote = on_demote
        self.on_drop = on_drop
        self._host: "OrderedDict[int, None]" = OrderedDict()  # key LRU
        self._disk: "OrderedDict[int, None]" = OrderedDict()  # key LRU
        self._promoting: Dict[int, int] = {}       # key -> reserved block
        self._promote_src: Dict[int, int] = {}     # key -> source tier
        self.demotions = 0                         # HBM -> host moves
        self.spills = 0                            # host -> disk moves
        self.tier_drops = 0                        # cold entries aged out
        self.promotions = 0                        # commits (blocks re-warmed)
        self.promote_aborts = 0

    # ------------------------------------------------------------- inventory
    @property
    def host_entries(self) -> int:
        return len(self._host)

    @property
    def disk_entries(self) -> int:
        return len(self._disk)

    @property
    def in_flight(self) -> int:
        """HBM blocks reserved for promotions still being copied."""
        return len(self._promoting)

    def check(self) -> None:
        """Tier-adjusted conservation (module docstring). Extends the parent
        invariant with the IN_FLIGHT state and key-exclusivity across
        tiers; cheap enough to call after every op in the property suites."""
        live = set(self._ref)
        free = set(self._free)
        cached = set(self._lru)
        inflight = set(self._promoting.values())
        assert len(free) == len(self._free), "free list duplicate"
        assert len(inflight) == len(self._promoting), \
            "one block reserved for two promotions"
        sets = (live, free, cached, inflight)
        for i, a in enumerate(sets):
            for b in sets[i + 1:]:
                assert not (a & b), "block in two states"
        assert len(free) + len(live) + len(cached) + len(inflight) \
            == self.num_blocks, (
                f"leak: {len(free)} free + {len(live)} live + "
                f"{len(cached)} cached + {len(inflight)} in_flight "
                f"!= {self.num_blocks}")
        for keys_b, b in self._trie.items():
            assert self._key_of.get(b) == keys_b, "trie/key_of out of sync"
        held_all = [b for bs in self._held.values() for b in bs]
        from collections import Counter
        assert dict(Counter(held_all)) == self._ref, \
            "refcounts != held references"
        # key exclusivity: warm, in-flight, host, disk are disjoint key sets
        # — with ONE legal transient: a twin prompt may register a key whose
        # promotion is still in flight (warm & in-flight overlap); the race
        # resolves at `promote_commit`, which frees the reserved block
        warm = set(self._trie)
        fly = set(self._promoting)
        host = set(self._host)
        disk = set(self._disk)
        for a, b in ((warm, host), (warm, disk), (fly, host), (fly, disk),
                     (host, disk)):
            assert not (a & b), "chain key in two tiers"
        assert fly == set(self._promote_src), "in-flight source tier lost"
        if self.host_capacity >= 0:
            assert len(host) <= self.host_capacity, "host tier over capacity"
        assert len(disk) <= self.disk_capacity, "disk tier over capacity"

    # ------------------------------------------------------------- demotion
    def _take_block(self) -> Optional[int]:
        """Parent semantics (free list, then LRU eviction) — but the evicted
        key's content is demoted to the host tier instead of vanishing.
        Pinned blocks are untouchable here by construction: only CACHED
        (refcount-0) blocks live in the LRU."""
        if self._free:
            return self._free.pop()
        if self._lru:
            b, _ = self._lru.popitem(last=False)
            key = self._key_of.pop(b)
            del self._trie[key]
            self.evictions += 1
            if self.host_capacity > 0:
                self._demote(key, b)
            return b
        return None

    def _demote(self, key: int, block: int) -> None:
        """key's content leaves HBM: enter the host tier (MRU), cascading
        host overflow into the disk tier and disk overflow into a drop.
        The owner's ``on_demote`` snapshot hook fires BEFORE this returns —
        i.e. before the freed HBM block can be reused."""
        self._disk.pop(key, None)          # exclusivity: host copy is fresher
        if self.on_demote is not None:
            self.on_demote(key, block, TIER_HOST)
        self._host[key] = None
        self._host.move_to_end(key)
        self.demotions += 1
        self._enforce_cold_capacity()

    def _enforce_cold_capacity(self) -> None:
        """Age out cold-tier overflow: host LRU spills into disk (when one
        exists, else drops), disk LRU drops. Called after every insertion
        into a cold tier — demotion AND a `promote_abort` restore (the tier
        may have filled up while the aborted copy was in flight)."""
        while len(self._host) > self.host_capacity:
            k2, _ = self._host.popitem(last=False)
            if self.disk_capacity > 0:
                if self.on_demote is not None:
                    self.on_demote(k2, None, TIER_DISK)
                self._disk[k2] = None
                self._disk.move_to_end(k2)
                self.spills += 1
            else:
                self.tier_drops += 1
                if self.on_drop is not None:
                    self.on_drop(k2, TIER_HOST)
        while len(self._disk) > self.disk_capacity:
            k3, _ = self._disk.popitem(last=False)
            self.tier_drops += 1
            if self.on_drop is not None:
                self.on_drop(k3, TIER_DISK)

    def _drop_cold(self, key: int) -> None:
        """A freshly computed copy of `key` is being registered: any cold
        copy is now redundant AND must leave its tier (key exclusivity) —
        the owner frees its stored data via ``on_drop``. An in-flight
        promotion of the key is left alone: `promote_commit` detects the
        twin registration and frees its reserved block."""
        for tier, store in ((TIER_HOST, self._host), (TIER_DISK, self._disk)):
            if key in store:
                del store[key]
                self.tier_drops += 1
                if self.on_drop is not None:
                    self.on_drop(key, tier)

    def register(self, seq_id: int, keys: Sequence[int]) -> int:
        """Parent `register`, plus tier exclusivity: each key actually
        registered supersedes (drops) its cold copy — the recompute path
        produced fresher content than the demoted snapshot."""
        blocks = self._held[seq_id]
        added = 0
        for k, b in zip(keys, blocks):
            if k in self._trie or b in self._key_of:
                continue
            self._drop_cold(k)
            self._trie[k] = b
            self._key_of[b] = k
            added += 1
        return added

    def commit(self, seq_id: int, keys: Sequence[int]) -> int:
        """Parent `commit` (simulator completion path), with the same
        supersede-cold-copy step per key newly registered."""
        held = self._held[seq_id]
        hit = len(held)
        added = 0
        for k in keys[hit:]:
            if k in self._trie:
                continue
            b = self._take_block()
            if b is None:
                break
            self._drop_cold(k)
            self._ref[b] = 1
            held.append(b)
            self._trie[k] = b
            self._key_of[b] = k
            added += 1
        self.release(seq_id)
        return added

    # -------------------------------------------------------------- probing
    def probe_tiers(self, keys: Sequence[int]) -> TierHit:
        """Tier-tagged hit lengths: the warm run (exactly `probe` — touches
        the HBM LRU), then the contiguous cold run classified per tier.
        A key whose promotion is in flight counts as a host hit (it is on
        its way up). Stops at the first key absent everywhere."""
        warm = len(self.probe(keys))
        host = disk = 0
        for k in keys[warm:]:
            if k in self._host or k in self._promoting:
                host += 1
                if k in self._host:
                    self._host.move_to_end(k)      # a probe is a touch
            elif k in self._disk:
                disk += 1
                self._disk.move_to_end(k)
            else:
                break
        return TierHit(hbm_blocks=warm, host_blocks=host, disk_blocks=disk)

    # ------------------------------------------------------------ promotion
    def promote_begin(self, keys: Sequence[int],
                      max_blocks: Optional[int] = None) \
            -> List[Tuple[int, int, int]]:
        """Reserve HBM blocks for the cold extension of `keys`' warm run.
        Each reservable cold key is popped from its tier and parked
        IN_FLIGHT on a freshly taken block (which may itself demote other
        cached keys — the key being promoted is popped FIRST so the cascade
        cannot age it out from under us). Keys already warm or already in
        flight are skipped (in-flight dedup); the walk stops at the first
        key absent everywhere or when the pool has nothing to give.

        Returns ``[(key, reserved_block, source_tier)]`` — the copy
        manifest. Every entry MUST eventually reach `promote_commit` or
        `promote_abort` (the property suites assert no in-flight leaks)."""
        out: List[Tuple[int, int, int]] = []
        budget = len(keys) if max_blocks is None else max_blocks
        for k in keys:
            if k in self._trie or k in self._promoting:
                continue                    # warm, or someone is on it
            if len(out) >= budget:
                break
            if k in self._host:
                tier = TIER_HOST
                del self._host[k]
            elif k in self._disk:
                tier = TIER_DISK
                del self._disk[k]
            else:
                break                       # cold run ends here
            b = self._take_block()
            if b is None:                   # pool exhausted: restore, stop
                tgt = self._host if tier == TIER_HOST else self._disk
                tgt[k] = None
                break
            self._promoting[k] = b
            self._promote_src[k] = tier
            out.append((k, b, tier))
        return out

    def promote_commit(self, key: int) -> Optional[int]:
        """The copy landed: the reserved block becomes CACHED (refcount 0,
        MRU) and the key re-registers in the trie. Returns the block — or
        None when a twin prompt registered the key meanwhile (the reserved
        block is freed; the twin's copy is the live one)."""
        b = self._promoting.pop(key)
        del self._promote_src[key]
        if key in self._trie:
            self._free.append(b)
            return None
        self._trie[key] = b
        self._key_of[b] = key
        self._lru[b] = None
        self.promotions += 1
        return b

    def promote_abort(self, key: int, corrupt: bool = False) -> None:
        """The copy failed or was cancelled: free the reserved block. The
        key returns to its source tier (MRU — it is still the best copy we
        have) unless ``corrupt``, in which case it is dropped outright:
        a checksum-mismatched copy must never be probed into again."""
        b = self._promoting.pop(key)
        tier = self._promote_src.pop(key)
        self._free.append(b)
        self.promote_aborts += 1
        if corrupt:
            self.tier_drops += 1
            if self.on_drop is not None:
                self.on_drop(key, tier)
            return
        if key in self._trie:
            return                          # twin raced us in: nothing to keep
        tgt = self._host if tier == TIER_HOST else self._disk
        tgt[key] = None
        tgt.move_to_end(key)
        self._enforce_cold_capacity()       # the tier may have filled since


# ---------------------------------------------------------------------------
# Async block-copy engine
# ---------------------------------------------------------------------------


class CopyJob:
    """One tier transfer. ``wait`` blocks until the worker ran it (or the
    engine shut down); ``result`` / ``error`` carry the outcome. Jobs are
    deduplicated per (kind, key) while in flight, so callers may hold the
    same job object."""

    __slots__ = ("kind", "key", "fn", "done", "result", "error")

    def __init__(self, kind: str, key: int, fn: Callable[[], object]):
        self.kind = kind
        self.key = key
        self.fn = fn
        self.done = threading.Event()
        self.result: object = None
        self.error: Optional[BaseException] = None

    @property
    def ok(self) -> bool:
        return self.done.is_set() and self.error is None

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.done.wait(timeout)


class _Shutdown(Exception):
    """Marks jobs cancelled by engine shutdown (drained, not run)."""


class BlockCopyEngine:
    """Bounded background worker for tier transfers (demotions, disk
    spills, promotions) with per-(kind, key) in-flight dedup.

    ONE worker thread by default: per-key ordering then falls out of FIFO
    submission (a key's host snapshot lands before its disk spill or its
    promotion reads it), which is exactly the dependency chain the tiered
    `PagedKVCache` relies on. The queue is bounded — a submitter that
    outruns the copy bandwidth blocks briefly instead of buffering
    unboundedly (backpressure, not OOM).

    `shutdown` drains cleanly: queued-but-unrun jobs complete with a
    `_Shutdown` error so every waiter wakes and every reserved block can be
    aborted back to the pool — no leaked blocks, no hung prefill
    (tests/test_tiered_kv.py fault-injection suite).

    Fault-injection hooks (tests only): ``fail_keys`` makes the worker
    error any job touching those keys; ``delay_s`` sleeps before each job
    (to hold transfers in flight across a shutdown)."""

    def __init__(self, workers: int = 1, max_queue: int = 256):
        self._q: "queue.Queue[Optional[CopyJob]]" = queue.Queue(max_queue)
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple[str, int], CopyJob] = {}
        self._closed = False
        self.completed = 0
        self.failed = 0
        self.fail_keys: set = set()
        self.delay_s: float = 0.0
        self._threads = [
            threading.Thread(target=self._run, daemon=True,
                             name=f"block-copy-{i}")
            for i in range(max(workers, 1))]
        for t in self._threads:
            t.start()

    # ---------------------------------------------------------------- submit
    def submit(self, kind: str, key: int,
               fn: Callable[[], object]) -> CopyJob:
        """Enqueue a transfer; an identical in-flight (kind, key) job is
        returned instead of queuing a duplicate copy."""
        with self._lock:
            if self._closed:
                job = CopyJob(kind, key, fn)
                job.error = _Shutdown("engine closed")
                job.done.set()
                return job
            existing = self._inflight.get((kind, key))
            if existing is not None:
                return existing
            job = CopyJob(kind, key, fn)
            self._inflight[(kind, key)] = job
        self._q.put(job)
        return job

    def _finish(self, job: CopyJob) -> None:
        with self._lock:
            cur = self._inflight.get((job.kind, job.key))
            if cur is job:
                del self._inflight[(job.kind, job.key)]
        job.done.set()

    def _run(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                self._q.task_done()
                return
            try:
                if self.delay_s > 0:
                    # fault injection: keep the transfer "on the wire"
                    import time as _time
                    _time.sleep(self.delay_s)
                if self._closed:
                    raise _Shutdown("engine closed with transfer in flight")
                if job.key in self.fail_keys:
                    raise IOError(f"injected copy failure for key {job.key}")
                job.result = job.fn()
                self.completed += 1
            except BaseException as e:      # noqa: BLE001 — jobs never raise
                job.error = e
                self.failed += 1
            finally:
                self._finish(job)
                self._q.task_done()

    # ----------------------------------------------------------------- drain
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait for every queued job to finish. True when the queue emptied
        within `timeout` (None = wait forever)."""
        if timeout is None:
            self._q.join()
            return True
        import time as _time
        deadline = _time.monotonic() + timeout
        while _time.monotonic() < deadline:
            with self._lock:
                if not self._inflight:
                    return True
            _time.sleep(0.002)
        with self._lock:
            return not self._inflight

    def shutdown(self, wait: bool = True, timeout: float = 10.0) -> None:
        """Stop accepting work and drain. Jobs still queued when the flag
        flips complete with a `_Shutdown` error (their waiters wake and
        abort their reservations) — a clean drain, never a hang."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._threads:
            self._q.put(None)
        if wait:
            for t in self._threads:
                t.join(timeout)
