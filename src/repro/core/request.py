"""Request model shared by the scheduler core, the real serving runtime, and
the discrete-event simulator."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

_rid_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    DONE = "done"
    DROPPED = "dropped"


@dataclass
class Request:
    num_tokens: int                      # prompt length
    slo: float                           # TTFT SLO (seconds)
    arrival: float = 0.0
    task_type: str = "text"
    rid: int = field(default_factory=lambda: next(_rid_counter))
    state: RequestState = RequestState.WAITING

    # runtime-owned progress (operator granularity)
    ops_done: int = 0                    # operators completed so far
    ops_total: int = 0                   # set when execution plan is known
    tokens_done: int = 0                 # prefill tokens fully processed (chunking)

    # batching: rids co-executing with this request (paper Alg. 1)
    batch_members: List[int] = field(default_factory=list)
    batch_tokens: int = 0                # aggregate token count of the batch

    # prefix sharing: the prompt's block hash chain (one key per FULL
    # kv-cache block, repro.core.prefixcache.block_keys semantics) — the
    # dispatch-visible signal prefix-affinity routes on. None = opaque
    # prompt (no sharing possible). Populated by the trace generator (sim)
    # or derived from token ids (runtime).
    prefix_hash: Optional[Tuple[int, ...]] = None
    # tokens of this prompt served from the prefix cache of the instance it
    # was dispatched to (set at dispatch; 0 = cold). Runtime-owned.
    prefix_hit: int = 0

    # decode phase (cluster-level end-to-end accounting; 0 = prefill-only)
    output_tokens: int = 0               # tokens to decode after prefill
    tbt_slo: float = float("inf")        # per-token TBT/TPOT SLO (seconds)
    decode_start: Optional[float] = None  # first decode admission/enqueue time
    decode_migrations: int = 0           # times this decode moved instances
    decode_preemptions: int = 0          # times this decode was displaced
    # speculative decoding (sim): per-token draft accept probability for this
    # stream's fluid accept surface (repro.core.predictor
    # .expected_accept_tokens). 0.0 = drafts never accepted (plain-rate).
    spec_accept: float = 0.0

    # fault recovery (instance churn): times this request was stranded by a
    # failing instance and re-dispatched (KV lost -> recompute); the retry
    # budget caps it. shed=True means admission control rejected it outright
    # (state DROPPED, never dispatched) — distinct from retries-exhausted.
    retries: int = 0
    shed: bool = False

    # outcome
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    mean_tpot: Optional[float] = None    # observed mean time-per-output-token

    def __post_init__(self):
        if self.batch_tokens == 0:
            self.batch_tokens = self.num_tokens

    @property
    def deadline(self) -> float:
        return self.arrival + self.slo

    @property
    def decode_deadline(self) -> float:
        """Decode-phase deadline: the TBT SLO is met iff the decode finishes
        by first-join + output_tokens * tbt_slo (mean-TPOT basis), so that
        instant IS the deadline the decode S-EDF scheduler ranks by. Infinite
        until the decode is first enqueued or for prefill-only requests."""
        if self.decode_start is None or self.output_tokens <= 0:
            return float("inf")
        return self.decode_start + self.output_tokens * self.tbt_slo

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def slo_met(self) -> bool:
        return self.ttft is not None and self.ttft <= self.slo + 1e-9

    @property
    def tbt_met(self) -> bool:
        """Decode-phase SLO: mean time-per-output-token within the TBT SLO
        (vacuously true for prefill-only requests)."""
        if self.output_tokens <= 0:
            return True
        return self.mean_tpot is not None and \
            self.mean_tpot <= self.tbt_slo + 1e-9

    @property
    def e2e_met(self) -> bool:
        """End-to-end goodness: TTFT SLO and decode TBT SLO both attained."""
        return self.slo_met and self.tbt_met

    def remaining_fraction(self) -> float:
        """Fraction of prefill work left (1.0 = untouched)."""
        if self.ops_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.ops_done / self.ops_total)

    def remaining_tokens(self) -> float:
        """Token-equivalent remaining work, used by the TTFT predictor.
        (Inlined remaining_fraction — this runs once per queued request per
        scheduling round, the simulator's hottest per-element path.)"""
        ot = self.ops_total
        if ot <= 0:
            return self.batch_tokens * 1.0
        frac = 1.0 - self.ops_done / ot
        return self.batch_tokens * frac if frac > 0.0 else 0.0
