"""Event queue for event-driven scheduling (FlowPrefill §5.2).

Only two event kinds exist by design — ARRIVAL and COMPLETION — so the number
of scheduling rounds is bounded by 2x the number of requests (§6.4 scheduling
cost analysis). The real runtime's Event Monitor blocks on this queue; the
simulator uses its own time-ordered heap and calls the same SchedulerCore.
"""
from __future__ import annotations

import enum
import itertools
import queue
from dataclasses import dataclass, field
from typing import Any, Optional


class EventKind(enum.Enum):
    ARRIVAL = "arrival"
    COMPLETION = "completion"
    SHUTDOWN = "shutdown"
    # chaos-harness injection (core/faults.py): payload is an Exception to
    # raise inside the scheduler loop (crash) or ("hang", seconds) to stall
    # it. Never emitted by normal serving; scheduling-round bound unchanged.
    FAULT = "fault"


_seq = itertools.count()


@dataclass(order=True)
class Event:
    time: float
    seq: int = field(default_factory=lambda: next(_seq))
    kind: EventKind = field(compare=False, default=EventKind.ARRIVAL)
    payload: Any = field(compare=False, default=None)


class EventMonitor:
    """Thread-safe FIFO the Scheduler blocks on. Each consumed event triggers
    exactly one scheduling round."""

    def __init__(self):
        self._q: "queue.Queue[Event]" = queue.Queue()
        self.rounds = 0                   # scheduling rounds triggered
        self.counts = {k: 0 for k in EventKind}

    def publish(self, event: Event) -> None:
        self.counts[event.kind] += 1
        self._q.put(event)

    def next_event(self, timeout: Optional[float] = None) -> Optional[Event]:
        try:
            ev = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        self.rounds += 1
        return ev

    def qsize(self) -> int:
        return self._q.qsize()
