"""Instance-level dispatch policies (cluster layer).

Pure policy logic, shared — like SchedulerCore — between the real runtime
(repro/serving/proxy.py) and the discrete-event cluster simulator
(repro/sim/cluster.py), so the dispatch policy evaluated in simulation is the
one deployed.

A policy sees a per-instance load snapshot (`InstanceLoad`) taken *relative to
the arriving request* and picks the instance for it:

  * ``round-robin``  — the paper's §4 proxy baseline (blind cycling).
  * ``least-loaded`` — join-shortest-predicted-queue: pick the instance whose
    predicted TTFT for the newcomer (TTFTPredictor over the instance's
    outstanding competing tokens plus the newcomer's) is smallest.  Follows
    the load-aware direction of arXiv 2605.02329 (SLO-aware scheduling for
    disaggregated inference).
  * ``deflection``   — slack-aware deflection (arXiv 2607.02043): keep the
    round-robin default target, but when the target's backlog (its running
    head plus queue) would eat too much of the newcomer's slack, deflect to a
    feasible instance; with none feasible, take the least predicted TTFT.
  * ``capacity-weighted`` — heterogeneous-pool JSQ: rank instances by
    *drain time* = outstanding tokens normalized by the instance's peak
    prefill throughput (`InstanceLoad.capacity`), so a mixed A800/A100/TPU
    pool routes proportionally more work to faster hardware instead of
    equalizing raw token backlogs.
  * ``decode-aware`` — capacity-weighted drain time, inflated when the
    instance's downstream decode stage is near its TBT-SLO knee
    (`InstanceLoad.decode_pressure`, fed from `DecodeCostModel.step_time`):
    prefills are deflected away from instances whose decode batch would blow
    the token-by-token SLO right after handoff (the load-aware prefill
    deflection direction of arXiv 2607.02043 applied to downstream pressure).
  * ``prefix-affinity`` — the decode-aware score MINUS the predicted TTFT
    saved by the instance's prefix cache (`InstanceLoad.ttft_saved`, priced
    by the owner from its per-instance predictor/residency model): route a
    request to the instance already holding its prompt prefix's KV — unless
    that instance's queue pressure outweighs the recompute saved. Affinity
    deliberately concentrates load where prefixes live, so the queue term
    (drain time, which grows with backlog) is what keeps it from re-creating
    the hotspot problem load-aware deflection exists to solve; with no hits
    anywhere the score degrades exactly to decode-aware/capacity-weighted.

The load measure matters: under S-EDF with cheap operator-level preemption,
a long or already-doomed (negative-slack) request in an instance's queue does
NOT delay a short strict-SLO newcomer — it gets preempted or ranked below.
`competing_tokens` therefore counts only work that would actually run before
the newcomer: outstanding items with an earlier deadline that are themselves
still feasible.  (Raw aggregate tokens make join-shortest-queue *worse* than
round-robin here: doomed long requests repel traffic from instances that
would serve it instantly.)

Decode-side rebalancing lives here too: `DecodeLoad` snapshots a decode
instance's continuous batch + admission queue, and `plan_decode_migrations`
produces a cost-gated plan for moving QUEUED decodes off an instance whose
effective TBT pressure has crossed the SLO knee — the decode-aware policy's
dispatch-time avoidance turned into a run-time correction. The same plan
function drives `ClusterSim` (KV-handoff priced by the cost model) and the
real `Proxy` (host-memory handoff). Policy-by-policy rationale and the
figures demonstrating each live in docs/SCHEDULING.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.core.request import Request


@dataclass(frozen=True)
class InstanceLoad:
    """Snapshot of one prefill instance's backlog, relative to a candidate
    request (see `competing_tokens`). Built fresh per dispatch decision by the
    owner (Proxy / ClusterSim); policies never mutate it."""
    instance_id: int
    queued_tokens: float = 0.0           # competing waiting+preempted tokens
    running_tokens: float = 0.0          # competing in-flight tokens
    n_outstanding: int = 0
    # heterogeneous pools: instance peak prefill throughput (tokens/s).
    # 1.0 = unknown/uniform — capacity-weighted then degrades to raw-token JSQ.
    capacity: float = 1.0
    # downstream decode TBT pressure were this request's decode to join now:
    # predicted step time / TBT SLO (1.0 = exactly at the SLO knee)
    decode_pressure: float = 0.0
    # prefix sharing: tokens of THIS request's prompt cached at the
    # instance, and the predicted seconds of prefill service time that hit
    # would save (owner-priced: predictor(n) - predictor(n - hit)). With a
    # tiered cache `prefix_hit` is the EFFECTIVE hit (warm + cold tokens the
    # owner decided to promote) and `ttft_saved` is already NET of the
    # promotion copy time — warm, cold, and absent are three prices, not a
    # binary hit bit, but the policy score needs no tier awareness.
    prefix_hit: int = 0
    ttft_saved: float = 0.0
    # tier observability: cold (host/disk-resident) tokens behind the warm
    # run, and the predicted copy time to promote them (0 when untiered)
    prefix_hit_cold: int = 0
    promote_time: float = 0.0

    @property
    def outstanding_tokens(self) -> float:
        return self.queued_tokens + self.running_tokens


def competing_tokens(items: Iterable[Tuple[float, float]],
                     candidate: Request, now: float,
                     predict: Optional[Callable[[float], float]]) -> float:
    """Backlog that would run BEFORE `candidate` under S-EDF: the sum of
    remaining tokens over `items` (pairs of (remaining_tokens, deadline))
    whose deadline is earlier than the candidate's and which are still
    feasible (positive slack) — infeasible work ranks below any feasible
    newcomer and preemptable work yields within one operator.

    Built per dispatch decision for EVERY instance, so large backlogs batch
    the predictions through the predictor's `predict_many` (bit-identical:
    same elementwise Horner, same sequential accumulation order)."""
    items = list(items)
    if predict is not None and len(items) >= 8:
        pm = getattr(getattr(predict, "__self__", None), "predict_many", None)
        if pm is not None:
            k = len(items)
            rems = np.fromiter((it[0] for it in items), np.float64, k)
            dls = np.fromiter((it[1] for it in items), np.float64, k)
            keep = (dls <= candidate.deadline) & (dls - now - pm(rems) > 0)
            n = 0.0
            for v in rems[keep].tolist():
                n += v
            return n
    n = 0.0
    for rem, deadline in items:
        if deadline > candidate.deadline:
            continue
        lat = predict(rem) if predict is not None else 0.0
        if deadline - now - lat > 0:
            n += rem
    return n


def drain_time(req: Request, load: InstanceLoad) -> float:
    """Capacity-normalized backlog: seconds for `load`'s instance to drain its
    competing work plus the newcomer at peak throughput. With the default
    capacity of 1.0 this is just raw tokens (monotone, so homogeneous pools
    behave like token-JSQ)."""
    return (load.outstanding_tokens + req.num_tokens) / max(load.capacity,
                                                            1e-9)


def predicted_ttft(req: Request, load: InstanceLoad,
                   predictor: Optional[TTFTPredictor]) -> float:
    """Predicted TTFT were `req` dispatched to `load`'s instance now: the
    predictor evaluated over the instance's competing tokens plus the
    newcomer's (a serial-drain estimate; with no predictor, raw tokens act as
    the time proxy — monotone, which is all least-loaded needs)."""
    n = load.outstanding_tokens + req.num_tokens
    if predictor is None:
        return float(n)
    return predictor.predict(n)


class DispatchPolicy:
    """Picks an instance id for one request given per-instance load."""
    name = "base"
    needs_loads = True        # False: owner may pass zeroed load snapshots
    needs_decode_pressure = False  # True: owner attaches decode_pressure
                                   # (and pairs prefill->decode instances)
    needs_prefix = False      # True: owner attaches prefix_hit/ttft_saved
                              # from its per-instance residency model

    def __init__(self, predictor: Optional[TTFTPredictor] = None):
        self.predictor = predictor

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        raise NotImplementedError


class RoundRobinDispatch(DispatchPolicy):
    name = "round-robin"
    needs_loads = False       # blind cycling: only len(loads) matters

    def __init__(self, predictor: Optional[TTFTPredictor] = None):
        super().__init__(predictor)
        self._next = 0

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        i = self._next % len(loads)
        self._next += 1
        return loads[i].instance_id


class LeastLoadedDispatch(DispatchPolicy):
    name = "least-loaded"

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        return min(loads, key=lambda ld: (predicted_ttft(req, ld,
                                                         self.predictor),
                                          ld.instance_id)).instance_id


class DeflectionDispatch(DispatchPolicy):
    """Slack-aware deflection: round-robin default target, deflected when the
    newcomer's predicted TTFT there would consume more than `slack_margin` of
    its slack. The small default margin deflects *early*: by the time the
    predicted TTFT reaches the full slack it is too late to recover under
    bursty arrivals (headroom is what absorbs the burst)."""
    name = "deflection"

    def __init__(self, predictor: Optional[TTFTPredictor] = None,
                 slack_margin: float = 0.25):
        super().__init__(predictor)
        self._next = 0
        self.slack_margin = slack_margin    # fraction of slack we may consume

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        i = self._next % len(loads)
        self._next += 1
        budget = (req.deadline - now) * self.slack_margin
        primary = loads[i]
        if predicted_ttft(req, primary, self.predictor) <= budget:
            return primary.instance_id
        feasible = [ld for ld in loads
                    if predicted_ttft(req, ld, self.predictor) <= budget]
        pool = feasible or list(loads)
        return min(pool, key=lambda ld: (predicted_ttft(req, ld,
                                                        self.predictor),
                                         ld.instance_id)).instance_id


class CapacityWeightedDispatch(DispatchPolicy):
    """Capacity-weighted JSQ for heterogeneous pools: join the instance whose
    backlog drains fastest AT ITS OWN SPEED. Raw-token JSQ equalizes token
    backlogs, which on mixed hardware means the slow instance's equal-sized
    queue takes longer to clear — its requests burn SLO slack in line. Peak
    throughput as the normalizer is deliberately workload-independent: it
    needs one offline number per hardware generation, not a per-request
    latency model."""
    name = "capacity-weighted"

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        return min(loads, key=lambda ld: (drain_time(req, ld),
                                          ld.instance_id)).instance_id


class DecodeAwareDispatch(DispatchPolicy):
    """Capacity-weighted drain time, inflated by downstream decode pressure.

    An instance whose paired decode stage sits near its TBT-SLO knee will
    violate the token-by-token SLO for any prefill handed to it — routing by
    prefill backlog alone green-lights requests into a decode stage that
    dooms them. The score multiplies drain time by (1 + penalty * excess),
    excess = max(0, decode_pressure - knee): below the knee decode is free
    capacity and the policy IS capacity-weighted JSQ; above it the instance
    is repelled in proportion to how deep into the knee its decode sits.
    Multiplicative (not additive) so the penalty needs no absolute scale —
    drain time already carries the units, and the newcomer's own tokens keep
    it nonzero even on an idle pool."""
    name = "decode-aware"
    needs_decode_pressure = True

    def __init__(self, predictor: Optional[TTFTPredictor] = None,
                 knee: float = 0.85, penalty: float = 8.0):
        super().__init__(predictor)
        self.knee = knee                 # pressure fraction where TBT binds
        self.penalty = penalty           # repulsion strength past the knee

    def _score(self, req: Request, ld: InstanceLoad) -> float:
        excess = max(0.0, ld.decode_pressure - self.knee)
        return drain_time(req, ld) * (1.0 + self.penalty * excess)

    def select(self, req: Request, loads: Sequence[InstanceLoad],
               now: float) -> int:
        return min(loads, key=lambda ld: (self._score(req, ld),
                                          ld.instance_id)).instance_id


class PrefixAffinityDispatch(DecodeAwareDispatch):
    """Prefix-cache-affinity dispatch: the decode-aware score minus the
    predicted TTFT saved by each instance's cached prefix of THIS prompt.

    score(i) = drain_time * (1 + penalty * decode excess)
               - affinity_weight * ttft_saved(i)

    Both terms are seconds (drain time is capacity-normalized backlog;
    ttft_saved is predictor-priced recompute), so `affinity_weight` is a
    pure preference knob: 1.0 trades a second of queueing for a second of
    saved prefill. The subtraction — not a hard affinity pin — is the
    load-aware deflection tension: once the prefix-holding instance's
    backlog exceeds the saving, colder instances win and the affinity
    stream SPILLS, spreading the hot prefix to a second cache instead of
    melting the first (cf. load-aware prefill deflection, arXiv 2607.02043).
    With zero hits everywhere this IS decode-aware dispatch (and, with no
    decode pressure attached, capacity-weighted JSQ)."""
    name = "prefix-affinity"
    needs_prefix = True

    def __init__(self, predictor: Optional[TTFTPredictor] = None,
                 knee: float = 0.85, penalty: float = 8.0,
                 affinity_weight: float = 1.0):
        super().__init__(predictor, knee=knee, penalty=penalty)
        self.affinity_weight = affinity_weight

    def _score(self, req: Request, ld: InstanceLoad) -> float:
        return super()._score(req, ld) - self.affinity_weight * ld.ttft_saved


DISPATCH_POLICIES = {
    p.name: p for p in
    (RoundRobinDispatch, LeastLoadedDispatch, DeflectionDispatch,
     CapacityWeightedDispatch, DecodeAwareDispatch, PrefixAffinityDispatch)
}


# ---------------------------------------------------------------------------
# Decode migration (cost-gated rebalancing of queued decodes)
# ---------------------------------------------------------------------------


@dataclass
class DecodeLoad:
    """Snapshot of one decode instance for migration planning: the continuous
    batch (`n_resident`, capped at `max_batch` slots), the admission queue
    (`n_waiting`), and the aggregate context those streams hold. Built fresh
    per planning decision by the owner (ClusterSim / Proxy)."""
    instance_id: int
    n_resident: int = 0
    n_waiting: int = 0
    ctx_tokens: float = 0.0        # total context (prompt + decoded) held
    max_batch: int = 0             # batch slot cap; 0 = unbounded
    step_time: Optional[Callable[[int, float], float]] = None

    @property
    def total(self) -> int:
        return self.n_resident + self.n_waiting

    def effective_step(self, extra_jobs: int = 0,
                       extra_ctx: float = 0.0) -> float:
        """Predicted effective per-token latency of one stream on this
        instance with `extra_jobs` streams added (negative = removed): the
        analytic step time of the slot-capped batch, inflated by the
        time-sharing factor N/max_batch once the population N exceeds the cap
        — B slots shared by N streams serve each at B/N of the batch rate, so
        queueing shows up as TBT degradation, the signal the knee is defined
        on. Uncapped instances never queue: the factor is exactly 1."""
        n = self.total + extra_jobs
        if n <= 0 or self.step_time is None:
            return 0.0
        b = min(n, self.max_batch) if self.max_batch > 0 else n
        t = self.step_time(b, (self.ctx_tokens + extra_ctx) / n)
        if self.max_batch > 0 and n > self.max_batch:
            t *= n / self.max_batch
        return t


@dataclass(frozen=True)
class DecodeCandidate:
    """One queued (not yet resident) decode considered for migration."""
    key: int                       # owner handle (request rid)
    context_tokens: float          # KV to hand off (prompt + decoded so far)
    remaining_tokens: float        # output tokens still to decode
    deadline: float                # Request.decode_deadline
    migrations: int = 0            # times already migrated


def plan_decode_migrations(
        src: DecodeLoad, candidates: Sequence[DecodeCandidate],
        loads: Sequence[DecodeLoad], now: float, *,
        transfer_time: Optional[Callable[[float], float]] = None,
        knee: float = 0.85, max_migrations: int = 1,
        margin: float = 0.25) -> List[Tuple[int, int, float]]:
    """Cost-gated plan for migrating queued decodes off a saturating `src`.

    For each candidate (earliest decode deadline first) the per-token budget
    is its REMAINING slack rate, (deadline - now) / remaining_tokens; `src` is
    saturating for that stream when its effective step time exceeds
    ``knee * budget``. Every gate below must hold, so a pool in which every
    instance sits past the knee produces an EMPTY plan (no thrash):

      * the candidate still has a finite deadline, positive budget, and fewer
        than `max_migrations` prior moves (KV churn cap);
      * the best destination, with the migrated stream's context added, stays
        at or below the knee for that stream;
      * the predicted finish at the destination — including the KV-handoff
        time `transfer_time(context_tokens)` plus a `margin` multiple of it
        as hysteresis — beats the predicted finish at `src`.

    Planned moves update the running tallies on both sides, so one planning
    pass cannot dump every queued stream onto the same target, and draining
    `src` below the knee stops further moves.

    Returns ``[(candidate key, destination instance_id, transfer seconds)]``.
    """
    others = [ld for ld in loads if ld.instance_id != src.instance_id]
    if not others:
        return []
    extra = {ld.instance_id: [0, 0.0] for ld in others}
    moved_jobs, moved_ctx = 0, 0.0
    plan: List[Tuple[int, int, float]] = []
    for cand in sorted(candidates, key=lambda c: (c.deadline, c.key)):
        if cand.migrations >= max_migrations:
            continue
        if not math.isfinite(cand.deadline) or cand.remaining_tokens <= 0:
            continue
        budget = (cand.deadline - now) / cand.remaining_tokens
        if budget <= 0:
            continue                # already doomed: a transfer can't save it
        t_src = src.effective_step(-moved_jobs, -moved_ctx)
        if t_src <= knee * budget:
            continue                # src under the knee for this stream
        xfer = transfer_time(cand.context_tokens) if transfer_time else 0.0
        best: Optional[Tuple[DecodeLoad, float]] = None
        for ld in others:
            ej, ec = extra[ld.instance_id]
            t_dst = ld.effective_step(1 + ej, cand.context_tokens + ec)
            if t_dst > knee * budget:
                continue            # destination would be saturated too
            finish_dst = now + xfer + cand.remaining_tokens * t_dst
            if best is None or finish_dst < best[1]:
                best = (ld, finish_dst)
        if best is None:
            continue                # every destination past the knee: no move
        finish_src = now + cand.remaining_tokens * t_src
        if best[1] + margin * xfer >= finish_src:
            continue                # benefit doesn't clear the handoff cost
        plan.append((cand.key, best[0].instance_id, xfer))
        extra[best[0].instance_id][0] += 1
        extra[best[0].instance_id][1] += cand.context_tokens
        moved_jobs += 1
        moved_ctx += cand.context_tokens
    return plan


def make_dispatch(policy: Union[str, DispatchPolicy],
                  predictor: Optional[TTFTPredictor] = None,
                  **kwargs) -> DispatchPolicy:
    """`policy` may also be a ready-made DispatchPolicy (passed through,
    adopting `predictor` if it has none)."""
    if isinstance(policy, DispatchPolicy):
        if policy.predictor is None:
            policy.predictor = predictor
        return policy
    try:
        cls = DISPATCH_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown dispatch policy {policy!r}; "
            f"known: {sorted(DISPATCH_POLICIES)}") from None
    return cls(predictor=predictor, **kwargs)
