from repro.core.dispatch import (DISPATCH_POLICIES, DecodeLoad, DispatchPolicy,
                                 InstanceLoad, make_dispatch,
                                 plan_decode_migrations)
from repro.core.events import Event, EventKind, EventMonitor
from repro.core.metrics import (attainment_by_task, max_goodput, min_slo_scale,
                                percentile_goodput, percentile_report,
                                slo_attainment, slo_frac_percentile,
                                tbt_stats, ttft_stats)
from repro.core.predictor import (DecodeStepPredictor, OnlineTTFTPredictor,
                                  TTFTPredictor)
from repro.core.preemption import BlockingStats, PreemptionSignal, SyncCounter
from repro.core.request import Request, RequestState
from repro.core.scheduler import (Action, Decision, DecodeEntry,
                                  DecodeSchedulerCore, HybridSchedulerCore,
                                  HybridStepPlan, PrefillSlice, SchedulerCore,
                                  decode_sedf_priority, slo_aware_batching)
