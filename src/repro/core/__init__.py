from repro.core.dispatch import (DISPATCH_POLICIES, DispatchPolicy,
                                 InstanceLoad, make_dispatch)
from repro.core.events import Event, EventKind, EventMonitor
from repro.core.metrics import (attainment_by_task, max_goodput, min_slo_scale,
                                slo_attainment, ttft_stats)
from repro.core.predictor import TTFTPredictor
from repro.core.preemption import BlockingStats, PreemptionSignal, SyncCounter
from repro.core.request import Request, RequestState
from repro.core.scheduler import (Action, Decision, SchedulerCore,
                                  slo_aware_batching)
