"""AdamW from scratch (no optax on this system — and the spec wants the
substrate built, not imported). Optimizer state is a pytree mirroring params
(m, v in f32) and is ZeRO-shardable: distributed/sharding.py maps its leaves
with the same logical axes as the params plus the 'zero' rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array                     # i32 scalar
    m: Any                              # pytree like params (f32)
    v: Any                              # pytree like params (f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    """One AdamW step with global-norm clipping. Returns (params', state',
    metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def opt_state_axes(params_axes) -> Any:
    """Logical axes for AdamWState given the params' axes (ZeRO: same layout
    as params; the 'zero' rule may additionally shard the fsdp dim)."""
    return AdamWState(step=(), m=params_axes, v=params_axes)
