"""Synthetic token data pipeline.

Deterministic, seeded, host-shardable stream of (tokens, labels) batches —
the same step index always yields the same global batch regardless of the
number of data-parallel hosts (each host materializes its shard), so elastic
restarts are reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng((cfg.seed, step))


def make_batch(cfg: DataConfig, step: int,
               model_cfg: Optional[ModelConfig] = None) -> Dict[str, jnp.ndarray]:
    """Deterministic global batch for `step`; this host's shard only.
    Sequences are Zipf-ish token streams with structure (next-token labels =
    shifted inputs) so a model can actually reduce loss on them."""
    rng = _batch_rng(cfg, step)
    per_host = cfg.global_batch // cfg.num_hosts
    lo = cfg.host_id * per_host
    # draw the full global batch deterministically, slice this host's rows
    # (cheap at test scale; at cluster scale draw per-row from (seed, step, row))
    zipf = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    tokens = np.minimum(zipf, cfg.vocab_size - 1).astype(np.int32)
    rows = tokens[lo:lo + per_host]
    batch = {"tokens": jnp.asarray(rows[:, :-1]),
             "labels": jnp.asarray(rows[:, 1:])}
    if model_cfg is not None and model_cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((per_host, model_cfg.num_patches,
                                 model_cfg.d_model)), jnp.float32)
    if model_cfg is not None and model_cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((per_host, model_cfg.encoder_seq,
                                 model_cfg.d_model)), jnp.float32)
    return batch


def data_iterator(cfg: DataConfig, start_step: int = 0,
                  model_cfg: Optional[ModelConfig] = None) -> Iterator[Dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step, model_cfg)
        step += 1
