"""Training substrate: loss, train_step (used by the dry-run for train_4k),
and a fault-tolerant training loop (checkpoint/auto-resume, straggler
watchdog, optional gradient compression).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.training import optimizer as opt
from repro.training.optimizer import AdamWConfig, AdamWState


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.mean(ll)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "auto", remat: str = "dots") -> jax.Array:
    logits = forward(params, cfg, batch, attn_impl=attn_impl, remat=remat)
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    attn_impl: str = "auto", remat: str = "dots",
                    compress=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics). This is the function the dry-run lowers for train_4k shapes.

    `compress` (optional): gradient-compression transform applied between
    backward and optimizer (see training/compression.py)."""

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, attn_impl=attn_impl, remat=remat)
        )(params)
        if compress is not None:
            grads = compress(grads)
        new_params, new_state, metrics = opt.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    # straggler mitigation: a step slower than watchdog_factor x the rolling
    # median is logged and counted; at cluster scale the same hook triggers
    # re-sharding away from the slow host (here: observable metric + callback)
    watchdog_factor: float = 3.0
    on_straggler: Optional[Callable[[int, float, float], None]] = None


def train_loop(cfg: ModelConfig, params, opt_state, train_step, data_iter,
               loop: LoopConfig, *, start_step: int = 0,
               log: Callable[[str], None] = print) -> Tuple[Any, Any, Dict]:
    """Runs steps [start_step, total_steps). Checkpoints atomically; on
    restart, `checkpoint.latest_step` + `restore` resume bit-identically
    (tested in tests/test_training.py)."""
    from repro.training import checkpoint as ckpt

    step_times = []
    stragglers = 0
    metrics = {}
    t_compile = None
    for step in range(start_step, loop.total_steps):
        batch = next(data_iter)
        t0 = time.monotonic()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        if t_compile is None:
            t_compile = dt                     # first step includes compile
        else:
            step_times.append(dt)
            if len(step_times) >= 5:
                med = sorted(step_times)[len(step_times) // 2]
                if dt > loop.watchdog_factor * med:
                    stragglers += 1
                    log(f"[watchdog] step {step} took {dt:.3f}s "
                        f"(median {med:.3f}s) — straggler")
                    if loop.on_straggler is not None:
                        loop.on_straggler(step, dt, med)
        if step % loop.log_every == 0:
            log(f"step {step}: loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} dt={dt*1e3:.0f}ms")
        if (step + 1) % loop.checkpoint_every == 0 or \
                step + 1 == loop.total_steps:
            ckpt.save(loop.checkpoint_dir, step + 1,
                      {"params": params, "opt_state": opt_state},
                      keep=loop.keep)
    info = {"stragglers": stragglers,
            "median_step_time": (sorted(step_times)[len(step_times) // 2]
                                 if step_times else 0.0),
            "final_loss": float(metrics.get("loss", float("nan")))}
    return params, opt_state, info
