"""Atomic numpy-based checkpointing with auto-resume and elastic re-mesh
restore.

Layout: <dir>/step_<n>/  arrays.npz + tree.json  (flattened pytree with
stable key paths). Writes go to a temp dir + atomic rename, so a crash
mid-write never corrupts the latest checkpoint. `restore(..., shardings=)`
re-shards leaves onto a (possibly different) mesh — elastic scaling: save on
mesh A, resume on mesh B (tested).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: Any, keep: int = 3,
         async_write: bool = False) -> str:
    """Atomic checkpoint write. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")

    flat = _flatten(tree)
    treedef = jax.tree.structure(tree)

    def _write():
        tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "tree.json"), "w") as f:
                json.dump({"treedef": str(treedef), "step": step,
                           "keys": list(flat)}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        _gc(directory, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    else:
        _write()
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`. With `shardings` (a pytree of
    jax.sharding.Sharding or None), leaves are placed onto the target mesh —
    this is the elastic re-mesh path (checkpoint saved on mesh A restores
    onto mesh B)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}

    leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
    out_leaves = []
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None or
                                    hasattr(x, "device_set"))
                    if shardings is not None else [None] * len(leaves_like))
    for (path_k, leaf), shard in zip(leaves_like, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if shard is not None:
            out_leaves.append(jax.device_put(arr.astype(leaf.dtype), shard))
        else:
            out_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(like), out_leaves)
