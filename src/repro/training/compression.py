"""Gradient compression with error feedback (beyond-paper distributed trick).

int8 block-quantized gradients cut DP all-reduce bytes 4x (bf16) / 2x (int8 vs
bf16 halves again with chunk-max scaling); error feedback accumulates the
quantization residual locally so convergence is preserved (EF-SGD result).

Under jit/SPMD the all-reduce itself is implicit; this transform makes the
*reduced operand* int8 so the collective moves 1/4 the bytes. The transform is
pure and composes with make_train_step(compress=...).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.size % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


class ErrorFeedbackCompressor:
    """Stateful wrapper: grads' = Q(grads + residual); residual' = input - out.
    Call .transform as the `compress` hook of make_train_step. The residual
    pytree lives alongside the optimizer state and is checkpointable."""

    def __init__(self, block: int = 256):
        self.block = block
        self.residual: Optional[Any] = None

    def init(self, grads_like):
        self.residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
        return self.residual

    def transform(self, grads, residual):
        """Pure version: returns (compressed_grads, new_residual)."""
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = quantize_int8(x, self.block)
            out = dequantize_int8(q, s, g.shape, x.size)
            return out.astype(g.dtype), x - out
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))
