"""Prefill instance (FlowPrefill §4/§5): Request Queue + Scheduler + Execution
Pool, wired event-driven. The Scheduler thread blocks on the Event Monitor;
each ARRIVAL/COMPLETION event triggers exactly one SchedulerCore round whose
Decision is enacted as submit / preempt / resume commands on the pool.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.events import Event, EventKind, EventMonitor
from repro.core.request import Request, RequestState
from repro.core.scheduler import Action, SchedulerCore
from repro.models.segments import SegmentedPrefill
from repro.serving.pool import ExecTask, ExecutionPool


class PrefillInstance:
    def __init__(self, params, cfg, scheduler: SchedulerCore, *, max_seq: int,
                 granularity: str = "op", chunk_tokens: int = 0,
                 attn_impl: str = "xla",
                 clock: Callable[[], float] = time.monotonic,
                 on_prefill_done: Optional[Callable] = None,
                 executor: Optional[SegmentedPrefill] = None,
                 dispatch_depth: int = 2):
        self.cfg = cfg
        self.scheduler = scheduler
        self.clock = clock
        self.max_seq = max_seq
        self.on_prefill_done = on_prefill_done
        # a pre-built (warm-compiled) executor may be shared across instances
        self.executor = executor or SegmentedPrefill(
            params, cfg, max_seq=max_seq, granularity=granularity,
            chunk_tokens=chunk_tokens, attn_impl=attn_impl)

        self.monitor = EventMonitor()
        self.pool = ExecutionPool(step_fn=self._step, on_complete=self._complete,
                                  clock=clock, dispatch_depth=dispatch_depth)

        # request bookkeeping (owned by the scheduler thread)
        self._tokens: Dict[int, np.ndarray] = {}
        self._waiting: List[Request] = []
        self._running: Optional[ExecTask] = None
        self._preempted: Dict[int, ExecTask] = {}   # head rid -> task
        self.completed: List[Request] = []
        self.completed_tasks: List[ExecTask] = []
        self._lock = threading.Lock()

        self._shutdown = False
        self._thread = threading.Thread(target=self._scheduler_loop,
                                        daemon=True, name="scheduler")
        self._thread.start()

    # ------------------------------------------------------------- frontend
    def submit_request(self, req: Request, tokens: np.ndarray) -> None:
        with self._lock:
            self._tokens[req.rid] = np.asarray(tokens)
        self.monitor.publish(Event(time=self.clock(), kind=EventKind.ARRIVAL,
                                   payload=req))

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until all submitted requests completed."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                busy = (self._waiting or self._preempted
                        or self._running is not None
                        or self.monitor.qsize() > 0)
            if not busy:
                return True
            time.sleep(0.002)
        return False

    def shutdown(self) -> None:
        self._shutdown = True
        self.monitor.publish(Event(time=self.clock(), kind=EventKind.SHUTDOWN))
        self._thread.join(5.0)
        self.pool.shutdown()

    # ---------------------------------------------------------------- worker
    def _step(self, task: ExecTask) -> bool:
        return self.executor.step(task.prefill_task)

    def _complete(self, task: ExecTask) -> None:
        now = task.complete_time
        for r in task.requests:
            r.first_token_time = now
            r.state = RequestState.DONE
            r.ops_done = r.ops_total
        self.monitor.publish(Event(time=now, kind=EventKind.COMPLETION,
                                   payload=task))

    # ------------------------------------------------------------- scheduler
    def _scheduler_loop(self) -> None:
        while not self._shutdown:
            ev = self.monitor.next_event(timeout=1.0)
            if ev is None:
                continue
            if ev.kind == EventKind.SHUTDOWN:
                return
            with self._lock:
                self._handle_event(ev)
                self._round()

    def _handle_event(self, ev: Event) -> None:
        if ev.kind == EventKind.ARRIVAL:
            req: Request = ev.payload
            req.state = RequestState.WAITING
            self._waiting.append(req)
        elif ev.kind == EventKind.COMPLETION:
            task: ExecTask = ev.payload
            if self._running is not None and task.task_id == self._running.task_id:
                self._running = None
            self.completed.extend(task.requests)
            self.completed_tasks.append(task)
            if self.on_prefill_done is not None:
                self.on_prefill_done(task)

    def _round(self) -> None:
        """One scheduling round (Alg. 2) + command execution."""
        now = self.clock()
        running_req = self._running.head if self._running is not None else None
        preempted_reqs = [t.head for t in self._preempted.values()]
        decision = self.scheduler.schedule_round(
            now, self._waiting, preempted_reqs, running_req)
        if decision.is_noop:
            return

        if decision.preempt is not None and self._running is not None:
            suspended = self.pool.preempt_current()
            if suspended is not None:
                head = suspended.head
                for r in suspended.requests:
                    r.state = RequestState.PREEMPTED
                head.ops_total = suspended.prefill_task.total_segments
                head.ops_done = suspended.prefill_task.cursor
                self._preempted[head.rid] = suspended
                self._running = None
            else:
                # completed concurrently; the COMPLETION event will arrive.
                self._running = None

        if decision.action == Action.SUBMIT:
            batch = decision.batch
            task = self._make_task(batch)
            for r in batch:
                r.state = RequestState.RUNNING
                r.ops_total = task.prefill_task.total_segments
                r.ops_done = 0
            waiting_ids = {r.rid for r in batch}
            self._waiting = [r for r in self._waiting
                             if r.rid not in waiting_ids]
            self._running = task
            self.pool.submit(task)
        elif decision.action == Action.RESUME:
            head = decision.target
            task = self._preempted.pop(head.rid)
            for r in task.requests:
                r.state = RequestState.RUNNING
            self._running = task
            self.pool.resume(task.task_id)

    def _make_task(self, batch: List[Request]) -> ExecTask:
        toks = [self._tokens[r.rid] for r in batch]
        lens = [len(t) for t in toks]
        S = max(lens)
        arr = np.zeros((len(batch), S), dtype=np.int32)
        for i, t in enumerate(toks):
            arr[i, :len(t)] = t
        pt = self.executor.start(jnp.asarray(arr), lens=jnp.asarray(lens))
        return ExecTask(prefill_task=pt, requests=list(batch))

    # ------------------------------------------------------------- metrics
    @property
    def blocking_stats(self):
        return self.pool.blocking

    @property
    def scheduling_rounds(self) -> int:
        return self.monitor.rounds
