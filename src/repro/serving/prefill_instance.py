"""Prefill instance (FlowPrefill §4/§5): Request Queue + Scheduler + Execution
Pool, wired event-driven. The Scheduler thread blocks on the Event Monitor;
each ARRIVAL/COMPLETION event triggers exactly one SchedulerCore round whose
Decision is enacted as submit / preempt / resume commands on the pool.

Prefix sharing (``prefix_share=True``): the instance owns a prefix-sharing
`PagedKVCache` holding completed prompts' KV. On ARRIVAL the prompt's block
hash chain probes the trie and the sequence is allocated with the cached
prefix pinned (only the suffix gets fresh blocks); at SUBMIT the pinned
prefix KV is gathered from the pool and `SegmentedPrefill.start` resumes at
operator offset ``prefix_len`` — a hit is pure skipped compute. On
COMPLETION the computed suffix KV is scattered into the fresh blocks, the
full blocks are registered in the trie, and the sequence is released
(refcount decrement: its blocks stay CACHED for the next matching prompt,
LRU-evicted only under capacity pressure).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.events import Event, EventKind, EventMonitor
from repro.core.prefixcache import block_keys
from repro.core.request import Request, RequestState
from repro.core.scheduler import Action, SchedulerCore
from repro.models.segments import SegmentedPrefill
from repro.serving.kvcache import PagedKVCache
from repro.serving.pool import ExecTask, ExecutionPool


class PrefillInstance:
    def __init__(self, params, cfg, scheduler: SchedulerCore, *, max_seq: int,
                 granularity: str = "op", chunk_tokens: int = 0,
                 attn_impl: str = "xla",
                 clock: Callable[[], float] = time.monotonic,
                 on_prefill_done: Optional[Callable] = None,
                 executor: Optional[SegmentedPrefill] = None,
                 dispatch_depth: int = 2,
                 prefix_share: bool = False,
                 prefix_cache_blocks: int = 512,
                 kv_block_size: int = 128,
                 host_cache_blocks: int = 0,
                 disk_cache_blocks: int = 0,
                 promote_wait_s: float = 10.0):
        self.cfg = cfg
        self.scheduler = scheduler
        self.clock = clock
        self.max_seq = max_seq
        self.on_prefill_done = on_prefill_done
        # a pre-built (warm-compiled) executor may be shared across instances
        self.executor = executor or SegmentedPrefill(
            params, cfg, max_seq=max_seq, granularity=granularity,
            chunk_tokens=chunk_tokens, attn_impl=attn_impl)

        # prefix-sharing prompt KV cache (None = disabled, the default)
        self.kv: Optional[PagedKVCache] = None
        self.kv_block_size = kv_block_size
        if prefix_share:
            self.kv = PagedKVCache(
                cfg.num_layers, prefix_cache_blocks, kv_block_size,
                cfg.num_kv_heads, cfg.resolved_head_dim,
                dtype=self.executor.cache_dtype, prefix_share=True,
                host_cache_blocks=host_cache_blocks,
                disk_cache_blocks=disk_cache_blocks)
        # guards self.kv: the scheduler thread mutates it on every
        # arrival/completion while the Proxy probes it for affinity routing
        self._kv_lock = threading.Lock()
        self._prefix: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        # rid -> (pool hit tokens, hash chain) for sequences holding blocks
        self.prefix_hits = 0                 # requests with a nonzero hit
        self.prefix_hit_tokens = 0           # prompt tokens served cached
        # tiered promotion: rid -> in-flight PromotionTicket, settled by
        # _make_task before the prefill that depends on the blocks starts
        self._tickets: Dict[int, object] = {}
        self.promote_wait_s = promote_wait_s
        self.prefix_promotions = 0           # blocks re-warmed from a tier
        self.prefix_promoted_tokens = 0      # hit tokens gained by promotion

        self.monitor = EventMonitor()
        self.pool = ExecutionPool(step_fn=self._step, on_complete=self._complete,
                                  clock=clock, dispatch_depth=dispatch_depth,
                                  on_error=self._on_pool_error)

        # supervised-worker health (docs/ARCHITECTURE.md failure model):
        # a crash in either worker thread strands the queued + in-flight
        # requests back to `on_fault` (the Proxy re-dispatches them) and
        # flips healthy=False until restart(). last_progress feeds the
        # Proxy's watchdog (hang detection).
        self.healthy = True
        self.on_fault: Optional[Callable] = None   # (requests, exc) -> None
        self.last_error: Optional[BaseException] = None
        self.last_progress = clock()

        # request bookkeeping (owned by the scheduler thread)
        self._tokens: Dict[int, np.ndarray] = {}
        self._waiting: List[Request] = []
        self._running: Optional[ExecTask] = None
        self._preempted: Dict[int, ExecTask] = {}   # head rid -> task
        self.completed: List[Request] = []
        self.completed_tasks: List[ExecTask] = []
        self._lock = threading.Lock()
        # drain() waits here; the scheduler thread notifies after any event
        # that may have emptied the instance (no polling — PR 4's
        # DecodeInstance.drain fix applied to the prefill side)
        self._idle_cv = threading.Condition(self._lock)

        self._shutdown = False
        self._thread = threading.Thread(target=self._scheduler_loop,
                                        daemon=True, name="scheduler")
        self._thread.start()

    # ------------------------------------------------------------- frontend
    def submit_request(self, req: Request, tokens: np.ndarray) -> None:
        with self._lock:
            self._tokens[req.rid] = np.asarray(tokens)
        self.monitor.publish(Event(time=self.clock(), kind=EventKind.ARRIVAL,
                                   payload=req))

    def probe_prefix(self, tokens: np.ndarray) -> int:
        """Cached-prefix tokens this instance's pool holds for `tokens` —
        the affinity signal the Proxy's prefix-affinity dispatch routes on.
        0 without prefix sharing. Capped at len-1: the last position is
        always computed live (first-token logits)."""
        if self.kv is None:
            return 0
        tokens = np.asarray(tokens)
        return self.probe_keys(block_keys(tokens, self.kv_block_size),
                               int(tokens.size))

    def probe_keys(self, keys, num_tokens: int) -> int:
        """`probe_prefix` for a pre-hashed chain: the Proxy hashes the
        prompt ONCE per dispatch and probes every instance with the same
        chain — only the trie walk runs under each instance's lock."""
        if self.kv is None:
            return 0
        with self._kv_lock:
            hit = self.kv.probe(keys)
        return min(hit, max(num_tokens - 1, 0))

    def probe_keys_tiers(self, keys, num_tokens: int) -> Tuple[int, int, int]:
        """`probe_keys` with tier-tagged lengths: (warm, host, disk) cached
        tokens, jointly capped at num_tokens - 1. Warm tokens are free;
        cold ones cost `promote_seconds` — the Proxy prices both into one
        net ttft_saved so dispatch sees warm/cold/absent as three prices."""
        if self.kv is None:
            return (0, 0, 0)
        with self._kv_lock:
            warm, host, disk = self.kv.probe_tiers(keys)
        cap = max(num_tokens - 1, 0)
        warm = min(warm, cap)
        host = min(host, cap - warm)
        disk = min(disk, cap - warm - host)
        return warm, host, disk

    def promote_seconds(self, host_tokens: int, disk_tokens: int = 0) -> float:
        """Predicted copy time to promote that many cold tokens (0 when
        this instance has no cold tiers)."""
        if self.kv is None or not getattr(self.kv, "tiered", False):
            return 0.0
        return self.kv.promote_seconds(host_tokens, disk_tokens)

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until all submitted requests completed. Waits on the
        instance condition variable — the scheduler thread notifies after
        every processed event — instead of the old 2 ms busy-wait poll."""
        def idle() -> bool:
            # unhealthy => never drained: the strand sweep clears these
            # queues BEFORE on_fault hands the victims to the supervisor,
            # and "drained" in that gap would let the proxy settle on work
            # that is mid-flight to the recovery path
            return self.healthy and not (
                self._waiting or self._preempted
                or self._running is not None
                or self.monitor.qsize() > 0)
        with self._idle_cv:
            return self._idle_cv.wait_for(idle, timeout)

    def shutdown(self) -> None:
        self._shutdown = True
        self.monitor.publish(Event(time=self.clock(), kind=EventKind.SHUTDOWN))
        self._thread.join(5.0)
        self.pool.shutdown()
        if self.kv is not None:
            # settle any promotion that never reached a SUBMIT (its request
            # is abandoned): drain the copy engine and abort the in-flight
            # reservations so the pool accounting stays leak-free
            for rid, ticket in list(self._tickets.items()):
                del self._tickets[rid]
                with self._kv_lock:
                    self.kv.promote_settle(ticket)
            self.kv.close()

    # ---------------------------------------------------------------- worker
    def _step(self, task: ExecTask) -> bool:
        return self.executor.step(task.prefill_task)

    def _complete(self, task: ExecTask) -> None:
        if not self.healthy:
            # zombie completion: the instance already stranded this task's
            # requests to the Proxy — mutating them now would race their
            # re-dispatch (the Proxy's _completed_rids dedupe is the second
            # line of defense for the narrow flag-read window)
            return
        now = task.complete_time
        for r in task.requests:
            r.first_token_time = now
            r.state = RequestState.DONE
            r.ops_done = r.ops_total
        self.monitor.publish(Event(time=now, kind=EventKind.COMPLETION,
                                   payload=task))

    # ------------------------------------------------------------- scheduler
    def _scheduler_loop(self) -> None:
        while not self._shutdown:
            ev = self.monitor.next_event(timeout=1.0)
            if ev is None:
                continue
            if ev.kind == EventKind.SHUTDOWN:
                return
            try:
                if ev.kind == EventKind.FAULT:
                    inj = ev.payload
                    if isinstance(inj, tuple) and inj and inj[0] == "hang":
                        # simulated hang: stall OUTSIDE the lock so the
                        # watchdog can still strand the queues
                        time.sleep(float(inj[1]))
                        continue
                    raise inj if isinstance(inj, BaseException) \
                        else RuntimeError(str(inj))
                if not self.healthy:
                    if ev.kind == EventKind.ARRIVAL:
                        # a dispatch that raced the failure: the request was
                        # not yet queued when the strand swept, so bounce it
                        # straight back to the recovery path (silently
                        # dropping it would break no-request-lost)
                        cb = self.on_fault
                        if cb is not None:
                            cb([ev.payload], self.last_error
                               or RuntimeError("instance down"))
                    continue        # stranded: drop zombies until restart()
                with self._lock:
                    self._handle_event(ev)
                    self._round()
                    if not (self._waiting or self._preempted
                            or self._running is not None
                            or self.monitor.qsize() > 0):
                        self._idle_cv.notify_all()
                self.last_progress = self.clock()
            except Exception as exc:
                self._on_worker_failure(exc)

    # ------------------------------------------------ supervised recovery
    def _on_worker_failure(self, exc: Exception) -> None:
        """Strand everything back to the proxy layer: idempotent (first
        failure wins), callable from the scheduler thread, the pool worker,
        or the Proxy's watchdog. Queued, suspended, and running requests are
        all returned — their partial prefill state died with the instance
        (the KV-lost convention the simulator shares)."""
        with self._lock:
            if not self.healthy:
                return
            self.healthy = False
            self.last_error = exc
            stranded: List[Request] = list(self._waiting)
            for task in self._preempted.values():
                stranded.extend(task.requests)
            if self._running is not None:
                stranded.extend(self._running.requests)
            self._waiting = []
            self._preempted = {}
            self._running = None
            self._idle_cv.notify_all()
        # stop the pool's in-flight task too: left running, it would still
        # occupy the pool after restart() and collide with the first
        # post-revive submit. From the pool worker's own error path _current
        # is already None and this returns immediately.
        self.pool.preempt_current(timeout=5.0)
        self.pool.clear_preempted()
        cb = self.on_fault
        if cb is not None:
            cb(stranded, exc)       # outside the lock: the Proxy re-enters

    def _on_pool_error(self, task, exc: Exception) -> None:
        # the failed ExecTask is still referenced from self._running /
        # self._preempted, so _on_worker_failure strands its requests too
        self._on_worker_failure(exc)

    def inject_fault(self, fault) -> None:
        """Chaos-harness entry (core/faults.py): an Exception crashes the
        scheduler loop at its next event; ("hang", seconds) stalls it."""
        self.monitor.publish(Event(time=self.clock(), kind=EventKind.FAULT,
                                   payload=fault))

    def restart(self) -> None:
        """Rejoin after a failure: both worker threads survive exceptions,
        so recovery is a state reset, not a thread respawn."""
        with self._lock:
            self.healthy = True
            self.last_error = None
            self.last_progress = self.clock()
        self.pool.restart()

    @property
    def progress_ts(self) -> float:
        """Latest liveness signal across both worker threads (scheduler
        event processed, or pool operator boundary crossed) — what the
        Proxy's hang watchdog compares against its deadline."""
        return max(self.last_progress, self.pool.last_step)

    def _acquire_prefix(self, req: Request, tokens: np.ndarray) -> None:
        """ARRIVAL-time trie probe + allocation: pin the cached prefix and
        reserve fresh suffix blocks, so eviction cannot touch the hit while
        the request waits/executes. A full pool (even after LRU eviction)
        just means this prompt goes uncached — never an error."""
        n = len(tokens)
        keys = block_keys(tokens, self.kv_block_size)
        with self._kv_lock:
            try:
                table = self.kv.allocate(req.rid, n, keys=keys)
            except MemoryError:
                return
            hit = min(table.length, max(n - 1, 0))
            ticket = self._begin_promotion(keys, n, table.length)
        self._prefix[req.rid] = (hit, keys)
        req.prefix_hit = hit
        if ticket is not None and ticket.blocks:
            self._tickets[req.rid] = ticket
        if hit:
            self.prefix_hits += 1
            self.prefix_hit_tokens += hit

    def _begin_promotion(self, keys, n: int, warm: int):
        """Under _kv_lock at ARRIVAL: if the prompt's chain extends into a
        cold tier, start promoting it — but only when the predicted copy
        time beats the recompute the promotion would save (the scheduler's
        TTFT predictor prices the save, exactly the transfer-vs-recompute
        gate decode migration uses). Returns a PromotionTicket or None."""
        if not getattr(self.kv, "tiered", False):
            return None
        _, host_t, disk_t = self.kv.probe_tiers(keys)
        cap = max(n - 1, 0) - warm         # useful tokens beyond the warm run
        cold = min(host_t + disk_t, cap)
        if cold <= 0:
            return None
        pred = getattr(self.scheduler, "predictor", None)
        if pred is not None:
            saved = max(float(pred.predict(n - warm))
                        - float(pred.predict(n - warm - cold)), 0.0)
            host_use = min(host_t, cold)
            cost = self.kv.promote_seconds(host_use, cold - host_use)
            if cost >= saved:
                return None                # cheaper to recompute than copy
        bs = self.kv_block_size
        return self.kv.promote_async(keys, max_blocks=(cold + bs - 1) // bs)

    def _publish_prefix(self, task: ExecTask) -> None:
        """COMPLETION-time insert: scatter each member's computed suffix KV
        into its fresh blocks, register the full blocks in the trie, release
        the sequence (refcount decrement — blocks stay cached, LRU-ordered).
        The prefill state's cache rows are fully valid (< prefix seeded,
        >= prefix computed), so the slice is always well-defined."""
        st = task.prefill_task.state
        with self._kv_lock:
            for i, req in enumerate(task.requests):
                entry = self._prefix.pop(req.rid, None)
                if entry is None:
                    continue                      # pool was full at arrival
                _, keys = entry
                table = self.kv.table(req.rid)
                start = table.prefix_blocks * self.kv_block_size
                n = int(st["lens"][i])
                if start < n:
                    self.kv.write_prompt(
                        req.rid, st["k_cache"][:, i, start:n],
                        st["v_cache"][:, i, start:n], start=start)
                self.kv.insert(req.rid, keys)
                self.kv.free(req.rid)

    def _handle_event(self, ev: Event) -> None:
        if ev.kind == EventKind.ARRIVAL:
            req: Request = ev.payload
            req.state = RequestState.WAITING
            if self.kv is not None:
                self._acquire_prefix(req, self._tokens[req.rid])
            self._waiting.append(req)
        elif ev.kind == EventKind.COMPLETION:
            task: ExecTask = ev.payload
            if self._running is not None and task.task_id == self._running.task_id:
                self._running = None
            if self.kv is not None:
                self._publish_prefix(task)
            self.completed.extend(task.requests)
            self.completed_tasks.append(task)
            if self.on_prefill_done is not None:
                self.on_prefill_done(task)

    def _round(self) -> None:
        """One scheduling round (Alg. 2) + command execution."""
        now = self.clock()
        running_req = self._running.head if self._running is not None else None
        preempted_reqs = [t.head for t in self._preempted.values()]
        decision = self.scheduler.schedule_round(
            now, self._waiting, preempted_reqs, running_req)
        if decision.is_noop:
            return

        if decision.preempt is not None and self._running is not None:
            suspended = self.pool.preempt_current()
            if suspended is not None:
                head = suspended.head
                for r in suspended.requests:
                    r.state = RequestState.PREEMPTED
                head.ops_total = suspended.prefill_task.total_segments
                head.ops_done = suspended.prefill_task.cursor
                self._preempted[head.rid] = suspended
                self._running = None
            else:
                # completed concurrently; the COMPLETION event will arrive.
                self._running = None

        if decision.action == Action.SUBMIT:
            batch = decision.batch
            task = self._make_task(batch)
            for r in batch:
                r.state = RequestState.RUNNING
                r.ops_total = task.prefill_task.total_segments
                r.ops_done = 0
            waiting_ids = {r.rid for r in batch}
            self._waiting = [r for r in self._waiting
                             if r.rid not in waiting_ids]
            self._running = task
            self.pool.submit(task)
        elif decision.action == Action.RESUME:
            head = decision.target
            task = self._preempted.pop(head.rid)
            for r in task.requests:
                r.state = RequestState.RUNNING
            self._running = task
            self.pool.resume(task.task_id)

    def _settle_promotion(self, req: Request, ticket) -> None:
        """SUBMIT-time settle for one batch member: wait for the copies
        OUTSIDE the kv lock (workers never take it — the prefill BLOCKS on a
        copy still in flight, it never crashes into one), then commit under
        the lock and re-pin the now-longer prefix. Every failure mode
        degrades to the pre-promotion hit: a timed-out copy aborts back to
        its tier, a corrupt one is dropped (recompute — never stale KV),
        and a full pool on re-pin just leaves the prompt uncached."""
        ticket.wait(self.promote_wait_s)
        entry = self._prefix.get(req.rid)
        gained = 0
        with self._kv_lock:
            committed = self.kv.promote_settle(ticket)
            if committed > 0 and entry is not None:
                old_hit, keys = entry
                n = int(self._tokens[req.rid].size)
                self.kv.free(req.rid)
                try:
                    table = self.kv.allocate(req.rid, n, keys=keys)
                except MemoryError:
                    self._prefix.pop(req.rid, None)
                    req.prefix_hit = 0
                    return
                hit = min(table.length, max(n - 1, 0))
                self._prefix[req.rid] = (hit, keys)
                req.prefix_hit = hit
                gained = max(hit - old_hit, 0)
                self.prefix_promotions += committed
                self.prefix_promoted_tokens += gained
                if old_hit == 0 and hit > 0:
                    self.prefix_hits += 1
                self.prefix_hit_tokens += gained

    def _make_task(self, batch: List[Request]) -> ExecTask:
        if self.kv is not None:
            for r in batch:
                ticket = self._tickets.pop(r.rid, None)
                if ticket is not None:
                    self._settle_promotion(r, ticket)
        toks = [self._tokens[r.rid] for r in batch]
        lens = [len(t) for t in toks]
        S = max(lens)
        arr = np.zeros((len(batch), S), dtype=np.int32)
        for i, t in enumerate(toks):
            arr[i, :len(t)] = t
        # prefix-cache resumption: the batch shares one operator offset, so
        # it starts at the MINIMUM member hit (rows with longer hits just
        # recompute a little — single-request tasks, the common case, use
        # their full hit). Capped at min(lens) - 1: the head needs a live
        # last position.
        P = 0
        if self.kv is not None and batch:
            P = min(self._prefix.get(r.rid, (0, ()))[0] for r in batch)
            P = min(P, min(lens) - 1)
        if P > 0:
            with self._kv_lock:
                ks, vs = [], []
                for r in batch:
                    k, v, _ = self.kv.gather(r.rid)
                    ks.append(k[:, :P])
                    vs.append(v[:, :P])
            pk = jnp.stack(ks, axis=1)           # (L, B, P, K, hd)
            pv = jnp.stack(vs, axis=1)
            pt = self.executor.start(jnp.asarray(arr), lens=jnp.asarray(lens),
                                     prefix_len=P, prefix_k=pk, prefix_v=pv)
        else:
            pt = self.executor.start(jnp.asarray(arr), lens=jnp.asarray(lens))
        return ExecTask(prefill_task=pt, requests=list(batch))

    # ------------------------------------------------------------- metrics
    @property
    def blocking_stats(self):
        return self.pool.blocking

    @property
    def scheduling_rounds(self) -> int:
        return self.monitor.rounds
