"""Execution Pool (FlowPrefill §4, §5.1).

Manages execution tasks: runs at most one at a time, safely preserves the
state of preempted tasks until resumption, and acts ONLY on explicit commands
(submit / preempt / resume) from the Scheduler — it makes no scheduling
decisions itself.

The worker thread advances the current task segment-by-segment, performing the
cooperative preemption check (a flag read) at every operator boundary — the
exact protocol of paper Fig. 7 including the signal/ACK handshake and the
completion race (a task finishing while a signal is pending ACKs immediately
so the scheduler never stalls; the ACK is distinguishable from suspension).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro.core.preemption import BlockingStats, PreemptionSignal
from repro.core.request import Request

_task_ids = itertools.count()


@dataclass
class ExecTask:
    """One execution task = one (possibly batched) prefill."""
    prefill_task: object                      # models.segments.PrefillTask
    requests: List[Request]                   # batch members (H first)
    task_id: int = field(default_factory=lambda: next(_task_ids))
    submit_time: float = 0.0
    complete_time: Optional[float] = None

    @property
    def head(self) -> Request:
        return self.requests[0]


class ExecutionPool:
    def __init__(self, step_fn: Callable[[ExecTask], bool],
                 on_complete: Callable[[ExecTask], None],
                 clock: Callable[[], float] = time.monotonic,
                 dispatch_depth: int = 2,
                 on_error: Optional[Callable[[Optional[ExecTask],
                                              Exception], None]] = None):
        """dispatch_depth bounds how many operator dispatches may be enqueued
        ahead of device completion. Without this bound JAX's async dispatch
        would let the host race to the end of the prefill, making the
        cooperative check vacuous; with it, preemption latency is
        <= (dispatch_depth + 1) x one operator — the paper's bound."""
        self._step = step_fn
        self._on_complete = on_complete
        self._on_error = on_error
        self._clock = clock
        self._dispatch_depth = max(dispatch_depth, 0)
        self.signal = PreemptionSignal()
        self.blocking = BlockingStats()
        self.healthy = True             # False after a worker exception
        self.last_step = clock()        # watchdog progress signal: stamped
                                        # at every operator boundary
        self._cv = threading.Condition()
        self._current: Optional[ExecTask] = None
        self._preempted: Dict[int, ExecTask] = {}
        self._shutdown = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="execution-pool")
        self._thread.start()

    # ------------------------------------------------------------------ API
    def submit(self, task: ExecTask) -> None:
        with self._cv:
            assert self._current is None, "pool executes at most one task"
            task.submit_time = self._clock()
            self._current = task
            self._cv.notify_all()

    def resume(self, task_id: int) -> ExecTask:
        with self._cv:
            task = self._preempted.pop(task_id)
        self.submit(task)
        return task

    def preempt_current(self, timeout: float = 10.0) -> Optional[ExecTask]:
        """Scheduler-side preemption (Fig. 7). Returns the suspended task, or
        None if nothing was running / the task completed concurrently."""
        with self._cv:
            task = self._current
        if task is None:
            return None
        self.signal.request_preemption()
        acked = self.signal.wait_ack(timeout)
        with self._cv:
            if acked and task.task_id in self._preempted:
                return task
        # completed before the boundary check could suspend it
        self.signal.cancel()
        return None

    def preempted_tasks(self) -> List[ExecTask]:
        with self._cv:
            return list(self._preempted.values())

    def clear_preempted(self) -> List[ExecTask]:
        """Drop all suspended tasks (supervised recovery: their requests are
        being re-dispatched elsewhere, so keeping the device state would only
        leak memory and invite zombie resumes)."""
        with self._cv:
            dropped = list(self._preempted.values())
            self._preempted.clear()
        return dropped

    def restart(self) -> None:
        """Mark the pool serviceable again after a worker exception (the
        worker thread survives errors, so this is just the health flip)."""
        with self._cv:
            self.healthy = True
            self.last_step = self._clock()

    def current(self) -> Optional[ExecTask]:
        with self._cv:
            return self._current

    def idle(self) -> bool:
        with self._cv:
            return self._current is None

    def shutdown(self, timeout: float = 5.0) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        self._thread.join(timeout)

    # --------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while self._current is None and not self._shutdown:
                    self._cv.wait()
                if self._shutdown:
                    return
                task = self._current

            try:
                self._run_task(task)
            except Exception as exc:        # supervised worker: a failing
                # operator (OOM, bad kernel, injected chaos) must not strand
                # the task forever — mark unhealthy, errback, keep the thread
                # alive so restart() can revive the instance
                with self._cv:
                    self.healthy = False
                    self._current = None
                if self.signal.check():
                    # unblock a racing preemption request (the scheduler
                    # would otherwise stall its full ack timeout)
                    self.signal.consume_and_ack()
                if self._on_error is not None:
                    self._on_error(task, exc)

    def _run_task(self, task: ExecTask) -> None:
        window: List = []                      # dispatched, maybe unfinished
        while True:
            # cooperative preemption check at the operator boundary
            if self.signal.check():
                # drain the in-flight operators (bounded by dispatch_depth)
                jax.block_until_ready(task.prefill_task.state)
                dt = self.signal.consume_and_ack()
                self.blocking.record(dt)
                with self._cv:
                    self._preempted[task.task_id] = task
                    self._current = None
                return

            done = self._step(task)
            self.last_step = self._clock()
            # flow control: keep at most dispatch_depth segments in flight
            tok = task.prefill_task.sync_token
            if tok is not None:
                window.append(tok)
                if len(window) > self._dispatch_depth:
                    jax.block_until_ready(window.pop(0))

            if done:
                if task.prefill_task.logits is not None:
                    jax.block_until_ready(task.prefill_task.logits)
                task.complete_time = self._clock()
                with self._cv:
                    self._current = None
                # unblock a racing preemption request (scheduler will see
                # the task is NOT in the preempted set -> completed)
                if self.signal.check():
                    self.signal.consume_and_ack()
                self._on_complete(task)
                return
