"""Decode instance (FlowPrefill §4, extended): continuous-batching
autoregressive decode of handed-over prefills (the PD-disaggregation KV
transfer), with pluggable batch-admission scheduling.

The runtime mirrors the simulator's decode model (`DecodeSim`,
docs/SCHEDULING.md — evaluated-is-deployed): an instance owns up to
``decode_max_batch`` resident SLOTS backed by `PagedKVCache` block tables and
runs ONE jitted decode step per token over the whole resident batch
(`repro.models.model.decode_step_ragged`): decode is bandwidth-bound, so
weights are streamed once per step regardless of how many streams share it —
tokens/s scales near-linearly with the batch (benchmarks/fig21).

Scheduling (`repro.core.scheduler.DecodeSchedulerCore`, shared verbatim with
the simulator):

  * ``policy="fcfs"``  — arrival-order admission into free slots; residents
    are never displaced (the paper's deliberately-plain decode stage).
  * ``policy="s-edf"`` — admission ranked by TBT-deadline slack; with
    ``preempt`` a near-deadline waiting stream displaces the most slack-rich
    resident at the next TOKEN boundary. Preemption is slot *eviction*:
    progress, KV blocks (kept resident in the pool), and the next token all
    survive, exactly like the old single-stream suspend — the decode analogue
    of the paper's operator-level prefill preemption.

Batch shapes are BUCKETED (``batch_buckets``, KV width padded to
power-of-two block multiples) so jit recompilations are bounded by the
bucket-pair count, not by the number of distinct resident populations
(asserted in tests/test_decode_batched.py).

``decode_max_batch=1`` (the default) keeps the original single-stream worker
byte-for-byte: one dense `decode_step` per token on the job's own handoff
cache, so the B=1 path bit-matches the pre-batching runtime.

Slack needs a per-token latency estimate: a `DecodeStepPredictor` (analytic
or profiled `step_time(B, ctx)` prior, EMA-calibrated from this instance's
own measured TBT samples) or, without one, a plain EMA of observed TBT.

Queued (not yet resident) jobs can be handed to another instance by the Proxy
(decode migration): `snapshot_load`/`snapshot_candidates` feed the shared
cost-gated planner in `repro.core.dispatch`, `take` removes the chosen jobs
(evicted pool-resident streams are gathered back into a dense handoff cache).

SPECULATIVE DECODING (``spec_decode=True``): decode is bandwidth-bound, so
the jitted step leaves most of the device's compute idle — spend it on a
draft-then-verify scheme. Each step every resident row proposes up to
``draft_k`` tokens (default: the self-drafting n-gram drafter
`_ngram_draft`, suffix-matching the stream's own generated tokens; a custom
``draft_fn(rid, history, k)`` can be injected), then ONE batched
`decode_verify_ragged` pass scores all k+1 positions per row. Greedy
acceptance (longest draft prefix matching the argmax chain) makes the
output BIT-IDENTICAL to plain greedy decoding — speculation only changes
how many tokens a step commits (1..k+1, per row). Rejected draft KV is
rolled back by committed-length truncation (`PagedKVCache.write_token_span`)
— never readable, never stale. Per-stream accept-rate EMAs
(`DecodeStepPredictor.observe_accept`) keep S-EDF slack, migration gating
and hybrid token budgets priced in per-ACCEPTED-token terms, and an
adaptive throttle drops low-accept streams back to drafting nothing (with a
periodic re-probe); a step in which no row drafts runs the PLAIN jitted
step, so the adversarial low-accept regime degrades to ~plain cost
(benchmarks/fig27_spec_decode.py gates both regimes). ``spec_decode=False``
(the default) leaves every code path byte-identical to before.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import DecodeCandidate, DecodeLoad
from repro.core.predictor import DecodeStepPredictor
from repro.core.request import Request
from repro.core.scheduler import DecodeEntry, DecodeSchedulerCore
from repro.models.model import (decode_step, decode_step_ragged,
                                decode_verify_ragged, supports_ragged_decode)
from repro.serving.kvcache import PagedKVCache

# sequence id of the pool slot padding rows write into / gather from — never
# a real request rid (rids are non-negative)
_SCRATCH_SEQ = -1

# per-stream drafter corpus cap: the n-gram drafter scans this many recent
# generated tokens (host memory + host-CPU bound, not device state)
_SPEC_HISTORY_CAP = 512


def _ngram_draft(history: Sequence[int], k: int) -> List[int]:
    """Self-drafting n-gram proposal: find the most recent EARLIER occurrence
    of the stream's current suffix (3-gram first, then 2-gram) in its own
    generated tokens and draft the k tokens that followed it. Costs zero
    model weights and zero device work — repetitive streams (agentic loops,
    templated output) hit constantly, low-reuse chat simply drafts nothing
    and the step falls back to plain decoding."""
    n = len(history)
    if k <= 0 or n < 3:
        return []
    for m in (3, 2):
        if n < m + 1:
            continue
        suffix = tuple(history[-m:])
        for i in range(n - m - 1, -1, -1):
            if tuple(history[i:i + m]) == suffix:
                cont = history[i + m:i + m + k]
                if cont:
                    return [int(t) for t in cont]
    return []


@dataclass
class DecodeJob:
    request: Request
    cache: Dict                     # model.decode_step cache (B=1 slice);
                                    # None while the stream's KV lives in the
                                    # instance's paged pool (batched path)
    first_token: int
    tokens_done: int = 0            # tokens already decoded (preemption state)
    next_token: Optional[int] = None  # resume point after a suspension
    enqueued: float = 0.0           # first submit (fixes the decode deadline)
    order: int = 0                  # FCFS order / deterministic tiebreak
    target: int = 0                 # tokens to decode for THIS job (set at
                                    # submit: request.output_tokens, or the
                                    # instance default) — deadlines and
                                    # remaining-work MUST use the same count
    base_len: int = 0               # prompt tokens in the pool (batched path):
                                    # kv position = base_len + tokens_done
    history: Optional[List[int]] = None   # generated tokens (speculative
                                    # drafter corpus; None until the stream's
                                    # first spec step — plain decoding never
                                    # materializes it)
    probe_in: int = 0               # steps until a throttled stream re-probes
                                    # the drafter (spec_decode)


class DecodeInstance:
    def __init__(self, params, cfg, *, decode_tokens: int = 8,
                 clock: Callable[[], float] = time.monotonic,
                 policy: str = "fcfs", preempt: Optional[bool] = None,
                 step_predictor: Optional[DecodeStepPredictor] = None,
                 decode_max_batch: int = 1,
                 batch_buckets: Sequence[int] = (1, 2, 4, 8),
                 kv_block_size: int = 128,
                 attn_impl: str = "naive",
                 prefix_share: bool = False,
                 kv_max_blocks: int = 0,
                 spec_decode: bool = False,
                 draft_k: int = 4,
                 draft_fn: Optional[Callable[
                     [int, Sequence[int], int], Sequence[int]]] = None,
                 spec_probe_period: int = 8,
                 spec_throttle: float = 1.15):
        if decode_max_batch > 1 and not supports_ragged_decode(cfg):
            raise ValueError(
                f"decode_max_batch={decode_max_batch} needs the batched "
                f"ragged decode step, unsupported for family "
                f"{cfg.family!r}; use decode_max_batch=1")
        if spec_decode and not supports_ragged_decode(cfg):
            raise ValueError(
                f"spec_decode needs the batched verify step, unsupported "
                f"for family {cfg.family!r}")
        self.params = params
        self.cfg = cfg
        self.decode_tokens = decode_tokens
        self.decode_max_batch = max(decode_max_batch, 1)
        self.clock = clock
        self.sched = DecodeSchedulerCore(
            policy=policy, preempt=(policy == "s-edf") if preempt is None
            else preempt)
        self.step_pred = step_predictor
        self.attn_impl = attn_impl
        self.kv_block_size = kv_block_size
        self.prefix_share = prefix_share   # pool created in share mode:
                                           # free() decrements refcounts and
                                           # parks trie-registered blocks in
                                           # the LRU cache instead of eagerly
                                           # freeing
        self.kv_max_blocks = kv_max_blocks  # admission-growth cap (0 = un-
                                            # bounded, the pre-cap behavior):
                                            # a leak then surfaces as
                                            # declined admissions instead of
                                            # unbounded pool doubling
        # batch-size buckets: padded shapes the jitted step may see — bounds
        # recompiles to len(buckets) x len(width buckets)
        self._b_buckets = sorted(
            {min(b, self.decode_max_batch) for b in batch_buckets if b >= 1}
            | {self.decode_max_batch})
        self._tbt_ema = 0.0             # fallback t_step estimate (no prior)
        self._waiting: List[DecodeJob] = []
        self._resident: Dict[int, DecodeJob] = {}   # rid -> job (slots)
        self._admitting = 0             # jobs mid-ingestion: in NEITHER list
                                        # (keeps drain/idle from lying)
        self._in_pool: set = set()      # rids whose KV lives in self.kv
        self.kv: Optional[PagedKVCache] = None      # lazily sized on first use
        # serializes ALL self.kv access: the worker's per-step gather/scatter
        # runs outside _cv (write_tokens DONATES the pool buffers), while
        # take() extracts evicted streams from other threads — unguarded
        # overlap would read a deleted/torn pool. Lock order: _cv -> _kv_lock.
        self._kv_lock = threading.Lock()
        self._cv = threading.Condition()
        self._order = 0
        self._shutdown = False
        self.finished: List[Request] = []
        self.tbt_samples: List[float] = []   # per-ACCEPTED-token TBT: a step
                                             # committing a tokens appends a
                                             # samples of dt/a (a=1 keeps the
                                             # plain path's values bit-equal)
        self.step_samples: List[float] = []  # per-STEP wall latency — the
                                             # satellite metric that stays
                                             # meaningful when tokens/step > 1
        self.preemptions = 0
        self.steps = 0                  # batched decode steps executed
        self.row_steps = 0              # (stream, step) pairs: per-row
                                        # tokens/step = len(tbt_samples)/this
        # --- speculative decoding (spec_decode=False leaves all of this
        # inert: plain paths never read it) ---------------------------------
        self.spec_decode = spec_decode
        # drafts must fit the scratch block (span writes at positions
        # 0..draft_k of the 1-block scratch sequence) and leave room for the
        # +1 verified token
        self.draft_k = max(1, min(int(draft_k), kv_block_size - 1))
        self.draft_fn = draft_fn        # None = self-drafting n-gram drafter
        self.spec_probe_period = max(int(spec_probe_period), 1)
        self.spec_throttle = float(spec_throttle)
        self.spec_steps = 0             # steps that ran the k+1 verify shape
        self.draft_proposed = 0         # draft tokens sent to verification
        self.draft_accepted = 0         # draft tokens committed
        self._accept_tps = 0.0          # aggregate tokens/step EMA fallback
                                        # (no step_pred attached)
        self._step = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c))
        self._step_ragged = jax.jit(
            lambda p, t, kg, vg, kl: decode_step_ragged(
                p, cfg, t, kg, vg, kl, attn_impl=attn_impl))
        self._step_verify = jax.jit(
            lambda p, t, kg, vg, kl: decode_verify_ragged(
                p, cfg, t, kg, vg, kl, attn_impl=attn_impl))
        # supervised-worker health (docs/ARCHITECTURE.md failure model): a
        # worker exception strands queued + resident jobs' REQUESTS back to
        # `on_fault` (the Proxy re-runs them from prefill — their pool KV
        # died with the instance) and flips healthy until restart().
        self.healthy = True
        self.on_fault: Optional[Callable] = None   # (requests, exc) -> None
        self.last_error: Optional[BaseException] = None
        self.last_progress = clock()
        self._inject: Optional[object] = None      # chaos: raise in worker
        # incarnation counter, bumped at every strand: a worker that wakes
        # from a hang AFTER restart() sees healthy=True again, so the flag
        # alone cannot tell it its job was re-dispatched — the epoch can
        # (the runtime analog of the simulator's killed_seq)
        self._epoch = 0
        # speculation lives in the batched worker (the verify pass IS a
        # batched ragged step), so spec_decode routes there even at slot cap 1
        run = self._run_batched \
            if self.decode_max_batch > 1 or self.spec_decode else self._run
        self._thread = threading.Thread(target=lambda: self._supervised(run),
                                        daemon=True, name="decode-instance")
        self._thread.start()

    # ------------------------------------------------------------- frontend
    def submit(self, job: DecodeJob) -> None:
        """Enqueue a decode job (fresh handoff or a migrated-in stream)."""
        req = job.request
        if req.decode_start is None:
            now = self.clock()
            job.enqueued = now
            req.decode_start = now      # fixes Request.decode_deadline
            if req.output_tokens <= 0:
                # the instance decodes exactly this many tokens; record it so
                # TBT accounting (decode_deadline / tbt_met) is well-defined
                req.output_tokens = self.decode_tokens
        if job.target <= 0:
            # deadline (output_tokens x tbt_slo) and remaining work must
            # count the SAME tokens, or slack estimates skew by their ratio
            job.target = req.output_tokens if req.output_tokens > 0 \
                else self.decode_tokens
        with self._cv:
            job.order = self._order
            self._order += 1
            self._waiting.append(job)
            # notify_all: drain() waits on the same cv — a single notify
            # could wake the drain waiter (predicate now false) instead of
            # the worker, costing a wait-timeout of first-token latency
            self._cv.notify_all()

    def pending(self) -> int:
        """Decode jobs waiting in this instance's queue (the backlog signal
        decode-aware dispatch prices via DecodeCostModel.step_time)."""
        with self._cv:
            return len(self._waiting)

    def resident(self) -> int:
        """Streams currently occupying batch slots."""
        with self._cv:
            return len(self._resident)

    def idle(self) -> bool:
        """No queued work and nothing decoding. NOTE: a job being migrated
        is momentarily in NO instance, so cross-instance quiescence must be
        checked under the owner's migration lock (Proxy.drain does).

        An unhealthy instance is never idle: the strand sweep empties the
        queues BEFORE `on_fault` hands the victims to the supervisor, and in
        that gap an "idle" answer would let a drain settle on work that is
        mid-flight to the recovery path."""
        with self._cv:
            return self.healthy and not self._waiting \
                and not self._resident and self._admitting == 0

    def compile_cache_size(self) -> int:
        """Compiled-shape count of the batched step families — the recompile
        budget the shape buckets bound (tests assert <= |B buckets| x |KV
        widths| per family: the plain S=1 step and, under spec_decode, the
        fixed S=k+1 verify step — at most a factor of 2, never per-draft-
        length shapes)."""
        total, found = 0, False
        for fn in (self._step_ragged, self._step_verify):
            size = getattr(fn, "_cache_size", None)
            if callable(size):
                found = True
                total += int(size())
        return total if found else -1

    # ------------------------------------------------- migration (the Proxy)
    def snapshot_load(self, instance_id: int,
                      step_time: Callable[[int, float], float]) -> DecodeLoad:
        """Planner view of this instance: the real slot cap (continuous batch
        width) plus the admission queue, so `DecodeLoad.effective_step` prices
        time-sharing beyond the cap exactly as the simulator does."""
        with self._cv:
            jobs = list(self._waiting)
            res = list(self._resident.values())
        ctx = sum(j.request.num_tokens + j.tokens_done for j in jobs) \
            + sum(j.request.num_tokens + j.tokens_done for j in res)
        if self.spec_decode:
            # migration gating prices per-ACCEPTED-token time: a step here
            # commits E[tokens/step] tokens, so the honest service rate is
            # the raw step time divided by the observed accept surface
            e = self._e_tokens()
            if e > 1.0:
                raw = step_time
                step_time = lambda b, c, _f=raw, _e=e: _f(b, c) / _e  # noqa: E731
        return DecodeLoad(instance_id=instance_id,
                          n_resident=len(res),
                          n_waiting=len(jobs), ctx_tokens=float(ctx),
                          max_batch=self.decode_max_batch,
                          step_time=step_time)

    def snapshot_candidates(self) -> List[DecodeCandidate]:
        """Queued (not resident) jobs as migration candidates."""
        with self._cv:
            jobs = list(self._waiting)
        return [DecodeCandidate(
            key=j.request.rid,
            context_tokens=float(j.request.num_tokens + j.tokens_done),
            remaining_tokens=float(j.target - j.tokens_done),
            deadline=j.request.decode_deadline,
            migrations=j.request.decode_migrations) for j in jobs]

    def take(self, rids: Sequence[int]) -> List[DecodeJob]:
        """Remove and return queued jobs by request id (migration departure).
        Jobs that became resident meanwhile are silently skipped — their KV
        is hot on this instance. An EVICTED stream whose KV still lives in
        the paged pool is gathered back into a dense handoff cache first."""
        want = set(rids)
        with self._cv:
            taken = [j for j in self._waiting if j.request.rid in want]
            self._waiting = [j for j in self._waiting
                             if j.request.rid not in want]
        # pool extraction waits on _kv_lock (up to one decode step) — do it
        # AFTER releasing _cv so the Proxy's submit/snapshot path never
        # stalls behind it; the popped jobs are invisible to the worker
        for job in taken:
            if job.request.rid in self._in_pool:
                self._extract_cache(job)
        return taken

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        self._thread.join(10.0)

    # ------------------------------------------------ supervised recovery
    def _supervised(self, loop: Callable[[], None]) -> None:
        """Worker wrapper: exceptions strand the instance instead of
        silently killing the thread; the thread survives for restart()."""
        while True:
            try:
                loop()
                return                      # clean shutdown exit
            except Exception as exc:
                self._on_worker_failure(exc)

    def _check_inject(self) -> None:
        """Chaos hook, called at the token boundary: raise a pending
        injected fault, or stall for a simulated hang."""
        inj = self._inject
        if inj is None:
            return
        self._inject = None
        if isinstance(inj, tuple) and inj and inj[0] == "hang":
            time.sleep(float(inj[1]))
            return
        raise inj if isinstance(inj, BaseException) \
            else RuntimeError(str(inj))

    def inject_fault(self, fault) -> None:
        with self._cv:
            self._inject = fault
            self._cv.notify_all()

    def _on_worker_failure(self, exc: Exception) -> None:
        """Idempotent strand: queued + resident jobs' requests return to
        `on_fault`; the paged pool is considered dead (recovery re-prefills
        from scratch, the simulator's KV-lost convention)."""
        with self._cv:
            if not self.healthy:
                return
            self.healthy = False
            self.last_error = exc
            self._epoch += 1
            victims = [j.request for j in self._resident.values()]
            victims += [j.request for j in self._waiting]
            self._resident.clear()
            self._waiting = []
            self._admitting = 0
            with self._kv_lock:
                self.kv = None              # pool died with the worker
            self._in_pool.clear()
            self._cv.notify_all()
        cb = self.on_fault
        if cb is not None:
            cb(victims, exc)                # outside _cv: Proxy re-enters

    def restart(self) -> None:
        with self._cv:
            self.healthy = True
            self.last_error = None
            self.last_progress = self.clock()
            self._cv.notify_all()

    @property
    def progress_ts(self) -> float:
        return self.last_progress

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until the instance is idle. Waits on the instance condition
        variable (the worker notifies on every completion) instead of the old
        5 ms busy-wait poll."""
        with self._cv:
            return self._cv.wait_for(
                lambda: not self._waiting and not self._resident
                and self._admitting == 0, timeout)

    # -------------------------------------------------------------- shared
    def _t_step(self, b: int, ctx: float) -> float:
        if self.step_pred is not None:
            return self.step_pred.step_time(b, ctx)
        return self._tbt_ema

    def _e_tokens(self, key: Optional[int] = None) -> float:
        """E[tokens committed per step] for S-EDF/budget pricing: the
        per-stream accept EMA when `key` has history, else the aggregate;
        exactly 1.0 with speculation off (all pricing unchanged)."""
        if not self.spec_decode:
            return 1.0
        if self.step_pred is not None:
            return self.step_pred.expected_tokens_per_step(key)
        return self._accept_tps if self._accept_tps > 0.0 else 1.0

    def _t_token(self, b: int, ctx: float,
                 key: Optional[int] = None) -> float:
        """Per-ACCEPTED-token service time — what TBT-deadline slack must be
        computed from: raw step time over expected tokens/step. Identical to
        `_t_step` without speculation."""
        return self._t_step(b, ctx) / self._e_tokens(key)

    def _observe_accept(self, rid: int, advance: int) -> None:
        """Record that one step committed `advance` tokens for stream rid."""
        if self.step_pred is not None:
            self.step_pred.observe_accept(rid, advance)
        a = 0.25 if self._accept_tps > 0.0 else 1.0
        self._accept_tps += a * (advance - self._accept_tps)

    def _entry(self, job: DecodeJob) -> DecodeEntry:
        return DecodeEntry(key=job.request.rid,
                           remaining_tokens=float(
                               job.target - job.tokens_done),
                           deadline=job.request.decode_deadline,
                           order=job.order)

    def _observe(self, b: int, ctx: float, tbt: float) -> None:
        a = 0.1 if self._tbt_ema > 0 else 1.0
        self._tbt_ema += a * (tbt - self._tbt_ema)
        if self.step_pred is not None:
            self.step_pred.observe(b, ctx, tbt)

    def _finish(self, job: DecodeJob, now: float) -> None:
        job.request.finish_time = now
        job.request.mean_tpot = (now - job.enqueued) / max(job.target, 1)
        self.finished.append(job.request)

    # ------------------------------------- single-stream worker (slot cap 1)
    def _pick_next_locked(self, now: float) -> DecodeJob:
        # caller holds _cv; _waiting is non-empty
        if len(self._waiting) == 1:
            return self._waiting.pop(0)
        ctx = sum(j.request.num_tokens + j.tokens_done
                  for j in self._waiting) / len(self._waiting)
        ranked = self.sched.rank([self._entry(j) for j in self._waiting],
                                 now, self._t_token(1, ctx))
        best = ranked[0].key
        for i, j in enumerate(self._waiting):
            if j.request.rid == best:
                return self._waiting.pop(i)
        return self._waiting.pop(0)       # unreachable; defensive

    def _should_yield(self, job: DecodeJob, now: float) -> bool:
        """Token-boundary preemption check: a strictly-higher-priority queued
        job displaces the running one."""
        if not (self.sched.policy == "s-edf" and self.sched.preempt):
            return False
        with self._cv:
            if not self._waiting:
                return False
            queued = list(self._waiting)
        ctx = job.request.num_tokens + job.tokens_done
        t_step = self._t_token(1, float(ctx), job.request.rid)
        own = self.sched.priority(self._entry(job), now, t_step)
        best = max(self.sched.priority(self._entry(j), now, t_step)
                   for j in queued)
        return best > own

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._waiting and not self._shutdown \
                        and self._inject is None:
                    self._cv.wait(0.1)
                if not self._waiting and self._inject is None:
                    return                     # shutdown with an empty queue
            self._check_inject()
            with self._cv:
                if not self._waiting:
                    continue
                job = self._pick_next_locked(self.clock())
                self._resident[job.request.rid] = job
                epoch = self._epoch
            start = job.first_token if job.next_token is None \
                else job.next_token
            tok = jnp.asarray([start], jnp.int32)
            cache = job.cache
            last = self.clock()
            while job.tokens_done < job.target:
                logits, cache = self._step(self.params, tok, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                now = self.clock()
                self.tbt_samples.append(now - last)
                self.step_samples.append(now - last)  # 1 token/step: equal
                self.row_steps += 1
                self._observe(
                    1, float(job.request.num_tokens + job.tokens_done),
                    now - last)
                last = now
                self.last_progress = now
                job.tokens_done += 1
                job.cache = cache
                job.next_token = int(tok[0])
                self._check_inject()           # chaos: token-boundary fault
                if self._epoch != epoch:
                    # stranded mid-decode (the hang injection sleeps right
                    # above, and the watchdog may strand AND restart() may
                    # run before we wake): this job was already re-dispatched
                    # — finishing it here would complete the request twice
                    break
                if job.tokens_done < job.target and \
                        self._should_yield(job, now):
                    job.request.decode_preemptions += 1
                    self.preemptions += 1
                    with self._cv:
                        self._waiting.append(job)
                        self._resident.pop(job.request.rid, None)
                        self._cv.notify_all()
                    break
            else:
                self._finish(job, self.clock())
                with self._cv:
                    self._resident.pop(job.request.rid, None)
                    self._cv.notify_all()

    # --------------------------------- continuous-batching worker (slots > 1)
    def _ensure_pool_locked(self, job: DecodeJob, need_blocks: int) -> None:
        """Create the paged pool on first admission (sized for 2x the slot
        cap at this stream's footprint) or grow it when a larger stream
        arrives while nothing can be freed."""
        k = job.cache["k"]
        L_, K, hd = k.shape[0], k.shape[-2], k.shape[-1]
        if self.kv is None:
            blocks = max((2 * self.decode_max_batch + 1) * need_blocks + 1, 8)
            self.kv = PagedKVCache(L_, blocks, self.kv_block_size, K, hd,
                                   dtype=k.dtype,
                                   prefix_share=self.prefix_share,
                                   max_blocks=self.kv_max_blocks)
            # scratch sequence: the slot padding rows of the batched step
            # write into / gather from (never read through a kv_len mask)
            self.kv.allocate(_SCRATCH_SEQ, 1)

    def _ingest(self, job: DecodeJob, force: bool = False) -> bool:
        """Move a stream's KV into the paged pool (no-op for an evicted
        stream whose blocks stayed resident). False = pool genuinely cannot
        hold it right now; the job goes back to the queue. ``force`` grows
        the pool instead of declining — the no-resident deadlock guard,
        where waiting for another stream's completion to free blocks can
        never succeed. Takes only _kv_lock (prompt ingestion is device I/O);
        the caller owns the job exclusively while it is neither waiting nor
        resident (`_admitting` keeps drain/idle honest meanwhile)."""
        rid = job.request.rid
        if rid in self._in_pool:
            return True
        pos = int(job.cache["pos"])
        remaining = job.target - job.tokens_done
        need_tokens = pos + max(remaining, 1)
        if self.spec_decode:
            # draft headroom: a verify step scatters the FULL k+1 span (the
            # jit shape is static even when only part of it commits), so the
            # last step may touch positions up to final_len + draft_k
            need_tokens += self.draft_k
        need_blocks = (need_tokens + self.kv_block_size - 1) \
            // self.kv_block_size
        with self._kv_lock:
            self._ensure_pool_locked(job, need_blocks)
            if not self.kv.can_allocate(need_tokens):
                # stay queued only if the pool COULD fit this stream once
                # residents complete; a footprint larger than the whole pool
                # (minus the scratch block) would starve forever under
                # continuous load — grow for it now. Growth is geometric
                # (doubling at least) so pool-shape recompiles of the
                # jitted scatters stay O(log): see kvcache._scatter_prompt
                can_ever_fit = need_blocks <= self.kv.num_blocks - 1
                if can_ever_fit and self._in_pool and not force:
                    return False
                try:
                    # capped doubling (same growth as before when no
                    # kv_max_blocks is set)
                    self.kv.grow_for(need_blocks)
                except MemoryError:
                    if not force:
                        return False    # cap reached: stream stays queued
                                        # (visible backlog, not silent OOM)
                    # the no-resident deadlock guard must make progress:
                    # exceed the cap rather than wedge the instance
                    self.kv.grow(max(need_blocks, self.kv.num_blocks))
            self.kv.allocate(rid, need_tokens)
            self.kv.write_prompt(rid, job.cache["k"][:, 0, :pos],
                                 job.cache["v"][:, 0, :pos])
        # the handoff cache's pos covers prompt + already-decoded tokens
        # (a migrated-in mid-stream job has tokens_done > 0), while the kv
        # position is computed as base_len + tokens_done — subtract so the
        # two bookkeepings agree
        job.base_len = pos - job.tokens_done
        job.cache = None                # the pool is now authoritative
        self._in_pool.add(rid)
        return True

    def _extract_cache(self, job: DecodeJob) -> None:
        """Gather an evicted stream's KV out of the pool back into the dense
        handoff-cache format (migration departure; caller owns the job —
        it is in neither the waiting list nor a slot). The dense view is
        padded to cover the REMAINING decode so a slot-cap-1 receiver (dense
        `decode_step`, which writes at `pos`) never runs off the cache."""
        rid = job.request.rid
        with self._kv_lock:
            k, v, length = self.kv.gather(rid)
            k = jax.block_until_ready(k)     # copy out before the worker's
            v = jax.block_until_ready(v)     # next donated scatter runs
            self.kv.free(rid)
        kv_len = job.base_len + job.tokens_done
        need = kv_len + max(job.target - job.tokens_done, 0) + 1
        keep = max(kv_len, int(length))
        k, v = k[:, None, :keep], v[:, None, :keep]
        if keep < need:
            pad = [(0, 0)] * k.ndim
            pad[2] = (0, need - keep)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        job.cache = {"k": k, "v": v, "pos": jnp.asarray(kv_len, jnp.int32)}
        self._in_pool.discard(rid)

    def _plan_locked(self, now: float) -> List[DecodeJob]:
        """Token-boundary admission + eviction DECISIONS (caller holds _cv):
        one `select_batch` over residents + waiting picks the new resident
        set (the simulator's `DecodeSim._rebatch`, verbatim policy core).
        Pool-resident streams (evicted earlier) are admitted in place —
        free. NEW streams are popped from the queue, counted in
        `_admitting`, and returned for the caller to ingest OUTSIDE the
        condition variable: prompt ingestion is device I/O, and holding _cv
        across it would stall the Proxy's submit/snapshot/migration path."""
        everyone: Dict[int, DecodeJob] = dict(self._resident)
        for j in self._waiting:
            everyone[j.request.rid] = j
        if not everyone:
            return []
        total = len(everyone)
        b_eff = min(self.decode_max_batch, total)
        ctx = sum(j.request.num_tokens + j.tokens_done
                  for j in everyone.values())
        # per-accepted-token pricing: S-EDF slack compares deadline headroom
        # against remaining_tokens * t, so t must be time-per-COMMITTED-token
        t_step = self._t_token(b_eff, ctx / total)
        entries = [self._entry(j) for j in everyone.values()]
        batch, preempted = self.sched.select_batch(
            entries, set(self._resident), self.decode_max_batch, now, t_step)
        for rid in preempted:
            # slot eviction: progress, pool blocks, and next token all kept
            job = self._resident.pop(rid)
            job.request.decode_preemptions += 1
            self.preemptions += 1
            self._waiting.append(job)
        to_ingest: List[DecodeJob] = []
        claimed = set()
        for rid in batch:
            if rid in self._resident:
                continue
            job = everyone[rid]
            claimed.add(rid)
            if rid in self._in_pool:
                self._resident[rid] = job          # re-admission is free
            else:
                self._admitting += 1
                to_ingest.append(job)
        if claimed:
            self._waiting = [j for j in self._waiting
                             if j.request.rid not in claimed]
        return to_ingest

    def _bucket(self, n: int, buckets: Sequence[int]) -> int:
        for b in buckets:
            if b >= n:
                return b
        return buckets[-1]

    def _step_batch(self, jobs: List[DecodeJob]) -> None:
        """One jitted decode step over the whole resident batch: gather the
        resident KV views, run `decode_step_ragged` at the padded bucket
        shape, scatter the new K/V back in one batched write."""
        n = len(jobs)
        bb = self._bucket(n, self._b_buckets)
        seq_ids = [j.request.rid for j in jobs] + \
            [_SCRATCH_SEQ] * (bb - n)
        kv_lens = np.zeros(bb, np.int32)
        tokens = np.zeros(bb, np.int32)
        for i, j in enumerate(jobs):
            kv_lens[i] = j.base_len + j.tokens_done
            tokens[i] = j.first_token if j.next_token is None else j.next_token
        t0 = self.clock()
        with self._kv_lock:
            # KV width bucket: power-of-two over the widest row's ALLOCATED
            # block count — gather_batch pads to at least the table width,
            # so bucketing the current kv_len instead would let per-stream
            # allocation sizes leak into the jitted shape (unbounded
            # recompiles)
            need_blocks = max(
                (len(self.kv.table(j.request.rid).blocks) for j in jobs),
                default=1)
            width = 1
            while width < need_blocks:
                width *= 2
            k_g, v_g, _ = self.kv.gather_batch(seq_ids, width)
            logits, k_new, v_new = self._step_ragged(
                self.params, jnp.asarray(tokens), k_g, v_g,
                jnp.asarray(kv_lens))
            next_tokens = np.asarray(jnp.argmax(logits, -1))
            self.kv.write_tokens(seq_ids, kv_lens.tolist(), k_new, v_new)
        # the next token cannot start before the scatter completes: stamp the
        # step AFTER write_tokens so observed dt matches what
        # profile_step_times measures (the prior the EMA calibrates against)
        now = self.clock()
        self.steps += 1
        self.last_progress = now
        dt = now - t0
        self.step_samples.append(dt)
        mean_ctx = float(kv_lens[:n].mean())
        self._observe(n, mean_ctx, dt)
        done: List[DecodeJob] = []
        self.row_steps += len(jobs)
        for i, j in enumerate(jobs):
            self.tbt_samples.append(dt)
            j.tokens_done += 1
            j.next_token = int(next_tokens[i])
            if self.spec_decode:
                # drafter corpus + accept surface stay current through the
                # plain-step fallback, or throttled streams would never see
                # their tokens/step settle to 1
                if j.history is None:
                    j.history = [int(tokens[i])]
                j.history.append(j.next_token)
                del j.history[:-_SPEC_HISTORY_CAP]
                self._observe_accept(j.request.rid, 1)
            if j.tokens_done >= j.target:
                done.append(j)
        self._retire_done(done, now)

    def _retire_done(self, done: List[DecodeJob], now: float) -> None:
        """Finish completed streams and release their pool blocks (shared
        tail of the plain and speculative batched steps)."""
        if not done:
            return
        with self._cv:
            for j in done:
                rid = j.request.rid
                if rid not in self._resident:
                    # stranded mid-step (watchdog fired while the jitted
                    # step compiled/ran): the request was re-dispatched —
                    # finishing it here would complete it twice
                    continue
                self._finish(j, now)
                self._resident.pop(rid, None)
                with self._kv_lock:
                    # a refcount DECREMENT, not an eager free: on a
                    # prefix-sharing pool blocks other streams still
                    # reference stay live, and trie-registered prompt
                    # blocks stay cached for the next matching prompt
                    if self.kv is not None:
                        self.kv.free(rid)
                self._in_pool.discard(rid)
                if self.step_pred is not None and self.spec_decode:
                    self.step_pred.forget_stream(rid)
            self._cv.notify_all()

    # -------------------------------------- speculative draft -> verify step
    def _draft_for(self, job: DecodeJob) -> List[int]:
        """Propose this step's draft for one stream (possibly empty).

        Adaptive throttle: when the stream's observed tokens/step EMA sits
        below `spec_throttle`, verification costs more latency than the
        committed tokens repay — draft nothing (the step then runs at plain
        shape) and re-probe every `spec_probe_period` steps in case the
        stream turned repetitive."""
        k = min(self.draft_k, job.target - job.tokens_done - 1)
        if k <= 0:
            return []
        rid = job.request.rid
        if self._e_tokens(rid) < self.spec_throttle:
            job.probe_in -= 1
            if job.probe_in > 0:
                return []
            job.probe_in = self.spec_probe_period
        if self.draft_fn is not None:
            d = [int(t) for t in self.draft_fn(rid, job.history, k)][:k]
        else:
            d = _ngram_draft(job.history, k)[:k]
        self.draft_proposed += len(d)
        return d

    def _spec_step_batch(self, jobs: List[DecodeJob]) -> None:
        """One speculative decode step: draft per row, ONE jitted k+1-wide
        verify pass (`decode_verify_ragged`) over the batch, greedy
        acceptance, multi-token commit with rejected-KV rollback by length
        truncation. When EVERY row drafts empty (throttled / no n-gram
        match) the step delegates to the plain `_step_batch` — graceful
        degradation to plain cost is what the fig27 low-accept gate holds."""
        for j in jobs:
            start = j.first_token if j.next_token is None else j.next_token
            if j.history is None:
                j.history = [start]
        drafts = [self._draft_for(j) for j in jobs]
        if not any(drafts):
            self._step_batch(jobs)
            return
        n = len(jobs)
        S = self.draft_k + 1
        bb = self._bucket(n, self._b_buckets)
        seq_ids = [j.request.rid for j in jobs] + [_SCRATCH_SEQ] * (bb - n)
        kv_lens = np.zeros(bb, np.int32)
        tokens = np.zeros((bb, S), np.int32)
        for i, (j, d) in enumerate(zip(jobs, drafts)):
            kv_lens[i] = j.base_len + j.tokens_done
            tokens[i, 0] = j.first_token if j.next_token is None \
                else j.next_token
            # short/empty drafts leave zero-padding in the tail columns:
            # their logits are computed but the acceptance scan below stops
            # at len(d), so they are never committed
            for s, t in enumerate(d):
                tokens[i, 1 + s] = t
        t0 = self.clock()
        with self._kv_lock:
            # pre-extend each row's block table to cover the FULL span the
            # verify step scatters (kv_len + S tokens) BEFORE gathering, so
            # the gathered width includes the draft positions (ingestion
            # already reserves draft_k headroom; this is the cheap invariant
            # check that keeps a migrated-in table safe)
            for i, j in enumerate(jobs):
                rid = j.request.rid
                need = int(kv_lens[i]) + S
                table = self.kv.table(rid)
                if len(table.blocks) * self.kv_block_size < need:
                    self.kv.extend(rid, need - table.length)
            need_blocks = max(
                (len(self.kv.table(j.request.rid).blocks) for j in jobs),
                default=1)
            width = 1
            while width < need_blocks:
                width *= 2
            k_g, v_g, _ = self.kv.gather_batch(seq_ids, width)
            logits, k_new, v_new = self._step_verify(
                self.params, jnp.asarray(tokens), k_g, v_g,
                jnp.asarray(kv_lens))
            greedy = np.asarray(jnp.argmax(logits, -1))       # (bb, S)
            # greedy acceptance: commit the longest draft prefix that
            # matches the argmax chain, plus the one token the verify pass
            # proves — bit-identical to plain greedy decoding by the
            # decode_verify_ragged column contract
            counts = [0] * bb              # scratch rows commit nothing
            advances = [1] * n
            for i, (j, d) in enumerate(zip(jobs, drafts)):
                a = 0
                while a < len(d) and d[a] == int(greedy[i, a]):
                    a += 1
                advances[i] = min(a + 1, j.target - j.tokens_done)
                counts[i] = advances[i]
            self.kv.write_token_span(seq_ids, kv_lens.tolist(), counts,
                                     k_new, v_new)
        now = self.clock()
        self.steps += 1
        self.spec_steps += 1
        self.last_progress = now
        dt = now - t0
        self.step_samples.append(dt)
        self._observe(n, float(kv_lens[:n].mean()), dt)
        done: List[DecodeJob] = []
        self.row_steps += n
        for i, (j, d) in enumerate(zip(jobs, drafts)):
            adv = advances[i]
            emitted = [int(greedy[i, s]) for s in range(adv)]
            j.history.extend(emitted)
            del j.history[:-_SPEC_HISTORY_CAP]
            j.tokens_done += adv
            j.next_token = emitted[-1]
            self.draft_accepted += adv - 1
            self._observe_accept(j.request.rid, adv)
            # per-accepted-token TBT: one sample per committed token so
            # percentile TBT gates stay meaningful at tokens/step > 1
            for _ in range(adv):
                self.tbt_samples.append(dt / adv)
            if j.tokens_done >= j.target:
                done.append(j)
        self._retire_done(done, now)

    def _run_batched(self) -> None:
        while True:
            with self._cv:
                while not self._waiting and not self._resident \
                        and not self._shutdown and self._inject is None:
                    self._cv.wait(0.1)
                if self._shutdown and not self._waiting \
                        and not self._resident:
                    return
            self._check_inject()
            with self._cv:
                to_ingest = self._plan_locked(self.clock())
            for job in to_ingest:                  # device I/O: no _cv held
                ok = self._ingest(job)
                with self._cv:
                    self._admitting -= 1
                    if ok:
                        self._resident[job.request.rid] = job
                    else:
                        self._waiting.append(job)
            with self._cv:
                force_job = None
                if not self._resident and self._waiting:
                    # deadlock guard: nothing is decoding, so no completion
                    # can ever free blocks for the declined admissions above
                    # — force the top-ranked stream in (grows the pool)
                    force_job = self._pick_next_locked(self.clock())
                    self._admitting += 1
            if force_job is not None:
                self._ingest(force_job, force=True)
                with self._cv:
                    self._admitting -= 1
                    self._resident[force_job.request.rid] = force_job
            with self._cv:
                batch = sorted(self._resident.values(), key=lambda j: j.order)
            if not batch:
                time.sleep(0.001)
                continue
            if self.spec_decode:
                self._spec_step_batch(batch)
            else:
                self._step_batch(batch)


def profile_step_times(params, cfg, *, batch_sizes: Sequence[int] = (1, 2, 4, 8),
                       ctx: int = 256, decode_tokens: int = 16,
                       warmup: int = 2, kv_block_size: int = 128,
                       attn_impl: str = "naive",
                       clock: Callable[[], float] = time.monotonic,
                       ) -> List[Tuple[int, float, float]]:
    """Measure the REAL batched decode step over a sweep of batch sizes.

    Drives `decode_step_ragged` + `PagedKVCache` directly (no threads): for
    each B, B synthetic streams with `ctx` prompt tokens decode
    `decode_tokens` tokens; the MEDIAN per-token wall time after `warmup`
    steps is recorded (robust to host scheduler jitter — decode steps are
    milliseconds, one descheduling would dominate a mean).
    Returns ``[(B, mean_context, seconds_per_step)]`` —
    the samples `DecodeStepPredictor.from_profile` fits its measured
    step-time prior from (the profiled replacement for the analytic
    `DecodeCostModel.step_time` seed), and the data behind
    benchmarks/fig21_decode_batching.py.
    """
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L_ = cfg.num_layers
    step = jax.jit(lambda p, t, kg, vg, kl: decode_step_ragged(
        p, cfg, t, kg, vg, kl, attn_impl=attn_impl))
    samples: List[Tuple[int, float, float]] = []
    rng = np.random.default_rng(0)
    for bsz in batch_sizes:
        tokens_cap = ctx + decode_tokens + warmup + 1
        blocks_per = (tokens_cap + kv_block_size - 1) // kv_block_size
        kv = PagedKVCache(L_, bsz * blocks_per + 1, kv_block_size, K, hd,
                          dtype=jnp.bfloat16)
        for s in range(bsz):
            kv.allocate(s, tokens_cap)
            kprompt = jnp.asarray(
                rng.standard_normal((L_, ctx, K, hd)), jnp.bfloat16)
            vprompt = jnp.asarray(
                rng.standard_normal((L_, ctx, K, hd)), jnp.bfloat16)
            kv.write_prompt(s, kprompt, vprompt)
        width = 1
        while width * kv_block_size < tokens_cap:
            width *= 2
        seq_ids = list(range(bsz))
        toks = np.asarray(rng.integers(0, cfg.vocab_size, bsz), np.int32)
        lens = np.full(bsz, ctx, np.int32)
        elapsed: List[float] = []
        ctx_timed: List[float] = []
        for it in range(decode_tokens + warmup):
            t0 = clock()
            k_g, v_g, _ = kv.gather_batch(seq_ids, width)
            logits, k_new, v_new = step(params, jnp.asarray(toks), k_g, v_g,
                                        jnp.asarray(lens))
            toks = np.asarray(jnp.argmax(logits, -1), np.int32)
            kv.write_tokens(seq_ids, lens.tolist(), k_new, v_new)
            t1 = clock()
            if it >= warmup:
                elapsed.append(t1 - t0)
                ctx_timed.append(float(lens.mean()))   # ctx the step RAN at
            lens += 1
        samples.append((bsz, float(np.mean(ctx_timed)),
                        float(np.median(elapsed))))
    return samples
