"""Decode instance (FlowPrefill §4, extended): autoregressive decode of
handed-over prefills (the PD-disaggregation KV transfer), with pluggable
batch-admission scheduling.

The paper's decode stage is deliberately plain FCFS; this instance keeps that
as the default but can run the SAME decode S-EDF policy the cluster simulator
evaluates (`repro.core.scheduler.DecodeSchedulerCore` — evaluated-is-deployed,
see docs/SCHEDULING.md):

  * ``policy="fcfs"``  — worker pops finished prefills in arrival order and
    decodes `decode_tokens` tokens per request (the original behavior).
  * ``policy="s-edf"`` — the worker picks the queued job with the highest
    TBT-deadline-slack priority, and (with ``preempt``) re-checks the queue at
    every TOKEN boundary: if a strictly-higher-priority job is waiting, the
    running decode is suspended mid-stream — progress, KV cache, and next
    token kept — and resumes later. This is the decode analogue of the
    paper's operator-level prefill preemption: scheduling stays event-driven
    while preemption granularity is one token.

Slack needs a per-token latency estimate: a `DecodeStepPredictor` (analytic
`DecodeCostModel.step_time` prior, EMA-calibrated from this instance's own
measured TBT samples) or, without one, a plain EMA of observed TBT.

Queued (not yet started) jobs can be handed to another instance by the Proxy
(decode migration): `snapshot_load`/`snapshot_candidates` feed the shared
cost-gated planner in `repro.core.dispatch`, `take` removes the chosen jobs.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.dispatch import DecodeCandidate, DecodeLoad
from repro.core.predictor import DecodeStepPredictor
from repro.core.request import Request
from repro.core.scheduler import DecodeEntry, DecodeSchedulerCore
from repro.models.model import decode_step


@dataclass
class DecodeJob:
    request: Request
    cache: Dict                     # model.decode_step cache (B=1 slice)
    first_token: int
    tokens_done: int = 0            # tokens already decoded (preemption state)
    next_token: Optional[int] = None  # resume point after a suspension
    enqueued: float = 0.0           # first submit (fixes the decode deadline)
    order: int = 0                  # FCFS order / deterministic tiebreak
    target: int = 0                 # tokens to decode for THIS job (set at
                                    # submit: request.output_tokens, or the
                                    # instance default) — deadlines and
                                    # remaining-work MUST use the same count


class DecodeInstance:
    def __init__(self, params, cfg, *, decode_tokens: int = 8,
                 clock: Callable[[], float] = time.monotonic,
                 policy: str = "fcfs", preempt: Optional[bool] = None,
                 step_predictor: Optional[DecodeStepPredictor] = None):
        self.params = params
        self.cfg = cfg
        self.decode_tokens = decode_tokens
        self.clock = clock
        self.sched = DecodeSchedulerCore(
            policy=policy, preempt=(policy == "s-edf") if preempt is None
            else preempt)
        self.step_pred = step_predictor
        self._tbt_ema = 0.0             # fallback t_step estimate (no prior)
        self._waiting: List[DecodeJob] = []
        self._active: Optional[DecodeJob] = None
        self._cv = threading.Condition()
        self._order = 0
        self._shutdown = False
        self.finished: List[Request] = []
        self.tbt_samples: List[float] = []
        self.preemptions = 0
        self._step = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c))
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="decode-instance")
        self._thread.start()

    # ------------------------------------------------------------- frontend
    def submit(self, job: DecodeJob) -> None:
        """Enqueue a decode job (fresh handoff or a migrated-in stream)."""
        req = job.request
        if req.decode_start is None:
            now = self.clock()
            job.enqueued = now
            req.decode_start = now      # fixes Request.decode_deadline
            if req.output_tokens <= 0:
                # the instance decodes exactly this many tokens; record it so
                # TBT accounting (decode_deadline / tbt_met) is well-defined
                req.output_tokens = self.decode_tokens
        if job.target <= 0:
            # deadline (output_tokens x tbt_slo) and remaining work must
            # count the SAME tokens, or slack estimates skew by their ratio
            job.target = req.output_tokens if req.output_tokens > 0 \
                else self.decode_tokens
        with self._cv:
            job.order = self._order
            self._order += 1
            self._waiting.append(job)
            self._cv.notify()

    def pending(self) -> int:
        """Decode jobs waiting in this instance's queue (the backlog signal
        decode-aware dispatch prices via DecodeCostModel.step_time)."""
        with self._cv:
            return len(self._waiting)

    def idle(self) -> bool:
        """No queued work and nothing decoding. NOTE: a job being migrated
        is momentarily in NO instance, so cross-instance quiescence must be
        checked under the owner's migration lock (Proxy.drain does)."""
        with self._cv:
            return not self._waiting and self._active is None

    # ------------------------------------------------- migration (the Proxy)
    def snapshot_load(self, instance_id: int,
                      step_time: Callable[[int, float], float]) -> DecodeLoad:
        """Planner view of this instance: the worker decodes one stream at a
        time, so the slot cap is 1 and queueing shows up as the N/1
        time-sharing factor in `DecodeLoad.effective_step`."""
        with self._cv:
            jobs = list(self._waiting)
            active = self._active
        ctx = sum(j.request.num_tokens + j.tokens_done for j in jobs)
        if active is not None:
            ctx += active.request.num_tokens + active.tokens_done
        return DecodeLoad(instance_id=instance_id,
                          n_resident=1 if active is not None else 0,
                          n_waiting=len(jobs), ctx_tokens=float(ctx),
                          max_batch=1, step_time=step_time)

    def snapshot_candidates(self) -> List[DecodeCandidate]:
        """Queued (never running) jobs as migration candidates."""
        with self._cv:
            jobs = list(self._waiting)
        return [DecodeCandidate(
            key=j.request.rid,
            context_tokens=float(j.request.num_tokens + j.tokens_done),
            remaining_tokens=float(j.target - j.tokens_done),
            deadline=j.request.decode_deadline,
            migrations=j.request.decode_migrations) for j in jobs]

    def take(self, rids: Sequence[int]) -> List[DecodeJob]:
        """Remove and return queued jobs by request id (migration departure).
        Jobs that started decoding meanwhile are silently skipped — their KV
        is hot on this instance."""
        want = set(rids)
        with self._cv:
            taken = [j for j in self._waiting if j.request.rid in want]
            self._waiting = [j for j in self._waiting
                             if j.request.rid not in want]
        return taken

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify()
        self._thread.join(10.0)

    def drain(self, timeout: float = 60.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._cv:
                if not self._waiting and self._active is None:
                    return True
            time.sleep(0.005)
        return False

    # -------------------------------------------------------------- worker
    def _t_step(self, ctx: float) -> float:
        if self.step_pred is not None:
            return self.step_pred.step_time(1, ctx)
        return self._tbt_ema

    def _entry(self, job: DecodeJob) -> DecodeEntry:
        return DecodeEntry(key=job.request.rid,
                           remaining_tokens=float(
                               job.target - job.tokens_done),
                           deadline=job.request.decode_deadline,
                           order=job.order)

    def _pick_next_locked(self, now: float) -> DecodeJob:
        # caller holds _cv; _waiting is non-empty
        if len(self._waiting) == 1:
            return self._waiting.pop(0)
        ctx = sum(j.request.num_tokens + j.tokens_done
                  for j in self._waiting) / len(self._waiting)
        ranked = self.sched.rank([self._entry(j) for j in self._waiting],
                                 now, self._t_step(ctx))
        best = ranked[0].key
        for i, j in enumerate(self._waiting):
            if j.request.rid == best:
                return self._waiting.pop(i)
        return self._waiting.pop(0)       # unreachable; defensive

    def _should_yield(self, job: DecodeJob, now: float) -> bool:
        """Token-boundary preemption check: a strictly-higher-priority queued
        job displaces the running one."""
        if not (self.sched.policy == "s-edf" and self.sched.preempt):
            return False
        with self._cv:
            if not self._waiting:
                return False
            queued = list(self._waiting)
        ctx = job.request.num_tokens + job.tokens_done
        t_step = self._t_step(float(ctx))
        own = self.sched.priority(self._entry(job), now, t_step)
        best = max(self.sched.priority(self._entry(j), now, t_step)
                   for j in queued)
        return best > own

    def _observe(self, job: DecodeJob, tbt: float) -> None:
        self.tbt_samples.append(tbt)
        a = 0.1 if self._tbt_ema > 0 else 1.0
        self._tbt_ema += a * (tbt - self._tbt_ema)
        if self.step_pred is not None:
            self.step_pred.observe(
                1, float(job.request.num_tokens + job.tokens_done), tbt)

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._waiting and not self._shutdown:
                    self._cv.wait(0.1)
                if not self._waiting:
                    return                     # shutdown with an empty queue
                job = self._pick_next_locked(self.clock())
                self._active = job
            start = job.first_token if job.next_token is None \
                else job.next_token
            tok = jnp.asarray([start], jnp.int32)
            cache = job.cache
            last = self.clock()
            while job.tokens_done < job.target:
                logits, cache = self._step(self.params, tok, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                now = self.clock()
                self._observe(job, now - last)
                last = now
                job.tokens_done += 1
                job.cache = cache
                job.next_token = int(tok[0])
                if job.tokens_done < job.target and \
                        self._should_yield(job, now):
                    job.request.decode_preemptions += 1
                    self.preemptions += 1
                    with self._cv:
                        self._waiting.append(job)
                        self._active = None
                        self._cv.notify()
                    break
            else:
                now = self.clock()
                job.request.finish_time = now
                job.request.mean_tpot = (now - job.enqueued) \
                    / max(job.target, 1)
                self.finished.append(job.request)
                with self._cv:
                    self._active = None
