"""Decode instance (FlowPrefill §4): reuses the framework's default execution
logic with FCFS scheduling — decoding optimization is explicitly out of the
paper's scope, so this instance is deliberately plain: a worker thread pops
finished prefills FCFS and autoregressively decodes `decode_tokens` tokens per
request using the handed-over KV cache (the PD-disaggregation KV transfer).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.request import Request
from repro.models.model import decode_step


@dataclass
class DecodeJob:
    request: Request
    cache: Dict                     # model.decode_step cache (B=1 slice)
    first_token: int


class DecodeInstance:
    def __init__(self, params, cfg, *, decode_tokens: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        self.params = params
        self.cfg = cfg
        self.decode_tokens = decode_tokens
        self.clock = clock
        self._q: "queue.Queue[Optional[DecodeJob]]" = queue.Queue()
        self.finished: List[Request] = []
        self.tbt_samples: List[float] = []
        self._step = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c))
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="decode-instance")
        self._thread.start()

    def submit(self, job: DecodeJob) -> None:
        self._q.put(job)

    def pending(self) -> int:
        """Decode jobs waiting in this instance's queue (the backlog signal
        decode-aware dispatch prices via DecodeCostModel.step_time)."""
        return self._q.qsize()

    def shutdown(self) -> None:
        self._q.put(None)
        self._thread.join(10.0)

    def drain(self, timeout: float = 60.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._q.qsize() == 0:
                return True
            time.sleep(0.005)
        return False

    def _run(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            tok = jnp.asarray([job.first_token], jnp.int32)
            cache = job.cache
            last = self.clock()
            for _ in range(self.decode_tokens):
                logits, cache = self._step(self.params, tok, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                now = self.clock()
                self.tbt_samples.append(now - last)
                last = now
            job.request.finish_time = self.clock()
            self.finished.append(job.request)
