"""Paged KV cache manager (vLLM-style block tables, jnp-native).

The decode instance allocates cache blocks per sequence from a shared pool;
`gather` materializes a contiguous (T, K, hd) view per layer for attention,
and the continuous-batching decode runtime uses the BATCHED pool I/O:

  * ``write_tokens(seq_ids, positions, k, v)`` — one jitted, donated scatter
    writes every resident stream's new token per step. The scalar ``write``
    is kept as the reference: each of its two functional ``.at[].set`` calls
    copies the ENTIRE pool, so per-token per-stream writes cost O(pool) each —
    the churn the batched path eliminates (donation lets XLA update in place).
  * ``gather_batch(seq_ids, width)`` — one jitted gather materializes the
    whole resident set as (L, B, T_pad, K, hd) dense views for the batched
    decode step, rows padded to a common block count.

PREFIX SHARING (``prefix_share=True``): block accounting is delegated to a
`repro.core.prefixcache.PrefixBlockManager` — per-block refcounts, a prefix
trie keyed on token-id block hashes (`block_keys`), LRU retention of
refcount-0 blocks instead of eager free, and copy-on-divergence when a write
lands in a shared or cached block. ``allocate(seq, n, keys=...)`` then pins
the cached prefix and allocates only the suffix; `free` becomes a refcount
decrement (blocks whose content is registered in the trie stay CACHED for
the next prompt that starts the same way). The default (``prefix_share=
False``) keeps the original allocator bit-for-bit: same LIFO free list, same
eager free, pinned by tests/test_prefix_cache.py.

Tested standalone (tests/test_property.py, tests/test_decode_batched.py,
tests/test_prefix_cache.py) incl. hypothesis properties: no double
allocation, free-list conservation under share/free interleavings, no block
reachable from two diverged suffixes, eviction never dropping a pinned
block, data round-trip.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prefixcache import PrefixBlockManager, block_keys
from repro.core.tieredcache import (TIER_HOST, BlockCopyEngine, TierDataError,
                                    TieredBlockManager, block_checksum)

__all__ = ["BlockTable", "PagedKVCache", "PromotionTicket", "block_keys"]


class PromotionTicket:
    """Handle for one batch of in-flight tier promotions started by
    `PagedKVCache.promote_async`. The protocol that keeps this deadlock-free:
    `wait` OUTSIDE the owner's kv lock (copy workers never take it), then
    `PagedKVCache.promote_settle(ticket)` UNDER the lock. A prefill that
    depends on the promoted blocks therefore BLOCKS until the copies land
    (or time out and abort back to their tier) — it never crashes into a
    half-copied block."""

    __slots__ = ("items",)

    def __init__(self, items):
        self.items = items               # [(key, reserved_block, tier, job)]

    @property
    def blocks(self) -> int:
        return len(self.items)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """True once every copy job finished (ok or errored) in time."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for _, _, _, job in self.items:
            t = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            if not job.wait(t):
                return False
        return True


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_tokens(k_pool, v_pool, blk, off, k, v):
    """Batched single-token scatter: pools (L, NB, bs, K, hd), blk/off (B,),
    k/v (L, B, K, hd). Donated pools let XLA write in place."""
    k_pool = k_pool.at[:, blk, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[:, blk, off].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_token_span(k_pool, v_pool, blk, off, k, v):
    """Batched multi-token scatter for speculative verify: pools
    (L, NB, bs, K, hd), blk/off (B, S), k/v (L, B, S, K, hd). Same donated
    in-place update as `_scatter_tokens`, one jit cache entry per (B, S)
    bucket. Scratch padding rows may repeat (blk, off) pairs — whichever
    write wins is garbage either way (positions past every committed
    length)."""
    k_pool = k_pool.at[:, blk, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[:, blk, off].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_prompt(k_pool, v_pool, blocks, k, v):
    """Bulk prompt scatter: pools (L, NB, bs, K, hd), blocks (nb,),
    k/v (L, nb, bs, K, hd) — the whole prompt lands in one donated update
    (the per-block functional loop copied the full pool per block).
    Retraces per distinct prompt block count nb (bounded by
    max-prompt-tokens / block_size — a one-time, admission-path cost, unlike
    the per-token step whose shapes the caller buckets) and per pool shape
    (`grow` itself is an exact primitive; the decode runtime requests
    doubling-at-least growth, so pool shapes occur O(log) times)."""
    k_pool = k_pool.at[:, blocks].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[:, blocks].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


@jax.jit
def _gather_blocks(k_pool, v_pool, tables):
    """tables (B, nb) block ids -> contiguous (L, B, nb*bs, K, hd) views."""
    k = k_pool[:, tables]                       # (L, B, nb, bs, K, hd)
    v = v_pool[:, tables]
    L_, B, nb, bs = k.shape[:4]
    k = k.reshape(L_, B, nb * bs, *k.shape[4:])
    v = v.reshape(L_, B, nb * bs, *v.shape[4:])
    return k, v


@dataclass
class BlockTable:
    seq_id: int
    blocks: List[int] = field(default_factory=list)
    length: int = 0                      # tokens currently stored
    prefix_blocks: int = 0               # leading blocks served from the
                                         # shared cache (prefix_share only)


class PagedKVCache:
    """Block pool shared by all sequences on one instance.

    Storage layout: k/v pools of shape (L, num_blocks, block_size, K, hd).

    ``prefix_share=True`` turns on block-level prefix sharing (module
    docstring); ``max_blocks`` caps `extend`'s geometric pool growth
    (0 = unbounded — growth doubles the pool, so shapes occur O(log) times).
    """

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.float32,
                 prefix_share: bool = False, max_blocks: int = 0,
                 host_cache_blocks: int = 0, disk_cache_blocks: int = 0,
                 disk_cache_dir: Optional[str] = None,
                 copy_engine: Optional[BlockCopyEngine] = None,
                 host_bw: float = 25e9, host_latency: float = 5e-4,
                 disk_bw: float = 3e9, disk_latency: float = 5e-3):
        self.num_layers = num_layers
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks = max_blocks
        shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self.prefix_share = prefix_share
        self.tiered = host_cache_blocks > 0
        if self.tiered and not prefix_share:
            raise ValueError("tiered KV cache requires prefix_share=True")
        if self.tiered:
            # demote-on-evict pool: LRU pressure moves cached block content
            # through host (and optionally disk) storage via the async copy
            # engine instead of dropping it (module docstring / tieredcache)
            self._mgr: Optional[PrefixBlockManager] = TieredBlockManager(
                num_blocks, host_blocks=host_cache_blocks,
                disk_blocks=disk_cache_blocks,
                on_demote=self._on_demote, on_drop=self._on_drop)
            self._engine = copy_engine if copy_engine is not None \
                else BlockCopyEngine()
            self._own_engine = copy_engine is None
            self._store_lock = threading.Lock()
            self._host_store: Dict[int, Tuple[np.ndarray, np.ndarray, int]] \
                = {}
            self._disk_index: Dict[int, str] = {}
            self._disk_dir = disk_cache_dir
            self._own_disk_dir = False
            if disk_cache_blocks > 0 and self._disk_dir is None:
                self._disk_dir = tempfile.mkdtemp(prefix="repro-kv-disk-")
                self._own_disk_dir = True
            self.host_bw, self.host_latency = host_bw, host_latency
            self.disk_bw, self.disk_latency = disk_bw, disk_latency
            self._bytes_per_token = (2 * num_layers * num_kv_heads * head_dim
                                     * jnp.zeros((), dtype).dtype.itemsize)
        else:
            self._mgr = PrefixBlockManager(num_blocks) if prefix_share \
                else None
            self._engine = None
        self._free: List[int] = [] if prefix_share \
            else list(range(num_blocks))
        self._tables: Dict[int, BlockTable] = {}

    # ------------------------------------------------------------ allocation
    @property
    def free_blocks(self) -> int:
        if self._mgr is not None:
            return self._mgr.free_blocks
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained for prefix reuse (share mode only)."""
        return self._mgr.cached_blocks if self._mgr is not None else 0

    def blocks_needed(self, num_tokens: int) -> int:
        return (num_tokens + self.block_size - 1) // self.block_size

    def can_allocate(self, num_tokens: int) -> bool:
        if self._mgr is not None:
            return self.blocks_needed(num_tokens) <= self._mgr.available()
        return self.blocks_needed(num_tokens) <= len(self._free)

    def probe(self, keys: Sequence[int]) -> int:
        """Cached-prefix length in TOKENS for a prompt whose block hash
        chain is `keys` (see `repro.core.prefixcache.block_keys`).
        0 without prefix sharing."""
        if self._mgr is None:
            return 0
        return self._mgr.probe_len(keys) * self.block_size

    # -------------------------------------------------------------- tiering
    def probe_tiers(self, keys: Sequence[int]) -> Tuple[int, int, int]:
        """(warm, host, disk) cached-prefix lengths in TOKENS: the
        HBM-resident run `probe` reports, then the contiguous cold run split
        by tier. Cold tokens are hittable only through `promote_async`;
        without tiering this is just (probe(keys), 0, 0) so callers can stay
        tier-agnostic."""
        if not self.tiered:
            return (self.probe(keys), 0, 0)
        th = self._mgr.probe_tiers(keys)
        bs = self.block_size
        return (th.hbm_blocks * bs, th.host_blocks * bs, th.disk_blocks * bs)

    def promote_seconds(self, host_tokens: int, disk_tokens: int = 0) -> float:
        """Predicted wall-clock to promote that many cold tokens back into
        HBM — the copy side of the promote-vs-recompute gate (the recompute
        side is the TTFT predictor's `ttft_saved`, exactly like cost-gated
        decode migration)."""
        t = 0.0
        if host_tokens > 0:
            t += self.host_latency \
                + host_tokens * self._bytes_per_token / self.host_bw
        if disk_tokens > 0:
            t += self.disk_latency \
                + disk_tokens * self._bytes_per_token / self.disk_bw
        return t

    def promote_async(self, keys: Sequence[int],
                      max_blocks: Optional[int] = None) -> PromotionTicket:
        """Start promoting the cold extension of `keys`' warm run: reserve
        HBM blocks (`promote_begin`) and enqueue one verify-and-fetch copy
        job per block. Call UNDER the owner's kv lock; then `ticket.wait`
        OUTSIDE it and `promote_settle(ticket)` back under it. Every
        reserved block is settled exactly once — commit or abort — so the
        conservation invariant holds through crashes of individual copies."""
        if not self.tiered:
            return PromotionTicket([])
        pairs = self._mgr.promote_begin(keys, max_blocks)
        items = []
        for key, block, tier in pairs:
            job = self._engine.submit("promote", key,
                                      lambda key=key: self._fetch_cold(key))
            items.append((key, block, tier, job))
        return PromotionTicket(items)

    def _fetch_cold(self, key: int) -> Tuple[np.ndarray, np.ndarray]:
        """Copy-worker body: pull the key's stored K/V (host store first,
        then disk), verify the checksum, and hand the arrays to settle.
        Move semantics — the cold copy is consumed. A lost or corrupt copy
        raises `TierDataError`: the promotion aborts-with-drop and the
        prefill recomputes those tokens instead of reading stale KV."""
        with self._store_lock:
            entry = self._host_store.pop(key, None)
            path = None if entry is not None \
                else self._disk_index.pop(key, None)
        if entry is not None:
            k_np, v_np, crc = entry
        elif path is not None:
            try:
                with np.load(path) as z:
                    k_np, v_np, crc = z["k"], z["v"], int(z["crc"])
                os.remove(path)
            except Exception as e:          # unreadable/garbled npz
                raise TierDataError(f"disk block for key {key:#x} lost: {e}")
        else:
            raise TierDataError(f"tier copy for key {key:#x} lost")
        if block_checksum(k_np, v_np) != crc:
            raise TierDataError(f"checksum mismatch for key {key:#x}")
        return k_np, v_np

    def promote_settle(self, ticket: PromotionTicket) -> int:
        """UNDER the kv lock: commit every landed copy (scatter the data
        into the reserved block, re-register the key) and abort the rest —
        a failed/corrupt copy drops its tier entry (recompute fallback), a
        timed-out one returns the key to its tier for a later try. Returns
        blocks committed."""
        if not self.tiered:
            return 0
        committed = 0
        for key, _block, _tier, job in ticket.items:
            if key not in self._mgr._promoting:
                continue                     # settled via an earlier ticket
            if job.done.is_set() and job.error is None \
                    and job.result is not None:
                k_np, v_np = job.result
                b = self._mgr.promote_commit(key)
                if b is not None:            # None: a twin re-registered key
                    self.k_pool = self.k_pool.at[:, b].set(
                        jnp.asarray(k_np, self.k_pool.dtype))
                    self.v_pool = self.v_pool.at[:, b].set(
                        jnp.asarray(v_np, self.v_pool.dtype))
                    committed += 1
            else:
                corrupt = isinstance(job.error, TierDataError)
                self._mgr.promote_abort(key, corrupt=corrupt)
        return committed

    def _on_demote(self, key: int, block: Optional[int], tier: int) -> None:
        """Manager demotion hook. HBM->host: slice the block's K/V NOW —
        an eager jax slice is an independent buffer, so the pool block can
        be reused (even via donated scatters) while the worker does the
        D2H copy + checksum off the critical path. Host->disk: the worker
        moves the host entry into an .npz spill file."""
        if tier == TIER_HOST:
            k_dev = self.k_pool[:, block]
            v_dev = self.v_pool[:, block]

            def snap(key=key, k_dev=k_dev, v_dev=v_dev):
                k_np, v_np = np.asarray(k_dev), np.asarray(v_dev)
                crc = block_checksum(k_np, v_np)
                with self._store_lock:
                    self._host_store[key] = (k_np, v_np, crc)

            self._engine.submit("demote", key, snap)
        else:
            def spill(key=key):
                with self._store_lock:
                    entry = self._host_store.pop(key, None)
                if entry is None:
                    return
                k_np, v_np, crc = entry
                path = os.path.join(self._disk_dir, f"kvblk_{key:08x}.npz")
                np.savez(path, k=k_np, v=v_np, crc=np.uint32(crc))
                with self._store_lock:
                    self._disk_index[key] = path

            self._engine.submit("spill", key, spill)

    def _on_drop(self, key: int, tier: int) -> None:
        """Manager drop hook: a cold entry aged out (or was corrupt) — free
        its stored data. Queued behind any pending snapshot/spill for the
        same key (single-worker FIFO), so a drop never races its own write."""
        def drop(key=key):
            with self._store_lock:
                self._host_store.pop(key, None)
                path = self._disk_index.pop(key, None)
            if path is not None:
                try:
                    os.remove(path)
                except OSError:
                    pass

        self._engine.submit("drop", key, drop)

    def tier_stats(self) -> Dict[str, int]:
        """Tier observability counters (benchmarks + tests)."""
        if not self.tiered:
            return {}
        m = self._mgr
        return {"demotions": m.demotions, "spills": m.spills,
                "promotions": m.promotions,
                "promote_aborts": m.promote_aborts,
                "tier_drops": m.tier_drops,
                "host_entries": m.host_entries,
                "disk_entries": m.disk_entries,
                "in_flight": m.in_flight,
                "copies_completed": self._engine.completed,
                "copies_failed": self._engine.failed}

    def close(self, timeout: float = 5.0) -> None:
        """Drain the copy engine, abort any promotion still in flight (its
        reserved block returns to the pool — no leaks), and clean up an
        owned disk spill directory. Safe to call twice; no-op untiered."""
        if not self.tiered:
            return
        self._engine.drain(timeout)
        if self._own_engine:
            self._engine.shutdown(wait=True)
        for key in list(self._mgr._promoting):
            self._mgr.promote_abort(key)
        if self._own_disk_dir and self._disk_dir \
                and os.path.isdir(self._disk_dir):
            shutil.rmtree(self._disk_dir, ignore_errors=True)
            self._own_disk_dir = False

    def allocate(self, seq_id: int, num_tokens: int,
                 keys: Optional[Sequence[int]] = None) -> BlockTable:
        """Allocate a sequence's block chain. With prefix sharing and a hash
        chain (`keys`), the longest cached prefix is PINNED (shared blocks,
        refcount bumped — their KV data is already in the pool) and only the
        suffix gets fresh blocks; the returned table's ``prefix_blocks`` /
        ``length`` reflect the tokens already present."""
        need = self.blocks_needed(num_tokens)
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        if self._mgr is not None:
            hit = self._mgr.acquire(seq_id, keys or (), need)
            table = BlockTable(seq_id=seq_id,
                               blocks=self._mgr.blocks_of(seq_id),
                               length=hit * self.block_size,
                               prefix_blocks=hit)
            self._tables[seq_id] = table
            return table
        if need > len(self._free):
            raise MemoryError(f"KV pool exhausted: need {need}, "
                              f"free {len(self._free)}")
        blocks = [self._free.pop() for _ in range(need)]
        table = BlockTable(seq_id=seq_id, blocks=blocks, length=0)
        self._tables[seq_id] = table
        return table

    def insert(self, seq_id: int, keys: Sequence[int]) -> int:
        """Register a completed sequence's leading blocks in the prefix trie
        (share mode): its prompt KV becomes hittable by later prompts with
        the same hash chain. No-op without sharing. Returns blocks added."""
        if self._mgr is None:
            return 0
        return self._mgr.register(seq_id, keys)

    def grow_for(self, need_blocks: int) -> None:
        """Geometric growth backing `extend` (and the decode runtime's
        admission growth): at least double the pool (so jitted
        scatter/gather shapes occur O(log) times), clamped to `max_blocks`.
        Raises MemoryError at the cap — the fail-fast backstop that makes a
        block leak surface as an error instead of unbounded device-memory
        doubling."""
        extra = max(need_blocks, self.num_blocks)
        if self.max_blocks > 0:
            extra = min(extra, self.max_blocks - self.num_blocks)
        if extra < need_blocks:
            raise MemoryError(
                f"KV pool at max_blocks={self.max_blocks} cap "
                f"(need {need_blocks} more)")
        self.grow(extra)

    def extend(self, seq_id: int, extra_tokens: int = 1) -> BlockTable:
        """Grow a sequence (decode appends); allocates blocks on demand.
        An exhausted free list GROWS the pool geometrically (`grow_for`,
        capped by ``max_blocks``) instead of raising — in share mode only
        after LRU eviction of refcount-0 cached blocks came up short."""
        table = self._tables[seq_id]
        target = table.length + extra_tokens
        need = self.blocks_needed(target) - len(table.blocks)
        if need <= 0:
            return table
        if self._mgr is not None:
            if self._mgr.available() < need:
                self.grow_for(need - self._mgr.available())
            table.blocks.extend(self._mgr.extend_seq(seq_id, need))
            return table
        if len(self._free) < need:
            self.grow_for(need - len(self._free))
        for _ in range(need):
            table.blocks.append(self._free.pop())
        return table

    def free(self, seq_id: int) -> None:
        """Release a sequence — in share mode a refcount DECREMENT per block
        (the decode instance's free): blocks still referenced by other
        sequences stay live, refcount-0 blocks registered in the trie stay
        CACHED (LRU-evictable), only unregistered ones return to the free
        list. Without sharing every block is exclusively held, so this is
        the original eager free."""
        table = self._tables.pop(seq_id)
        if self._mgr is not None:
            self._mgr.release(seq_id)
            return
        self._free.extend(table.blocks)

    def grow(self, extra_blocks: int) -> None:
        """Append `extra_blocks` fresh blocks to the pool (live tables keep
        their indices — new blocks land at the tail of both pools)."""
        if extra_blocks <= 0:
            return
        pad = [(0, 0)] * self.k_pool.ndim
        pad[1] = (0, extra_blocks)
        self.k_pool = jnp.pad(self.k_pool, pad)
        self.v_pool = jnp.pad(self.v_pool, pad)
        if self._mgr is not None:
            self._mgr.grow(extra_blocks)
        else:
            self._free.extend(range(self.num_blocks,
                                    self.num_blocks + extra_blocks))
        self.num_blocks += extra_blocks

    def table(self, seq_id: int) -> Optional[BlockTable]:
        return self._tables.get(seq_id)

    def accounting(self) -> Tuple[int, int, int, int]:
        """(free, live, cached, num_blocks) — the leak-free invariant is
        free + live + cached == num_blocks (asserted by tests after draining
        traces). Live counts DISTINCT blocks reachable from tables."""
        if self._mgr is not None:
            self._mgr.check()
            return (self._mgr.free_blocks, self._mgr.live_blocks,
                    self._mgr.cached_blocks, self.num_blocks)
        live = {b for t in self._tables.values() for b in t.blocks}
        return (len(self._free), len(live), 0, self.num_blocks)

    # ------------------------------------------------- copy-on-divergence
    def _writable_block(self, table: BlockTable, block_index: int) -> int:
        """Block id safe to WRITE at `block_index` of `table`'s chain. In
        share mode a shared block (refcount > 1) is replaced by a fresh
        private copy (data duplicated — the diverging writer must not
        clobber the other readers' prefix), and an exclusively-held but
        trie-registered block is unregistered (its cached content is about
        to change). Plain mode: the block itself."""
        b = table.blocks[block_index]
        if self._mgr is None:
            return b
        nb, copied = self._mgr.make_private(table.seq_id, block_index)
        if copied:
            self.k_pool = self.k_pool.at[:, nb].set(self.k_pool[:, b])
            self.v_pool = self.v_pool.at[:, nb].set(self.v_pool[:, b])
            table.blocks[block_index] = nb
            if block_index < table.prefix_blocks:
                table.prefix_blocks = block_index
        return nb

    # ------------------------------------------------------------------ data
    def write(self, seq_id: int, pos: int, k: jax.Array, v: jax.Array) -> None:
        """Write one token's K/V at absolute position pos. k/v: (L, K, hd).

        Scalar reference path: each functional ``.at[].set`` copies the
        ENTIRE pool — the batched equivalent `write_tokens` (one donated
        scatter for every resident stream) is the hot-path version, and in
        share mode both route the target block through copy-on-divergence
        (`_writable_block`) before touching it."""
        table = self._tables[seq_id]
        blk = self._writable_block(table, pos // self.block_size)
        off = pos % self.block_size
        self.k_pool = self.k_pool.at[:, blk, off].set(k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, blk, off].set(v.astype(self.v_pool.dtype))
        table.length = max(table.length, pos + 1)

    def write_prompt(self, seq_id: int, k: jax.Array, v: jax.Array,
                     start: int = 0) -> None:
        """Bulk write a prefilled prompt in ONE jitted, donated scatter.
        k/v: (L, T, K, hd) covering positions [start, start + T); `start`
        must be block-aligned — the prefix-sharing suffix write passes
        ``start = hit_tokens`` so the pinned shared blocks are never
        scattered into (their data is the hit). The final partial block's
        tail is zero-filled — positions past `length` are dead until a later
        write claims them (readers mask by kv_len), so this is equivalent to
        leaving them stale."""
        table = self._tables[seq_id]
        T = k.shape[1]
        if T == 0:
            return
        bs = self.block_size
        if start % bs != 0:
            raise ValueError(f"write_prompt start={start} must be a "
                             f"multiple of block_size={bs}")
        b0 = start // bs
        nb = (T + bs - 1) // bs
        if nb * bs != T:
            pad = [(0, 0)] * k.ndim
            pad[1] = (0, nb * bs - T)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        L_ = k.shape[0]
        k = k.reshape(L_, nb, bs, *k.shape[2:])
        v = v.reshape(L_, nb, bs, *v.shape[2:])
        if self._mgr is not None:
            for bi in range(b0, b0 + nb):
                self._writable_block(table, bi)
        blocks = jnp.asarray(table.blocks[b0:b0 + nb], jnp.int32)
        self.k_pool, self.v_pool = _scatter_prompt(
            self.k_pool, self.v_pool, blocks, k, v)
        table.length = max(table.length, start + T)

    def gather(self, seq_id: int):
        """Contiguous (L, T_padded, K, hd) view via the block table.

        Scalar reference path (one sequence); `gather_batch` is the batched
        equivalent for the resident set. Works unchanged under prefix
        sharing: a table's chain interleaves shared and private block ids
        transparently."""
        table = self._tables[seq_id]
        idx = jnp.asarray(table.blocks, dtype=jnp.int32)
        k = self.k_pool[:, idx]                     # (L, nb, bs, K, hd)
        v = self.v_pool[:, idx]
        L_, nb, bs = k.shape[:3]
        k = k.reshape(L_, nb * bs, *k.shape[3:])
        v = v.reshape(L_, nb * bs, *v.shape[3:])
        return k, v, table.length

    # ------------------------------------------------- batched pool I/O
    def write_tokens(self, seq_ids: Sequence[int], positions: Sequence[int],
                     k: jax.Array, v: jax.Array) -> None:
        """Write one token's K/V for EVERY listed sequence in one jitted,
        donated scatter. k/v: (L, B, K, hd), row i at absolute position
        positions[i] of seq_ids[i]. This replaces B pairs of O(pool)
        functional copies (see module docstring) with a single batched
        update whose recompile count is bounded by the caller's batch-shape
        buckets."""
        n = len(seq_ids)
        blk = np.empty(n, np.int32)
        off = np.empty(n, np.int32)
        for i, (sid, pos) in enumerate(zip(seq_ids, positions)):
            table = self._tables[sid]
            blk[i] = self._writable_block(table, pos // self.block_size)
            off[i] = pos % self.block_size
            table.length = max(table.length, pos + 1)
        self.k_pool, self.v_pool = _scatter_tokens(
            self.k_pool, self.v_pool, jnp.asarray(blk), jnp.asarray(off), k, v)

    def write_token_span(self, seq_ids: Sequence[int],
                         positions: Sequence[int], counts: Sequence[int],
                         k: jax.Array, v: jax.Array) -> None:
        """Speculative-verify sibling of `write_tokens`: write an S-token
        span per sequence in one jitted, donated scatter, but COMMIT only
        counts[i] tokens. k/v: (L, B, S, K, hd); row i's span starts at
        absolute position positions[i] of seq_ids[i].

        Rollback-by-truncation: all S positions are written physically (the
        scatter shape must stay static for the jit cache), but
        ``table.length`` only advances to positions[i] + counts[i] — rejected
        draft positions sit past the committed length, where every reader
        masks by per-row kv_len, and are simply overwritten by a later step.
        No stale KV is ever readable. Rows with counts[i] == 0 (scratch
        padding) commit nothing. The caller must have ``extend``ed each
        sequence's block table to cover positions[i] + S - 1 beforehand (the
        decode runtime pre-extends before gathering so the draft span is
        in-view)."""
        n = len(seq_ids)
        S = int(k.shape[2])
        blk = np.empty((n, S), np.int32)
        off = np.empty((n, S), np.int32)
        for i, (sid, pos) in enumerate(zip(seq_ids, positions)):
            table = self._tables[sid]
            if (pos + S - 1) // self.block_size >= len(table.blocks):
                raise ValueError(
                    f"seq {sid}: span [{pos}, {pos + S}) exceeds its "
                    f"{len(table.blocks)}-block table; extend before writing")
            for s in range(S):
                p = pos + s
                blk[i, s] = self._writable_block(table, p // self.block_size)
                off[i, s] = p % self.block_size
            if counts[i] > 0:
                table.length = max(table.length, pos + int(counts[i]))
        self.k_pool, self.v_pool = _scatter_token_span(
            self.k_pool, self.v_pool, jnp.asarray(blk), jnp.asarray(off), k, v)

    def gather_batch(self, seq_ids: Sequence[int],
                     width: int = 0) -> Tuple[jax.Array, jax.Array, np.ndarray]:
        """Batched `gather` for the resident set: (L, B, T_pad, K, hd) views
        plus the per-row valid lengths. Rows are padded to `width` blocks
        (>= every row's block count; 0 = the max over rows) with an arbitrary
        valid block — padded positions lie past each row's length, so the
        decode step's per-row kv_len mask never reads them."""
        tabs = [self._tables[sid] for sid in seq_ids]
        need = max((len(t.blocks) for t in tabs), default=1)
        width = max(width or need, need, 1)
        filler = next((t.blocks[0] for t in tabs if t.blocks), 0)
        arr = np.full((len(tabs), width), filler, np.int32)
        for i, t in enumerate(tabs):
            if t.blocks:
                arr[i, :len(t.blocks)] = t.blocks
        k, v = _gather_blocks(self.k_pool, self.v_pool, jnp.asarray(arr))
        lens = np.asarray([t.length for t in tabs], np.int32)
        return k, v, lens
