"""Paged KV cache manager (vLLM-style block tables, jnp-native).

The decode instance allocates cache blocks per sequence from a shared pool;
`gather` materializes a contiguous (T, K, hd) view per layer for attention.
Tested standalone (tests/test_kvcache.py) incl. hypothesis properties:
no double allocation, free-list conservation, data round-trip.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp


@dataclass
class BlockTable:
    seq_id: int
    blocks: List[int] = field(default_factory=list)
    length: int = 0                      # tokens currently stored


class PagedKVCache:
    """Block pool shared by all sequences on one decode instance.

    Storage layout: k/v pools of shape (L, num_blocks, block_size, K, hd).
    """

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.float32):
        self.num_layers = num_layers
        self.num_blocks = num_blocks
        self.block_size = block_size
        shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self._free: List[int] = list(range(num_blocks))
        self._tables: Dict[int, BlockTable] = {}

    # ------------------------------------------------------------ allocation
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, num_tokens: int) -> int:
        return (num_tokens + self.block_size - 1) // self.block_size

    def can_allocate(self, num_tokens: int) -> bool:
        return self.blocks_needed(num_tokens) <= len(self._free)

    def allocate(self, seq_id: int, num_tokens: int) -> BlockTable:
        need = self.blocks_needed(num_tokens)
        if need > len(self._free):
            raise MemoryError(f"KV pool exhausted: need {need}, "
                              f"free {len(self._free)}")
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        blocks = [self._free.pop() for _ in range(need)]
        table = BlockTable(seq_id=seq_id, blocks=blocks, length=0)
        self._tables[seq_id] = table
        return table

    def extend(self, seq_id: int, extra_tokens: int = 1) -> BlockTable:
        """Grow a sequence (decode appends); allocates blocks on demand."""
        table = self._tables[seq_id]
        target = table.length + extra_tokens
        while len(table.blocks) * self.block_size < target:
            if not self._free:
                raise MemoryError("KV pool exhausted on extend")
            table.blocks.append(self._free.pop())
        return table

    def free(self, seq_id: int) -> None:
        table = self._tables.pop(seq_id)
        self._free.extend(table.blocks)

    def table(self, seq_id: int) -> Optional[BlockTable]:
        return self._tables.get(seq_id)

    # ------------------------------------------------------------------ data
    def write(self, seq_id: int, pos: int, k: jax.Array, v: jax.Array) -> None:
        """Write one token's K/V at absolute position pos.
        k/v: (L, K, hd)."""
        table = self._tables[seq_id]
        blk = table.blocks[pos // self.block_size]
        off = pos % self.block_size
        self.k_pool = self.k_pool.at[:, blk, off].set(k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, blk, off].set(v.astype(self.v_pool.dtype))
        table.length = max(table.length, pos + 1)

    def write_prompt(self, seq_id: int, k: jax.Array, v: jax.Array) -> None:
        """Bulk write a prefilled prompt. k/v: (L, T, K, hd)."""
        table = self._tables[seq_id]
        T = k.shape[1]
        bs = self.block_size
        for i, blk in enumerate(table.blocks):
            lo, hi = i * bs, min((i + 1) * bs, T)
            if lo >= T:
                break
            self.k_pool = self.k_pool.at[:, blk, :hi - lo].set(
                k[:, lo:hi].astype(self.k_pool.dtype))
            self.v_pool = self.v_pool.at[:, blk, :hi - lo].set(
                v[:, lo:hi].astype(self.v_pool.dtype))
        table.length = max(table.length, T)

    def gather(self, seq_id: int):
        """Contiguous (L, T_padded, K, hd) view via the block table."""
        table = self._tables[seq_id]
        idx = jnp.asarray(table.blocks, dtype=jnp.int32)
        k = self.k_pool[:, idx]                     # (L, nb, bs, K, hd)
        v = self.v_pool[:, idx]
        L_, nb, bs = k.shape[:3]
        k = k.reshape(L_, nb * bs, *k.shape[3:])
        v = v.reshape(L_, nb * bs, *v.shape[3:])
        return k, v, table.length
