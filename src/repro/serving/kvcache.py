"""Paged KV cache manager (vLLM-style block tables, jnp-native).

The decode instance allocates cache blocks per sequence from a shared pool;
`gather` materializes a contiguous (T, K, hd) view per layer for attention,
and the continuous-batching decode runtime uses the BATCHED pool I/O:

  * ``write_tokens(seq_ids, positions, k, v)`` — one jitted, donated scatter
    writes every resident stream's new token per step. The scalar ``write``
    is kept as the reference: each of its two functional ``.at[].set`` calls
    copies the ENTIRE pool, so per-token per-stream writes cost O(pool) each —
    the churn the batched path eliminates (donation lets XLA update in place).
  * ``gather_batch(seq_ids, width)`` — one jitted gather materializes the
    whole resident set as (L, B, T_pad, K, hd) dense views for the batched
    decode step, rows padded to a common block count.

Tested standalone (tests/test_property.py, tests/test_decode_batched.py)
incl. hypothesis properties: no double allocation, free-list conservation,
data round-trip.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_tokens(k_pool, v_pool, blk, off, k, v):
    """Batched single-token scatter: pools (L, NB, bs, K, hd), blk/off (B,),
    k/v (L, B, K, hd). Donated pools let XLA write in place."""
    k_pool = k_pool.at[:, blk, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[:, blk, off].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_prompt(k_pool, v_pool, blocks, k, v):
    """Bulk prompt scatter: pools (L, NB, bs, K, hd), blocks (nb,),
    k/v (L, nb, bs, K, hd) — the whole prompt lands in one donated update
    (the per-block functional loop copied the full pool per block).
    Retraces per distinct prompt block count nb (bounded by
    max-prompt-tokens / block_size — a one-time, admission-path cost, unlike
    the per-token step whose shapes the caller buckets) and per pool shape
    (`grow` itself is an exact primitive; the decode runtime requests
    doubling-at-least growth, so pool shapes occur O(log) times)."""
    k_pool = k_pool.at[:, blocks].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[:, blocks].set(v.astype(v_pool.dtype))
    return k_pool, v_pool


@jax.jit
def _gather_blocks(k_pool, v_pool, tables):
    """tables (B, nb) block ids -> contiguous (L, B, nb*bs, K, hd) views."""
    k = k_pool[:, tables]                       # (L, B, nb, bs, K, hd)
    v = v_pool[:, tables]
    L_, B, nb, bs = k.shape[:4]
    k = k.reshape(L_, B, nb * bs, *k.shape[4:])
    v = v.reshape(L_, B, nb * bs, *v.shape[4:])
    return k, v


@dataclass
class BlockTable:
    seq_id: int
    blocks: List[int] = field(default_factory=list)
    length: int = 0                      # tokens currently stored


class PagedKVCache:
    """Block pool shared by all sequences on one decode instance.

    Storage layout: k/v pools of shape (L, num_blocks, block_size, K, hd).
    """

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.float32):
        self.num_layers = num_layers
        self.num_blocks = num_blocks
        self.block_size = block_size
        shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self._free: List[int] = list(range(num_blocks))
        self._tables: Dict[int, BlockTable] = {}

    # ------------------------------------------------------------ allocation
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, num_tokens: int) -> int:
        return (num_tokens + self.block_size - 1) // self.block_size

    def can_allocate(self, num_tokens: int) -> bool:
        return self.blocks_needed(num_tokens) <= len(self._free)

    def allocate(self, seq_id: int, num_tokens: int) -> BlockTable:
        need = self.blocks_needed(num_tokens)
        if need > len(self._free):
            raise MemoryError(f"KV pool exhausted: need {need}, "
                              f"free {len(self._free)}")
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        blocks = [self._free.pop() for _ in range(need)]
        table = BlockTable(seq_id=seq_id, blocks=blocks, length=0)
        self._tables[seq_id] = table
        return table

    def extend(self, seq_id: int, extra_tokens: int = 1) -> BlockTable:
        """Grow a sequence (decode appends); allocates blocks on demand."""
        table = self._tables[seq_id]
        target = table.length + extra_tokens
        while len(table.blocks) * self.block_size < target:
            if not self._free:
                raise MemoryError("KV pool exhausted on extend")
            table.blocks.append(self._free.pop())
        return table

    def free(self, seq_id: int) -> None:
        table = self._tables.pop(seq_id)
        self._free.extend(table.blocks)

    def grow(self, extra_blocks: int) -> None:
        """Append `extra_blocks` fresh blocks to the pool (live tables keep
        their indices — new blocks land at the tail of both pools)."""
        if extra_blocks <= 0:
            return
        pad = [(0, 0)] * self.k_pool.ndim
        pad[1] = (0, extra_blocks)
        self.k_pool = jnp.pad(self.k_pool, pad)
        self.v_pool = jnp.pad(self.v_pool, pad)
        self._free.extend(range(self.num_blocks,
                                self.num_blocks + extra_blocks))
        self.num_blocks += extra_blocks

    def table(self, seq_id: int) -> Optional[BlockTable]:
        return self._tables.get(seq_id)

    # ------------------------------------------------------------------ data
    def write(self, seq_id: int, pos: int, k: jax.Array, v: jax.Array) -> None:
        """Write one token's K/V at absolute position pos.
        k/v: (L, K, hd)."""
        table = self._tables[seq_id]
        blk = table.blocks[pos // self.block_size]
        off = pos % self.block_size
        self.k_pool = self.k_pool.at[:, blk, off].set(k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, blk, off].set(v.astype(self.v_pool.dtype))
        table.length = max(table.length, pos + 1)

    def write_prompt(self, seq_id: int, k: jax.Array, v: jax.Array) -> None:
        """Bulk write a prefilled prompt in ONE jitted, donated scatter.
        k/v: (L, T, K, hd). The final partial block's tail is zero-filled —
        positions past `length` are dead until a later write claims them
        (readers mask by kv_len), so this is equivalent to leaving them
        stale."""
        table = self._tables[seq_id]
        T = k.shape[1]
        if T == 0:
            return
        bs = self.block_size
        nb = (T + bs - 1) // bs
        if nb * bs != T:
            pad = [(0, 0)] * k.ndim
            pad[1] = (0, nb * bs - T)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        L_ = k.shape[0]
        k = k.reshape(L_, nb, bs, *k.shape[2:])
        v = v.reshape(L_, nb, bs, *v.shape[2:])
        blocks = jnp.asarray(table.blocks[:nb], jnp.int32)
        self.k_pool, self.v_pool = _scatter_prompt(
            self.k_pool, self.v_pool, blocks, k, v)
        table.length = max(table.length, T)

    def gather(self, seq_id: int):
        """Contiguous (L, T_padded, K, hd) view via the block table."""
        table = self._tables[seq_id]
        idx = jnp.asarray(table.blocks, dtype=jnp.int32)
        k = self.k_pool[:, idx]                     # (L, nb, bs, K, hd)
        v = self.v_pool[:, idx]
        L_, nb, bs = k.shape[:3]
        k = k.reshape(L_, nb * bs, *k.shape[3:])
        v = v.reshape(L_, nb * bs, *v.shape[3:])
        return k, v, table.length

    # ------------------------------------------------- batched pool I/O
    def write_tokens(self, seq_ids: Sequence[int], positions: Sequence[int],
                     k: jax.Array, v: jax.Array) -> None:
        """Write one token's K/V for EVERY listed sequence in one jitted,
        donated scatter. k/v: (L, B, K, hd), row i at absolute position
        positions[i] of seq_ids[i]. This replaces B pairs of O(pool)
        functional copies (see module docstring) with a single batched
        update whose recompile count is bounded by the caller's batch-shape
        buckets."""
        n = len(seq_ids)
        blk = np.empty(n, np.int32)
        off = np.empty(n, np.int32)
        for i, (sid, pos) in enumerate(zip(seq_ids, positions)):
            table = self._tables[sid]
            blk[i] = table.blocks[pos // self.block_size]
            off[i] = pos % self.block_size
            table.length = max(table.length, pos + 1)
        self.k_pool, self.v_pool = _scatter_tokens(
            self.k_pool, self.v_pool, jnp.asarray(blk), jnp.asarray(off), k, v)

    def gather_batch(self, seq_ids: Sequence[int],
                     width: int = 0) -> Tuple[jax.Array, jax.Array, np.ndarray]:
        """Batched `gather` for the resident set: (L, B, T_pad, K, hd) views
        plus the per-row valid lengths. Rows are padded to `width` blocks
        (>= every row's block count; 0 = the max over rows) with an arbitrary
        valid block — padded positions lie past each row's length, so the
        decode step's per-row kv_len mask never reads them."""
        tabs = [self._tables[sid] for sid in seq_ids]
        need = max((len(t.blocks) for t in tabs), default=1)
        width = max(width or need, need, 1)
        filler = next((t.blocks[0] for t in tabs if t.blocks), 0)
        arr = np.full((len(tabs), width), filler, np.int32)
        for i, t in enumerate(tabs):
            if t.blocks:
                arr[i, :len(t.blocks)] = t.blocks
        k, v = _gather_blocks(self.k_pool, self.v_pool, jnp.asarray(arr))
        lens = np.asarray([t.length for t in tabs], np.int32)
        return k, v, lens
