"""Proxy (FlowPrefill §4): receives frontend requests, dispatches them across
prefill instances via a pluggable instance-level policy (repro.core.dispatch —
the SAME policy objects the cluster simulator evaluates), hands completed
prefills to decode instances (the PD KV transfer), and aggregates results.

The proxy owns per-instance load accounting (`InstanceLoad`): outstanding
tokens are added at dispatch and retired when the instance reports the prefill
done, so load-aware policies (least-loaded / slack-aware deflection) see live
backlog without polling instance internals across threads.

Heterogeneous pools: pass `capacities` (peak prefill tokens/s per instance)
to feed capacity-weighted dispatch, and `decode_cost` (an analytic
DecodeCostModel) to derive downstream decode pressure for decode-aware
dispatch from each decode instance's live backlog. When the wired predictor
exposes `observe()` (OnlineTTFTPredictor), the proxy feeds measured prefill
latencies back on every completion — online refit against real hardware.

Prefix affinity: with a `needs_prefix` policy (``dispatch="prefix-
affinity"``) each dispatch decision probes every instance's prefix-sharing
KV cache for the arriving prompt (`PrefillInstance.probe_prefix`) and
attaches the hit plus its predictor-priced `ttft_saved` to the load
snapshot, so requests route to the instance already holding their prefix KV
unless its queue pressure outweighs the recompute saved
(docs/SCHEDULING.md).

Decode migration (``decode_migration=True``, needs `decode_cost`): after each
handoff the proxy re-plans with the SAME cost-gated planner the cluster
simulator uses (`repro.core.dispatch.plan_decode_migrations`) and moves
queued decode jobs off instances whose effective TBT pressure crossed the SLO
knee — the KV handoff is priced by `DecodeCostModel.kv_transfer_time` even
though the in-process transfer is a reference pass, so real decisions stay
conservative and consistent with the simulated ones (docs/SCHEDULING.md).
"""
from __future__ import annotations

import math
import threading
import time
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import (DispatchPolicy, InstanceLoad,
                                 competing_tokens, make_dispatch,
                                 plan_decode_migrations, predicted_ttft)
from repro.core.prefixcache import block_keys
from repro.core.metrics import (attainment_by_task, percentile_report,
                                slo_attainment, tbt_stats, ttft_stats)
from repro.core.predictor import TTFTPredictor
from repro.core.request import Request, RequestState
from repro.serving.decode_instance import DecodeInstance, DecodeJob
from repro.serving.pool import ExecTask
from repro.serving.prefill_instance import PrefillInstance


class Proxy:
    def __init__(self, prefill_instances: List[PrefillInstance],
                 decode_instances: Optional[List[DecodeInstance]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 dispatch: Union[str, DispatchPolicy] = "round-robin",
                 predictor: Optional[TTFTPredictor] = None,
                 capacities: Optional[Sequence[float]] = None,
                 decode_cost=None,
                 decode_migration: bool = False,
                 migration_knee: float = 0.85,
                 max_migrations: int = 1,
                 recovery: str = "retry",
                 max_retries: int = 3,
                 retry_backoff: float = 0.05,
                 retry_backoff_cap: float = 2.0,
                 watchdog_s: float = 0.0,
                 auto_restart_s: float = 0.0,
                 shed_policy: str = "off",
                 shed_budget: float = 2.0):
        if recovery not in ("none", "retry"):
            raise ValueError(f"unknown recovery mode {recovery!r}; "
                             f"known: ['none', 'retry']")
        if shed_policy not in ("off", "doomed-only", "budget"):
            raise ValueError(
                f"unknown shed_policy {shed_policy!r}; "
                f"known: ['off', 'doomed-only', 'budget']")
        self.prefill_instances = prefill_instances
        self.decode_instances = decode_instances or []
        self.clock = clock
        if predictor is None:
            # load-aware policies price backlog with the instances' own
            # TTFT predictor when available
            sched = getattr(prefill_instances[0], "scheduler", None)
            predictor = getattr(sched, "predictor", None)
        self.dispatch = make_dispatch(dispatch, predictor)
        if capacities is not None and len(capacities) != \
                len(prefill_instances):
            raise ValueError("capacities length must match prefill_instances")
        self.capacities = list(capacities) if capacities is not None \
            else [1.0] * len(prefill_instances)
        self.decode_cost = decode_cost        # analytic DecodeCostModel
        self.decode_migration = decode_migration and decode_cost is not None \
            and len(self.decode_instances) > 1
        self.migration_knee = migration_knee
        self.max_migrations = max_migrations
        self.decode_migrations = 0            # streams moved cross-instance
        self._migration_lock = threading.Lock()
        self._observe = getattr(self.dispatch.predictor, "observe", None)
        self._outstanding: List[dict] = [{} for _ in prefill_instances]
        self._load_lock = threading.Lock()
        self._rr_dec = 0
        self.requests: List[Request] = []
        self.dispatched: List[int] = [0] * len(prefill_instances)

        # ---------------- fault tolerance (docs/ARCHITECTURE.md) ----------
        # Supervised recovery: a failing instance strands its in-flight
        # requests back here via `on_fault`; the proxy re-dispatches them
        # with capped exponential backoff under a per-request retry budget
        # (the sim's ClusterSim.recover, identically). Invariant: no request
        # lost, none completed twice — `_completed_rids` dedupes zombie
        # prefill completions, the retained `_tokens` make full-recompute
        # retries possible after the instance's KV died with it.
        self.recovery = recovery
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.retry_backoff_cap = retry_backoff_cap
        self.watchdog_s = watchdog_s
        # supervisor restart policy: > 0 re-admits a failed instance after
        # this cooldown (the worker threads survive exceptions, so restart
        # is always safe). 0 = instances stay down until revive_instance()
        # — the chaos harness drives rejoins from its FaultPlan instead.
        self.auto_restart_s = auto_restart_s
        self.shed_policy = shed_policy
        self.shed_budget = shed_budget
        self.retries = 0                     # re-dispatches performed
        self.shed_requests = 0               # admission-control rejections
        self.lost_requests = 0               # retries exhausted / naive mode
        self.lost_rids: List[int] = []
        self._down: Set[int] = set()         # prefill idx marked unhealthy
        self._down_dec: Set[int] = set()     # decode idx marked unhealthy
        self._completed_rids: Set[int] = set()
        self._tokens: Dict[int, np.ndarray] = {}
        self._pending_retries = 0            # backoff timers not yet landed
        # requests in a handoff between tracked homes: popped from
        # _outstanding (done/fault callback) but not yet re-homed (decode
        # submit / retry timer / park / drop). drain() must not settle while
        # any exist — without this, a thread descheduled between the pop and
        # _recover's _pending_retries increment makes a wedged system look
        # quiescent (outstanding empty, pending 0) for the whole gap.
        self._inflight_handoffs = 0
        # adaptive watchdog backoff state (see _watchdog_loop): per-instance
        # multiplier on watchdog_s, doubled per fire, halved back toward 1.0
        # only after a fire-free interval of several effective periods
        self._wd_scale = [1.0] * len(prefill_instances)
        self._wd_scale_dec = [1.0] * len(self.decode_instances)
        self._wd_last_fire: Dict[int, float] = {}
        self._wd_last_fire_dec: Dict[int, float] = {}
        self._timers: List[threading.Timer] = []
        self._proxy_shutdown = False

        # wire prefill completion -> load retirement + decode handoff,
        # and worker failure -> supervised recovery
        for i, inst in enumerate(prefill_instances):
            inst.on_prefill_done = self._make_done_cb(i)
            if hasattr(inst, "on_fault"):
                inst.on_fault = self._make_fault_cb(i)
        for j, dec in enumerate(self.decode_instances):
            if hasattr(dec, "on_fault"):
                dec.on_fault = self._make_decode_fault_cb(j)

        self._watchdog_stop = threading.Event()
        self._watchdog: Optional[threading.Thread] = None
        if watchdog_s > 0:
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, daemon=True,
                name="proxy-watchdog")
            self._watchdog.start()

    # ------------------------------------------------------------- dispatch
    def _decode_pressure(self, prefill_idx: int, req: Request) -> float:
        """Downstream TBT pressure for the decode instance paired with
        `prefill_idx` (i mod D): the effective step time were this request's
        decode to join now (`DecodeLoad.effective_step` — the ONE slot-cap +
        queue-time-sharing formula shared with `DecodeSim.pressure` and the
        migration planner) over the candidate's TBT SLO. 0.0 without decode
        instances or a cost model."""
        if not self.decode_instances or self.decode_cost is None:
            return 0.0
        if req.tbt_slo <= 0 or req.tbt_slo == float("inf"):
            return 0.0
        dec = self.decode_instances[prefill_idx % len(self.decode_instances)]
        load = dec.snapshot_load(prefill_idx, self.decode_cost.step_time)
        return load.effective_step(1, float(req.num_tokens)) / req.tbt_slo

    def _ttft_saved(self, idx: int, req: Request, hit: int) -> float:
        """Predicted prefill seconds instance `idx`'s cached prefix would
        save this request: predictor-priced recompute of the hit tokens,
        falling back to capacity-normalized tokens (same units as drain
        time) when no predictor is wired."""
        return self._saved_seconds(idx, req.num_tokens, 0, hit)

    def _saved_seconds(self, idx: int, n: int, warm: int,
                       extra: int) -> float:
        """Predicted prefill seconds that `extra` additional cached tokens
        save, on top of `warm` tokens already served cached — the marginal
        value of a cold (tiered) run is priced from the warm baseline, not
        from zero."""
        if extra <= 0:
            return 0.0
        predict = getattr(self.dispatch.predictor, "predict", None)
        if predict is not None:
            return max(predict(n - warm) - predict(n - warm - extra), 0.0)
        return extra / max(self.capacities[idx], 1e-9)

    def _snapshot_loads(self, req: Request, now: float,
                        tokens=None) -> List[InstanceLoad]:
        """Per-instance competing-work snapshots for one dispatch decision
        (see repro.core.dispatch). Remaining tokens come from the requests'
        own progress counters, which the instances update as ops complete.
        Prefix-affinity policies additionally get each instance's cached-
        prefix hit for THIS prompt (`PrefillInstance.probe_prefix`) and its
        predictor-priced ttft_saved."""
        if not (self.dispatch.needs_loads or self.shed_policy != "off"):
            # admission control needs a real backlog view even under
            # load-oblivious dispatch (round-robin) — same forcing as
            # ClusterSim's arrival path
            return [InstanceLoad(instance_id=i)
                    for i in range(len(self._outstanding))]
        predict = getattr(self.dispatch.predictor, "predict", None)
        want_pressure = self.dispatch.needs_decode_pressure
        want_prefix = self.dispatch.needs_prefix and tokens is not None
        keys_by_bs: dict = {}
        if want_prefix:
            # hash the prompt ONCE per block size (instances normally share
            # one); each instance then only walks its trie
            tokens = np.asarray(tokens)
            for inst in self.prefill_instances:
                bs = inst.kv_block_size
                if bs not in keys_by_bs:
                    keys_by_bs[bs] = block_keys(tokens, bs)
        loads = []
        for i, outstanding in enumerate(self._outstanding):
            items = [(max(r.remaining_tokens(), 0.0), r.deadline)
                     for r in outstanding.values()]
            inst = self.prefill_instances[i]
            hit = cold = 0
            saved = promote_s = 0.0
            if want_prefix:
                inst_keys = keys_by_bs[inst.kv_block_size]
                n = int(tokens.size)
                probe_tiers = getattr(inst, "probe_keys_tiers", None)
                if probe_tiers is not None:
                    # tier-aware affinity: warm tokens are free, cold ones
                    # pay the promotion copy — the load carries the NET
                    # saving so warm/cold/absent are three prices to the
                    # policy, and an unprofitable cold run contributes
                    # nothing (the instance will recompute it)
                    warm, host_t, disk_t = probe_tiers(inst_keys, n)
                    cold = host_t + disk_t
                    hit = warm
                    saved = self._saved_seconds(i, n, 0, warm)
                    if cold > 0:
                        promote_s = inst.promote_seconds(host_t, disk_t)
                        net = self._saved_seconds(i, n, warm, cold) \
                            - promote_s
                        if net > 0:
                            saved += net
                            hit = warm + cold
                else:
                    hit = inst.probe_keys(inst_keys, n)
                    saved = self._ttft_saved(i, req, hit)
            loads.append(InstanceLoad(
                instance_id=i,
                queued_tokens=competing_tokens(items, req, now, predict),
                n_outstanding=len(outstanding),
                capacity=self.capacities[i],
                decode_pressure=self._decode_pressure(i, req)
                if want_pressure else 0.0,
                prefix_hit=hit,
                ttft_saved=saved,
                prefix_hit_cold=cold,
                promote_time=promote_s))
        return loads

    def submit(self, req: Request, tokens: np.ndarray) -> None:
        tokens = np.asarray(tokens)
        now = self.clock()
        with self._load_lock:
            self.requests.append(req)
            # retained for fault recovery: the dying instance's KV dies with
            # it, so a stranded request re-prefills from these tokens
            self._tokens[req.rid] = tokens
        if self.shed_policy != "off" and req.retries == 0:
            # SLO-aware admission control (sim-identical semantics): shed a
            # doomed FRESH arrival with an explicit rejection instead of
            # letting it queue, miss, and poison the p99 tail. Stranded-
            # then-recovered requests are never shed.
            with self._load_lock:
                loads = self._snapshot_loads(req, now, tokens)
                loads = [ld for ld in loads
                         if ld.instance_id not in self._down]
            if loads:
                best = min(predicted_ttft(req, ld, self.dispatch.predictor)
                           for ld in loads)
                if self.shed_policy == "doomed-only":
                    doomed = best > req.slo and \
                        all(ld.n_outstanding > 0 for ld in loads)
                else:                                           # "budget"
                    doomed = best > self.shed_budget * req.slo
                if doomed:
                    with self._load_lock:
                        req.state = RequestState.DROPPED
                        req.shed = True
                        self.shed_requests += 1
                        self._tokens.pop(req.rid, None)
                    return
        if not self._dispatch(req, tokens):
            self._park(req)

    def _dispatch(self, req: Request, tokens: np.ndarray) -> bool:
        """Dispatch to a live instance (down instances are excluded exactly
        like ClusterSim's arrival path). False when NO instance is live —
        the caller parks the request until one rejoins."""
        now = self.clock()
        with self._load_lock:
            if self.decode_instances and \
                    len(self._down_dec) == len(self.decode_instances):
                # prefilling now would only strand the handoff: every decode
                # instance is down, so hold the request until one rejoins
                return False
            loads = self._snapshot_loads(req, now, tokens)
            live = [ld for ld in loads if ld.instance_id not in self._down]
            if not live:
                return False
            idx = self.dispatch.select(req, live, now)
            self._outstanding[idx][req.rid] = req
            self.dispatched[idx] += 1
        self.prefill_instances[idx].submit_request(req, tokens)
        return True

    # ------------------------------------------------------ fault recovery
    def _make_fault_cb(self, idx: int):
        def cb(stranded: List[Request], exc: BaseException) -> None:
            with self._load_lock:
                self._down.add(idx)
                self._inflight_handoffs += len(stranded)
                for r in stranded:
                    self._outstanding[idx].pop(r.rid, None)
            try:
                self._arm_restart(idx, "prefill")
                self._recover(stranded)
            finally:
                with self._load_lock:
                    self._inflight_handoffs -= len(stranded)
        return cb

    def _make_decode_fault_cb(self, j: int):
        def cb(stranded: List[Request], exc: BaseException) -> None:
            with self._load_lock:
                self._down_dec.add(j)
                self._inflight_handoffs += len(stranded)
                for r in stranded:
                    # the decode KV died with the instance: recovery is a
                    # FULL re-prefill, so the rid must be completable again
                    self._completed_rids.discard(r.rid)
            try:
                self._arm_restart(j, "decode")
                self._recover(stranded)
            finally:
                with self._load_lock:
                    self._inflight_handoffs -= len(stranded)
        return cb

    def _arm_restart(self, idx: int, kind: str) -> None:
        if self.auto_restart_s <= 0:
            return
        t = threading.Timer(self.auto_restart_s, self.revive_instance,
                            args=(idx, kind))
        t.daemon = True
        with self._load_lock:
            if self._proxy_shutdown:
                return
            self._timers.append(t)
        t.start()

    @staticmethod
    def _reset_progress(req: Request) -> None:
        """Full progress reset before a re-dispatch: the partial prefill /
        decode state died with the instance (KV-lost convention, exactly the
        simulator's `recover`)."""
        req.state = RequestState.WAITING
        req.ops_done = 0
        req.ops_total = 0
        req.tokens_done = 0
        req.batch_members = []
        req.batch_tokens = req.num_tokens
        req.prefix_hit = 0
        req.first_token_time = None
        req.decode_start = None
        req.mean_tpot = None

    def _recover(self, stranded: List[Request]) -> None:
        """Re-dispatch stranded requests with capped exponential backoff
        under the per-request retry budget (ClusterSim.recover, identically:
        full progress reset — the KV is gone, recompute from scratch)."""
        for req in stranded:
            if req.finish_time is not None:
                continue                      # already terminal (paranoia)
            if self.recovery == "none" or req.retries >= self.max_retries:
                with self._load_lock:
                    req.state = RequestState.DROPPED
                    self.lost_requests += 1
                    self.lost_rids.append(req.rid)
                    self._tokens.pop(req.rid, None)
                continue
            req.retries += 1
            self._reset_progress(req)
            delay = min(self.retry_backoff * 2 ** (req.retries - 1),
                        self.retry_backoff_cap)
            with self._load_lock:
                self.retries += 1
                self._pending_retries += 1
            self._arm_retry(req, delay)

    def _arm_retry(self, req: Request, delay: float) -> None:
        t = threading.Timer(delay, self._retry_fire, args=(req,))
        t.daemon = True
        with self._load_lock:
            if self._proxy_shutdown:
                self._pending_retries -= 1
                return
            self._timers.append(t)
        t.start()

    def _retry_fire(self, req: Request) -> None:
        tokens = self._tokens.get(req.rid)
        if req.finish_time is not None or tokens is None \
                or self._proxy_shutdown:
            with self._load_lock:
                self._pending_retries -= 1
            return
        if self._dispatch(req, tokens):
            with self._load_lock:
                self._pending_retries -= 1
            return
        # every instance down: park at the cap delay WITHOUT charging a
        # retry — waiting for a rejoin is not the request's fault
        self._arm_park(req)

    def _park(self, req: Request) -> None:
        """No live instance at submit time: hold the request (counted as a
        pending retry so drain() waits for it) until one rejoins."""
        with self._load_lock:
            self._pending_retries += 1
        self._arm_park(req)

    def _arm_park(self, req: Request) -> None:
        t = threading.Timer(self.retry_backoff_cap, self._retry_fire,
                            args=(req,))
        t.daemon = True
        with self._load_lock:
            if self._proxy_shutdown:
                self._pending_retries -= 1
                return
            self._timers.append(t)
        t.start()

    # ---------------------------------------------------- chaos / watchdog
    def kill_instance(self, idx: int, kind: str = "prefill",
                      exc: Optional[BaseException] = None) -> None:
        """Chaos-harness entry point: crash one instance NOW. Its in-flight
        work strands to the recovery path; the instance stays excluded from
        dispatch until revive_instance()."""
        exc = exc or RuntimeError(f"injected crash: {kind}[{idx}]")
        if kind == "prefill":
            self.prefill_instances[idx]._on_worker_failure(exc)
        else:
            self.decode_instances[idx]._on_worker_failure(exc)

    def revive_instance(self, idx: int, kind: str = "prefill") -> None:
        """Delayed rejoin: restart the worker and readmit the instance to
        the dispatch pool."""
        if kind == "prefill":
            self.prefill_instances[idx].restart()
            with self._load_lock:
                self._down.discard(idx)
        else:
            self.decode_instances[idx].restart()
            with self._load_lock:
                self._down_dec.discard(idx)

    def _watchdog_loop(self) -> None:
        """Hang detection: a hung worker makes no progress but raises
        nothing — the only signal is a stalled progress timestamp while work
        is outstanding. Strand it like a crash (TimeoutError).

        The per-instance period is ADAPTIVE (the classic failure-detector
        compromise): a fixed timeout cannot separate a hang from an honest
        stall when the host is oversubscribed, and repeatedly stranding a
        slow-but-progressing worker livelocks recovery — every re-dispatch
        gets killed before it can finish. Each watchdog fire doubles that
        instance's effective period, so a sustained storm self-damps once
        the period outgrows the true stall scale. Decay keys on FIRE
        RECENCY, not on progress: an oversubscribed-but-honest worker shows
        fresh progress between the very hiccups that trip the watchdog, so
        progress-keyed decay would race the growth back down and the storm
        would never damp. Only after several fire-free effective periods
        does the scale halve back toward the configured base, restoring
        fast detection once the load subsides."""
        period = max(self.watchdog_s / 4.0, 0.01)

        def step(kind: str, k: int, scales: list, last_fire: dict,
                 obj, busy: bool, progress_ts: float, now: float) -> None:
            wd = self.watchdog_s * scales[k]
            if busy and now - progress_ts > wd:
                scales[k] = min(scales[k] * 2.0, 64.0)
                last_fire[k] = now
                obj._on_worker_failure(TimeoutError(
                    f"watchdog: {kind}[{k}] made no progress for "
                    f"{wd:.3f}s"))
            elif now - last_fire.get(k, -math.inf) > 4.0 * wd:
                scales[k] = max(scales[k] / 2.0, 1.0)

        while not self._watchdog_stop.wait(period):
            now = self.clock()
            for i, inst in enumerate(self.prefill_instances):
                if not getattr(inst, "healthy", True) or i in self._down:
                    continue
                with self._load_lock:
                    busy = bool(self._outstanding[i])
                step("prefill", i, self._wd_scale, self._wd_last_fire,
                     inst, busy, inst.progress_ts, now)
            for j, dec in enumerate(self.decode_instances):
                if not getattr(dec, "healthy", True) or j in self._down_dec:
                    continue
                step("decode", j, self._wd_scale_dec,
                     self._wd_last_fire_dec, dec, not dec.idle(),
                     dec.progress_ts, now)

    def _make_done_cb(self, idx: int) -> Callable[[ExecTask], None]:
        def cb(task: ExecTask) -> None:
            with self._load_lock:
                # exactly-once: a request re-dispatched after a fault may be
                # completed by two incarnations in pathological interleavings
                # (the instance-level zombie guard is the first defense);
                # only the FIRST completion proceeds to the decode handoff.
                keep = [i for i, r in enumerate(task.requests)
                        if r.rid not in self._completed_rids]
                for i in keep:
                    self._completed_rids.add(task.requests[i].rid)
                for r in task.requests:
                    self._outstanding[idx].pop(r.rid, None)
                if not self.decode_instances:
                    for r in task.requests:
                        self._tokens.pop(r.rid, None)
                # the kept requests are now in NO tracked home until the
                # decode submit (or park) below lands — hold drain open
                self._inflight_handoffs += len(keep)
            if not keep:
                return
            try:
                if self._observe is not None \
                        and task.complete_time is not None:
                    # online refit: measured service time of the batched
                    # prefill. complete_time is only ever set by the pool,
                    # which stamped submit_time first (possibly a legitimate
                    # 0.0 under an injected zero-based clock); observe()
                    # drops non-positive latencies itself.
                    self._observe(sum(r.num_tokens for r in task.requests),
                                  task.complete_time - task.submit_time)
                self._prefill_done(task, idx, keep)
            finally:
                with self._load_lock:
                    self._inflight_handoffs -= len(keep)
        return cb

    def _prefill_done(self, task: ExecTask, idx: int,
                      keep: Optional[List[int]] = None) -> None:
        if not self.decode_instances:
            return
        if keep is None:
            keep = list(range(len(task.requests)))
        with self._load_lock:           # called from every instance's thread
            live = [j for j in range(len(self.decode_instances))
                    if j not in self._down_dec]
            if not live:
                dec = None
            elif self.dispatch.needs_decode_pressure:
                # paired handoff (prefill i -> decode i mod D): keeps the
                # pressure signal attributable to the dispatch decision —
                # redirected to a live peer when the pair is down
                j = idx % len(self.decode_instances)
                if j not in live:
                    j = live[idx % len(live)]
                dec = self.decode_instances[j]
            else:
                dec = self.decode_instances[live[self._rr_dec % len(live)]]
                self._rr_dec += 1
        if dec is None:
            # nowhere live to decode: the prefill result dies with the
            # handoff — park the requests for re-prefill once a decode
            # instance rejoins. No retry charged: waiting out a pool-wide
            # outage is not the request's fault.
            victims = [task.requests[i] for i in keep]
            with self._load_lock:
                for r in victims:
                    self._completed_rids.discard(r.rid)
            for r in victims:
                self._reset_progress(r)
                self._park(r)
            return
        logits = task.prefill_task.logits
        first = jnp.argmax(logits, -1)
        st = task.prefill_task.state
        for i in keep:
            req = task.requests[i]
            # slice this request's cache row out of the batched prefill
            cache = {
                "k": st["k_cache"][:, i:i + 1],
                "v": st["v_cache"][:, i:i + 1],
                "pos": jnp.asarray(int(st["lens"][i]), jnp.int32),
            }
            dec.submit(DecodeJob(request=req, cache=cache,
                                 first_token=int(first[i])))
        if self.decode_migration:
            self.rebalance_decodes()

    def rebalance_decodes(self) -> int:
        """One pass of cost-gated decode migration (core/dispatch planner):
        queued jobs leave instances whose effective TBT pressure crossed the
        knee for the queue's streams. Returns the number of jobs moved.

        One pass at a time (`_migration_lock` — `_prefill_done` fires from
        every prefill instance's thread), and loads are re-snapshotted per
        SOURCE so a later source sees the jobs an earlier one just moved —
        matching ClusterSim's per-event `migrate_from` exactly; otherwise two
        over-the-knee sources planning from one stale snapshot would both
        dump onto the same destination and push it past the knee."""
        if self.decode_cost is None or len(self.decode_instances) < 2:
            return 0
        with self._load_lock:
            if self._down_dec:
                # no rebalancing during decode churn: the planner's loads
                # would nominate a down instance as a destination
                return 0
        moved = 0
        with self._migration_lock:
            for i, src in enumerate(self.decode_instances):
                if src.pending() == 0:
                    continue
                now = self.clock()
                loads = [dec.snapshot_load(j, self.decode_cost.step_time)
                         for j, dec in enumerate(self.decode_instances)]
                plan = plan_decode_migrations(
                    loads[i], src.snapshot_candidates(), loads, now,
                    transfer_time=self.decode_cost.kv_transfer_time,
                    knee=self.migration_knee,
                    max_migrations=self.max_migrations)
                for rid, dst_id, _ in plan:
                    for job in src.take([rid]):
                        job.request.decode_migrations += 1
                        self.decode_instances[dst_id].submit(job)
                        moved += 1
            self.decode_migrations += moved
        return moved

    def drain(self, timeout: float = 120.0) -> bool:
        """True iff every non-lost request reached its terminal state within
        `timeout`. Waits out in-flight backoff retries (`_pending_retries`)
        and re-checks from the top after each pass — a fault mid-drain
        re-queues work that an earlier check already saw as done. Down
        instances are skipped: their work was stranded to the retry path.

        ALL decode instances must be idle in one atomic observation under
        the migration lock: a migrating job is momentarily in NO instance
        (take -> submit inside rebalance_decodes), and per-instance
        sequential drains could each look empty while a job hops between
        already-checked instances."""
        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            with self._load_lock:
                busy = self._pending_retries > 0 \
                    or self._inflight_handoffs > 0
            if busy:
                time.sleep(0.005)
                continue
            live = [inst for i, inst in enumerate(self.prefill_instances)
                    if i not in self._down]
            if not all(inst.drain(min(remaining, 1.0)) for inst in live):
                continue
            if self.decode_instances:
                with self._migration_lock:
                    idle = all(dec.idle() for j, dec
                               in enumerate(self.decode_instances)
                               if j not in self._down_dec)
                if not idle:
                    time.sleep(0.005)
                    continue
            with self._load_lock:
                # settle check: a fault while we drained may have re-armed
                # a retry — only a pass with NO pending work all the way
                # through counts
                if self._pending_retries == 0 \
                        and self._inflight_handoffs == 0 \
                        and not any(self._outstanding):
                    return True
            time.sleep(0.005)

    def shutdown(self) -> None:
        with self._load_lock:
            self._proxy_shutdown = True
            timers = list(self._timers)
            self._timers.clear()
        for t in timers:
            t.cancel()
        self._watchdog_stop.set()
        if self._watchdog is not None:
            self._watchdog.join(2.0)
        for inst in self.prefill_instances:
            inst.shutdown()
        for dec in self.decode_instances:
            dec.shutdown()

    # ------------------------------------------------------------- metrics
    def _terminal(self, r: Request) -> bool:
        if r.state == RequestState.DROPPED:
            return True
        if r.output_tokens > 0:
            return r.finish_time is not None
        return r.first_token_time is not None

    def _spec_report(self) -> dict:
        proposed = sum(getattr(d, "draft_proposed", 0)
                       for d in self.decode_instances)
        accepted = sum(getattr(d, "draft_accepted", 0)
                       for d in self.decode_instances)
        steps = [s for d in self.decode_instances
                 for s in getattr(d, "step_samples", [])]
        tokens = sum(len(getattr(d, "tbt_samples", []))
                     for d in self.decode_instances)
        row_steps = sum(getattr(d, "row_steps", 0)
                        for d in self.decode_instances)
        return {
            "spec_steps": sum(getattr(d, "spec_steps", 0)
                              for d in self.decode_instances),
            "draft_proposed": proposed,
            "draft_accepted": accepted,
            "accept_rate": accepted / proposed if proposed else 0.0,
            # per-STREAM tokens committed per step (1.0 = plain decode);
            # independent of batch size by construction
            "tokens_per_step": tokens / row_steps if row_steps else 0.0,
            "step_latency_mean": float(np.mean(steps)) if steps else 0.0,
        }

    def report(self) -> dict:
        with self._load_lock:
            dispatched = list(self.dispatched)
            stranded = sorted(r.rid for r in self.requests
                              if not self._terminal(r))
            fault = {
                # supervised-recovery accounting (mirrors ClusterResult)
                "retries": self.retries,
                "shed_requests": self.shed_requests,
                "lost_requests": self.lost_requests,
                "lost_rids": sorted(self.lost_rids),
                # non-terminal at report time: after a clean drain this MUST
                # equal lost_rids' complement of nothing — any other rid here
                # is a stranded request the drain timed out on
                "stranded_rids": stranded,
                "pending_retries": self._pending_retries,
                "inflight_handoffs": self._inflight_handoffs,
                "down_instances": sorted(self._down),
                "down_decode_instances": sorted(self._down_dec),
                "instance_health": {
                    "prefill": [bool(getattr(i, "healthy", True))
                                for i in self.prefill_instances],
                    "decode": [bool(getattr(d, "healthy", True))
                               for d in self.decode_instances],
                },
            }
        return {
            **fault,
            "n_requests": len(self.requests),
            "dispatch_policy": self.dispatch.name,
            "dispatched_by_instance": dispatched,
            "slo_attainment": slo_attainment(self.requests),
            "by_task": attainment_by_task(self.requests),
            "ttft": ttft_stats(self.requests),
            "tbt": tbt_stats(self.requests),
            # full percentile families (p50/p90/p99 TTFT & TBT, aggregate +
            # per task, SLO-normalized p99s) — same shape as
            # ClusterResult.percentiles(): production SLOs gate on tails,
            # and a mid-run report counts unfinished requests as +inf tail
            # events rather than silently dropping them
            "percentiles": percentile_report(self.requests),
            "decode_migrations": self.decode_migrations,
            "decode_preemptions": sum(d.preemptions
                                      for d in self.decode_instances),
            "decode_steps": sum(getattr(d, "steps", 0)
                                for d in self.decode_instances),
            # speculative decoding: draft/accept counters plus the two
            # latencies multi-token steps split apart — per-accepted-token
            # TBT (tbt_samples, SLO basis) vs per-step wall latency
            # (step_samples, capacity basis). All zeros with spec off.
            "spec": self._spec_report(),
            "prefix_hits": sum(getattr(i, "prefix_hits", 0)
                               for i in self.prefill_instances),
            "prefix_hit_tokens": sum(getattr(i, "prefix_hit_tokens", 0)
                                     for i in self.prefill_instances),
            "prefix_promoted_tokens": sum(
                getattr(i, "prefix_promoted_tokens", 0)
                for i in self.prefill_instances),
            "scheduling_rounds": sum(i.scheduling_rounds
                                     for i in self.prefill_instances),
            "blocking_mean": float(np.mean(
                [i.blocking_stats.mean for i in self.prefill_instances])),
        }
