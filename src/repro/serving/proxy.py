"""Proxy (FlowPrefill §4): receives frontend requests, dispatches round-robin
to prefill instances, hands completed prefills to decode instances (the PD
KV transfer), and aggregates results. Instance-level load balancing beyond
round-robin is out of scope (paper §4)."""
from __future__ import annotations

import itertools
import time
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import attainment_by_task, slo_attainment, ttft_stats
from repro.core.request import Request
from repro.serving.decode_instance import DecodeInstance, DecodeJob
from repro.serving.pool import ExecTask
from repro.serving.prefill_instance import PrefillInstance


class Proxy:
    def __init__(self, prefill_instances: List[PrefillInstance],
                 decode_instances: Optional[List[DecodeInstance]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.prefill_instances = prefill_instances
        self.decode_instances = decode_instances or []
        self.clock = clock
        self._rr = itertools.cycle(range(len(prefill_instances)))
        self._rr_dec = itertools.cycle(range(max(len(self.decode_instances), 1)))
        self.requests: List[Request] = []
        # wire prefill completion -> decode handoff
        for inst in prefill_instances:
            inst.on_prefill_done = self._prefill_done

    def submit(self, req: Request, tokens: np.ndarray) -> None:
        self.requests.append(req)
        inst = self.prefill_instances[next(self._rr)]
        inst.submit_request(req, tokens)

    def _prefill_done(self, task: ExecTask) -> None:
        if not self.decode_instances:
            return
        dec = self.decode_instances[next(self._rr_dec)]
        logits = task.prefill_task.logits
        first = jnp.argmax(logits, -1)
        st = task.prefill_task.state
        for i, req in enumerate(task.requests):
            # slice this request's cache row out of the batched prefill
            cache = {
                "k": st["k_cache"][:, i:i + 1],
                "v": st["v_cache"][:, i:i + 1],
                "pos": jnp.asarray(int(st["lens"][i]), jnp.int32),
            }
            dec.submit(DecodeJob(request=req, cache=cache,
                                 first_token=int(first[i])))

    def drain(self, timeout: float = 120.0) -> bool:
        ok = all(inst.drain(timeout) for inst in self.prefill_instances)
        for dec in self.decode_instances:
            ok = dec.drain(timeout) and ok
        return ok

    def shutdown(self) -> None:
        for inst in self.prefill_instances:
            inst.shutdown()
        for dec in self.decode_instances:
            dec.shutdown()

    # ------------------------------------------------------------- metrics
    def report(self) -> dict:
        return {
            "n_requests": len(self.requests),
            "slo_attainment": slo_attainment(self.requests),
            "by_task": attainment_by_task(self.requests),
            "ttft": ttft_stats(self.requests),
            "scheduling_rounds": sum(i.scheduling_rounds
                                     for i in self.prefill_instances),
            "blocking_mean": float(np.mean(
                [i.blocking_stats.mean for i in self.prefill_instances])),
        }
