"""Proxy (FlowPrefill §4): receives frontend requests, dispatches them across
prefill instances via a pluggable instance-level policy (repro.core.dispatch —
the SAME policy objects the cluster simulator evaluates), hands completed
prefills to decode instances (the PD KV transfer), and aggregates results.

The proxy owns per-instance load accounting (`InstanceLoad`): outstanding
tokens are added at dispatch and retired when the instance reports the prefill
done, so load-aware policies (least-loaded / slack-aware deflection) see live
backlog without polling instance internals across threads.

Heterogeneous pools: pass `capacities` (peak prefill tokens/s per instance)
to feed capacity-weighted dispatch, and `decode_cost` (an analytic
DecodeCostModel) to derive downstream decode pressure for decode-aware
dispatch from each decode instance's live backlog. When the wired predictor
exposes `observe()` (OnlineTTFTPredictor), the proxy feeds measured prefill
latencies back on every completion — online refit against real hardware.

Prefix affinity: with a `needs_prefix` policy (``dispatch="prefix-
affinity"``) each dispatch decision probes every instance's prefix-sharing
KV cache for the arriving prompt (`PrefillInstance.probe_prefix`) and
attaches the hit plus its predictor-priced `ttft_saved` to the load
snapshot, so requests route to the instance already holding their prefix KV
unless its queue pressure outweighs the recompute saved
(docs/SCHEDULING.md).

Decode migration (``decode_migration=True``, needs `decode_cost`): after each
handoff the proxy re-plans with the SAME cost-gated planner the cluster
simulator uses (`repro.core.dispatch.plan_decode_migrations`) and moves
queued decode jobs off instances whose effective TBT pressure crossed the SLO
knee — the KV handoff is priced by `DecodeCostModel.kv_transfer_time` even
though the in-process transfer is a reference pass, so real decisions stay
conservative and consistent with the simulated ones (docs/SCHEDULING.md).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import (DispatchPolicy, InstanceLoad,
                                 competing_tokens, make_dispatch,
                                 plan_decode_migrations)
from repro.core.prefixcache import block_keys
from repro.core.metrics import (attainment_by_task, percentile_report,
                                slo_attainment, tbt_stats, ttft_stats)
from repro.core.predictor import TTFTPredictor
from repro.core.request import Request
from repro.serving.decode_instance import DecodeInstance, DecodeJob
from repro.serving.pool import ExecTask
from repro.serving.prefill_instance import PrefillInstance


class Proxy:
    def __init__(self, prefill_instances: List[PrefillInstance],
                 decode_instances: Optional[List[DecodeInstance]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 dispatch: Union[str, DispatchPolicy] = "round-robin",
                 predictor: Optional[TTFTPredictor] = None,
                 capacities: Optional[Sequence[float]] = None,
                 decode_cost=None,
                 decode_migration: bool = False,
                 migration_knee: float = 0.85,
                 max_migrations: int = 1):
        self.prefill_instances = prefill_instances
        self.decode_instances = decode_instances or []
        self.clock = clock
        if predictor is None:
            # load-aware policies price backlog with the instances' own
            # TTFT predictor when available
            sched = getattr(prefill_instances[0], "scheduler", None)
            predictor = getattr(sched, "predictor", None)
        self.dispatch = make_dispatch(dispatch, predictor)
        if capacities is not None and len(capacities) != \
                len(prefill_instances):
            raise ValueError("capacities length must match prefill_instances")
        self.capacities = list(capacities) if capacities is not None \
            else [1.0] * len(prefill_instances)
        self.decode_cost = decode_cost        # analytic DecodeCostModel
        self.decode_migration = decode_migration and decode_cost is not None \
            and len(self.decode_instances) > 1
        self.migration_knee = migration_knee
        self.max_migrations = max_migrations
        self.decode_migrations = 0            # streams moved cross-instance
        self._migration_lock = threading.Lock()
        self._observe = getattr(self.dispatch.predictor, "observe", None)
        self._outstanding: List[dict] = [{} for _ in prefill_instances]
        self._load_lock = threading.Lock()
        self._rr_dec = 0
        self.requests: List[Request] = []
        self.dispatched: List[int] = [0] * len(prefill_instances)
        # wire prefill completion -> load retirement + decode handoff
        for i, inst in enumerate(prefill_instances):
            inst.on_prefill_done = self._make_done_cb(i)

    # ------------------------------------------------------------- dispatch
    def _decode_pressure(self, prefill_idx: int, req: Request) -> float:
        """Downstream TBT pressure for the decode instance paired with
        `prefill_idx` (i mod D): the effective step time were this request's
        decode to join now (`DecodeLoad.effective_step` — the ONE slot-cap +
        queue-time-sharing formula shared with `DecodeSim.pressure` and the
        migration planner) over the candidate's TBT SLO. 0.0 without decode
        instances or a cost model."""
        if not self.decode_instances or self.decode_cost is None:
            return 0.0
        if req.tbt_slo <= 0 or req.tbt_slo == float("inf"):
            return 0.0
        dec = self.decode_instances[prefill_idx % len(self.decode_instances)]
        load = dec.snapshot_load(prefill_idx, self.decode_cost.step_time)
        return load.effective_step(1, float(req.num_tokens)) / req.tbt_slo

    def _ttft_saved(self, idx: int, req: Request, hit: int) -> float:
        """Predicted prefill seconds instance `idx`'s cached prefix would
        save this request: predictor-priced recompute of the hit tokens,
        falling back to capacity-normalized tokens (same units as drain
        time) when no predictor is wired."""
        return self._saved_seconds(idx, req.num_tokens, 0, hit)

    def _saved_seconds(self, idx: int, n: int, warm: int,
                       extra: int) -> float:
        """Predicted prefill seconds that `extra` additional cached tokens
        save, on top of `warm` tokens already served cached — the marginal
        value of a cold (tiered) run is priced from the warm baseline, not
        from zero."""
        if extra <= 0:
            return 0.0
        predict = getattr(self.dispatch.predictor, "predict", None)
        if predict is not None:
            return max(predict(n - warm) - predict(n - warm - extra), 0.0)
        return extra / max(self.capacities[idx], 1e-9)

    def _snapshot_loads(self, req: Request, now: float,
                        tokens=None) -> List[InstanceLoad]:
        """Per-instance competing-work snapshots for one dispatch decision
        (see repro.core.dispatch). Remaining tokens come from the requests'
        own progress counters, which the instances update as ops complete.
        Prefix-affinity policies additionally get each instance's cached-
        prefix hit for THIS prompt (`PrefillInstance.probe_prefix`) and its
        predictor-priced ttft_saved."""
        if not self.dispatch.needs_loads:
            return [InstanceLoad(instance_id=i)
                    for i in range(len(self._outstanding))]
        predict = getattr(self.dispatch.predictor, "predict", None)
        want_pressure = self.dispatch.needs_decode_pressure
        want_prefix = self.dispatch.needs_prefix and tokens is not None
        keys_by_bs: dict = {}
        if want_prefix:
            # hash the prompt ONCE per block size (instances normally share
            # one); each instance then only walks its trie
            tokens = np.asarray(tokens)
            for inst in self.prefill_instances:
                bs = inst.kv_block_size
                if bs not in keys_by_bs:
                    keys_by_bs[bs] = block_keys(tokens, bs)
        loads = []
        for i, outstanding in enumerate(self._outstanding):
            items = [(max(r.remaining_tokens(), 0.0), r.deadline)
                     for r in outstanding.values()]
            inst = self.prefill_instances[i]
            hit = cold = 0
            saved = promote_s = 0.0
            if want_prefix:
                inst_keys = keys_by_bs[inst.kv_block_size]
                n = int(tokens.size)
                probe_tiers = getattr(inst, "probe_keys_tiers", None)
                if probe_tiers is not None:
                    # tier-aware affinity: warm tokens are free, cold ones
                    # pay the promotion copy — the load carries the NET
                    # saving so warm/cold/absent are three prices to the
                    # policy, and an unprofitable cold run contributes
                    # nothing (the instance will recompute it)
                    warm, host_t, disk_t = probe_tiers(inst_keys, n)
                    cold = host_t + disk_t
                    hit = warm
                    saved = self._saved_seconds(i, n, 0, warm)
                    if cold > 0:
                        promote_s = inst.promote_seconds(host_t, disk_t)
                        net = self._saved_seconds(i, n, warm, cold) \
                            - promote_s
                        if net > 0:
                            saved += net
                            hit = warm + cold
                else:
                    hit = inst.probe_keys(inst_keys, n)
                    saved = self._ttft_saved(i, req, hit)
            loads.append(InstanceLoad(
                instance_id=i,
                queued_tokens=competing_tokens(items, req, now, predict),
                n_outstanding=len(outstanding),
                capacity=self.capacities[i],
                decode_pressure=self._decode_pressure(i, req)
                if want_pressure else 0.0,
                prefix_hit=hit,
                ttft_saved=saved,
                prefix_hit_cold=cold,
                promote_time=promote_s))
        return loads

    def submit(self, req: Request, tokens: np.ndarray) -> None:
        with self._load_lock:
            self.requests.append(req)
            idx = self.dispatch.select(req, self._snapshot_loads(
                req, self.clock(), tokens), self.clock())
            self._outstanding[idx][req.rid] = req
            self.dispatched[idx] += 1
        self.prefill_instances[idx].submit_request(req, tokens)

    def _make_done_cb(self, idx: int) -> Callable[[ExecTask], None]:
        def cb(task: ExecTask) -> None:
            with self._load_lock:
                for r in task.requests:
                    self._outstanding[idx].pop(r.rid, None)
            if self._observe is not None and task.complete_time is not None:
                # online refit: measured service time of the batched prefill.
                # complete_time is only ever set by the pool, which stamped
                # submit_time first (possibly a legitimate 0.0 under an
                # injected zero-based clock); observe() drops non-positive
                # latencies itself.
                self._observe(sum(r.num_tokens for r in task.requests),
                              task.complete_time - task.submit_time)
            self._prefill_done(task, idx)
        return cb

    def _prefill_done(self, task: ExecTask, idx: int) -> None:
        if not self.decode_instances:
            return
        with self._load_lock:           # called from every instance's thread
            if self.dispatch.needs_decode_pressure:
                # paired handoff (prefill i -> decode i mod D): keeps the
                # pressure signal attributable to the dispatch decision
                dec = self.decode_instances[idx % len(self.decode_instances)]
            else:
                dec = self.decode_instances[
                    self._rr_dec % len(self.decode_instances)]
                self._rr_dec += 1
        logits = task.prefill_task.logits
        first = jnp.argmax(logits, -1)
        st = task.prefill_task.state
        for i, req in enumerate(task.requests):
            # slice this request's cache row out of the batched prefill
            cache = {
                "k": st["k_cache"][:, i:i + 1],
                "v": st["v_cache"][:, i:i + 1],
                "pos": jnp.asarray(int(st["lens"][i]), jnp.int32),
            }
            dec.submit(DecodeJob(request=req, cache=cache,
                                 first_token=int(first[i])))
        if self.decode_migration:
            self.rebalance_decodes()

    def rebalance_decodes(self) -> int:
        """One pass of cost-gated decode migration (core/dispatch planner):
        queued jobs leave instances whose effective TBT pressure crossed the
        knee for the queue's streams. Returns the number of jobs moved.

        One pass at a time (`_migration_lock` — `_prefill_done` fires from
        every prefill instance's thread), and loads are re-snapshotted per
        SOURCE so a later source sees the jobs an earlier one just moved —
        matching ClusterSim's per-event `migrate_from` exactly; otherwise two
        over-the-knee sources planning from one stale snapshot would both
        dump onto the same destination and push it past the knee."""
        if self.decode_cost is None or len(self.decode_instances) < 2:
            return 0
        moved = 0
        with self._migration_lock:
            for i, src in enumerate(self.decode_instances):
                if src.pending() == 0:
                    continue
                now = self.clock()
                loads = [dec.snapshot_load(j, self.decode_cost.step_time)
                         for j, dec in enumerate(self.decode_instances)]
                plan = plan_decode_migrations(
                    loads[i], src.snapshot_candidates(), loads, now,
                    transfer_time=self.decode_cost.kv_transfer_time,
                    knee=self.migration_knee,
                    max_migrations=self.max_migrations)
                for rid, dst_id, _ in plan:
                    for job in src.take([rid]):
                        job.request.decode_migrations += 1
                        self.decode_instances[dst_id].submit(job)
                        moved += 1
            self.decode_migrations += moved
        return moved

    def drain(self, timeout: float = 120.0) -> bool:
        ok = all(inst.drain(timeout) for inst in self.prefill_instances)
        if not self.decode_instances:
            return ok
        # ALL decode instances must be idle in one atomic observation under
        # the migration lock: a migrating job is momentarily in NO instance
        # (take -> submit inside rebalance_decodes), and per-instance
        # sequential drains could each look empty while a job hops between
        # already-checked instances.
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._migration_lock:
                if all(dec.idle() for dec in self.decode_instances):
                    return ok
            time.sleep(0.005)
        return False

    def shutdown(self) -> None:
        for inst in self.prefill_instances:
            inst.shutdown()
        for dec in self.decode_instances:
            dec.shutdown()

    # ------------------------------------------------------------- metrics
    def report(self) -> dict:
        with self._load_lock:
            dispatched = list(self.dispatched)
        return {
            "n_requests": len(self.requests),
            "dispatch_policy": self.dispatch.name,
            "dispatched_by_instance": dispatched,
            "slo_attainment": slo_attainment(self.requests),
            "by_task": attainment_by_task(self.requests),
            "ttft": ttft_stats(self.requests),
            "tbt": tbt_stats(self.requests),
            # full percentile families (p50/p90/p99 TTFT & TBT, aggregate +
            # per task, SLO-normalized p99s) — same shape as
            # ClusterResult.percentiles(): production SLOs gate on tails,
            # and a mid-run report counts unfinished requests as +inf tail
            # events rather than silently dropping them
            "percentiles": percentile_report(self.requests),
            "decode_migrations": self.decode_migrations,
            "decode_preemptions": sum(d.preemptions
                                      for d in self.decode_instances),
            "decode_steps": sum(getattr(d, "steps", 0)
                                for d in self.decode_instances),
            "prefix_hits": sum(getattr(i, "prefix_hits", 0)
                               for i in self.prefill_instances),
            "prefix_hit_tokens": sum(getattr(i, "prefix_hit_tokens", 0)
                                     for i in self.prefill_instances),
            "prefix_promoted_tokens": sum(
                getattr(i, "prefix_promoted_tokens", 0)
                for i in self.prefill_instances),
            "scheduling_rounds": sum(i.scheduling_rounds
                                     for i in self.prefill_instances),
            "blocking_mean": float(np.mean(
                [i.blocking_stats.mean for i in self.prefill_instances])),
        }
