"""Hybrid (colocated prefill + decode) serving instance.

One worker thread drives token-budget rounds planned by
`HybridSchedulerCore`: every round packs the resident decode batch (one
token per stream) plus S-EDF-ranked prefill chunk slices onto the SAME
accelerator, against the SAME prefix-sharing `PagedKVCache`. The two
phases share blocks end-to-end, so a locally-decoded stream never pays a
PD handoff: at prefill completion the prompt KV is scattered into the
pool blocks the request already holds and the stream simply joins the
resident decode batch (zero copies, no dense-cache transfer).

Within a round, prefill advances ONE OPERATOR SEGMENT at a time
(`SegmentedPrefill.step`) and batched decode steps are WOVEN between
segments at an SLO-derived cadence (``cadence_margin x`` the tightest
resident TBT SLO): this is the colocation payoff of operator-level
interruption — a whole 512-token chunk costs many multiples of a decode
SLO, but a single operator segment costs ~1 ms, so decode tokens keep
flowing while the chunk computes. `HybridSim` in `sim/cluster.py` models
exactly this weave analytically; the measured interference the two agree
on replaces fig16's hard-coded utilization tax (see
`benchmarks/fig24_colocation.py`).

Preemption falls out of admission, as in the standalone engines: a
prefill not sliced this round keeps its device-resident task (the
operator cursor is untouched — it resumes at its exact operator offset),
and a decode stream squeezed out keeps its pool blocks and next token.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor import (DecodeStepPredictor, OnlineTTFTPredictor,
                                  TTFTPredictor)
from repro.core.prefixcache import block_keys
from repro.core.request import Request, RequestState
from repro.core.scheduler import (DecodeEntry, DecodeSchedulerCore,
                                  HybridSchedulerCore, SchedulerCore)
from repro.models.model import decode_step_ragged, supports_ragged_decode
from repro.models.segments import PrefillTask, SegmentedPrefill
from repro.serving.decode_instance import DecodeJob
from repro.serving.kvcache import PagedKVCache

# pool slot the batched decode step's padding rows write into / gather from
_SCRATCH_SEQ = -1


@dataclass
class _Prefill:
    """A request in its prefill phase. ``done_tokens`` is the scheduler's
    resume offset; the device-resident `PrefillTask` (created lazily at the
    first admitted slice) holds the matching operator cursor."""
    request: Request
    tokens: np.ndarray
    task: Optional[PrefillTask] = None
    done_tokens: int = 0
    keys: Tuple[int, ...] = ()
    hit: int = 0                        # pinned prefix tokens (capped n-1)
    allocated: bool = False             # pool blocks reserved at arrival
    started: float = 0.0                # first slice (predictor refit pair)
    ticket: Optional[object] = None     # in-flight tier PromotionTicket


@dataclass
class HybridJob:
    """A locally-decoding stream whose KV lives in the SHARED pool from
    birth — the prefill wrote it there, so there is nothing to ingest."""
    request: Request
    first_token: int
    tokens_done: int = 0
    next_token: Optional[int] = None
    enqueued: float = 0.0
    order: int = 0
    target: int = 0
    base_len: int = 0                   # prompt tokens (kv pos = base + done)
    last_emit: float = 0.0              # previous token's wall-clock (TBT)
    # full emitted trajectory ([first_token] + every decoded token) — the
    # parity handle tests compare against the standalone engines
    emitted: List[int] = field(default_factory=list)


class HybridInstance:
    """Colocated runtime: `HybridSchedulerCore` plans each round, the worker
    executes it as woven operator segments + batched decode steps."""

    def __init__(self, params, cfg, *, max_seq: int,
                 clock: Callable[[], float] = time.monotonic,
                 token_budget: int = 4096,
                 chunk_tokens: int = 512,
                 decode_max_batch: int = 8,
                 policy: str = "s-edf",
                 decode_policy: str = "s-edf",
                 decode_preempt: Optional[bool] = None,
                 predictor: Optional[TTFTPredictor] = None,
                 step_predictor: Optional[DecodeStepPredictor] = None,
                 decode_tokens: int = 8,
                 decode_cadence: float = 0.0,
                 cadence_margin: float = 0.8,
                 granularity: str = "op",
                 attn_impl: str = "xla",
                 batch_buckets: Sequence[int] = (1, 2, 4, 8),
                 kv_block_size: int = 128,
                 kv_pool_blocks: int = 512,
                 kv_max_blocks: int = 0,
                 host_cache_blocks: int = 0,
                 disk_cache_blocks: int = 0,
                 promote_wait_s: float = 10.0,
                 prefix_share: bool = True,
                 executor: Optional[SegmentedPrefill] = None,
                 on_decode_ready: Optional[Callable[[DecodeJob], None]]
                 = None):
        if not supports_ragged_decode(cfg):
            raise ValueError(f"hybrid decode needs the batched ragged step, "
                             f"unsupported for family {cfg.family!r}")
        if decode_max_batch < 1:
            raise ValueError("decode_max_batch must be >= 1")
        if chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1 (the weave quantum)")
        self.params = params
        self.cfg = cfg
        self.clock = clock
        self.max_seq = max_seq
        self.decode_tokens = decode_tokens
        self.decode_max_batch = decode_max_batch
        # 0.0 = derive per round from the tightest resident TBT SLO
        self.decode_cadence = decode_cadence
        self.cadence_margin = cadence_margin
        self.kv_block_size = kv_block_size
        self.step_pred = step_predictor
        # mixed-pool offload: when set, completed prefills are handed off as
        # dense-cache DecodeJobs (the PD path) instead of joining the local
        # batch — a hybrid becomes a weave-free prefill absorber while decode
        # consolidates on dedicated instances (ClusterSim's
        # hybrid_decode_offload models the same wiring)
        self.on_decode_ready = on_decode_ready

        if predictor is None and policy != "fcfs":
            # S-EDF needs a TTFT estimate; with no offline profile, start
            # from a mild linear prior and refit online from the prefill
            # latencies this instance itself observes
            predictor = OnlineTTFTPredictor(coeffs=np.array([0.0, 1e-4, 0.0]))
        self.predictor = predictor

        self.core = HybridSchedulerCore(
            prefill=SchedulerCore(predictor=predictor, policy=policy,
                                  enable_batching=False),
            decode=DecodeSchedulerCore(
                policy=decode_policy,
                preempt=(decode_policy == "s-edf") if decode_preempt is None
                else decode_preempt),
            token_budget=token_budget, chunk_tokens=chunk_tokens,
            decode_max_batch=decode_max_batch)
        self.executor = executor or SegmentedPrefill(
            params, cfg, max_seq=max_seq, granularity=granularity,
            chunk_tokens=chunk_tokens, attn_impl=attn_impl)

        self.prefix_share = prefix_share
        self.kv = PagedKVCache(
            cfg.num_layers, kv_pool_blocks, kv_block_size,
            cfg.num_kv_heads, cfg.resolved_head_dim,
            dtype=self.executor.cache_dtype, prefix_share=prefix_share,
            max_blocks=kv_max_blocks,
            host_cache_blocks=host_cache_blocks,
            disk_cache_blocks=disk_cache_blocks)
        self.promote_wait_s = promote_wait_s
        self.kv.allocate(_SCRATCH_SEQ, 1)
        # serializes pool access: the worker's gather/scatter (write_tokens
        # DONATES pool buffers) vs. the frontend's arrival-time allocate and
        # the Proxy's probe. Lock order: _cv -> _kv_lock.
        self._kv_lock = threading.Lock()

        self._b_buckets = sorted(
            {min(b, decode_max_batch) for b in batch_buckets if b >= 1}
            | {decode_max_batch})
        self._step_ragged = jax.jit(
            lambda p, t, kg, vg, kl: decode_step_ragged(
                p, cfg, t, kg, vg, kl, attn_impl="naive"))

        self._prefills: Dict[int, _Prefill] = {}
        self._jobs: Dict[int, HybridJob] = {}
        self._resident: Set[int] = set()
        self._order = 0
        self._tbt_ema = 0.0
        self._last_decode = clock()
        self._cv = threading.Condition()
        self._shutdown = False

        self.finished: List[Request] = []          # decoded to target
        self.finished_jobs: List[HybridJob] = []   # with emitted trajectories
        self.prefilled: List[Request] = []         # prefill phase completed
        self.tbt_samples: List[float] = []         # true inter-token gaps
        self.rounds = 0                            # hybrid steps planned
        self.steps = 0                             # batched decode steps
        self.preemptions = 0                       # decode slot evictions
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefix_promotions = 0                 # blocks re-warmed
        self.prefix_promoted_tokens = 0

        # supervised-worker health (docs/ARCHITECTURE.md failure model):
        # same contract as PrefillInstance/DecodeInstance — an exception in
        # the colocated worker strands every in-flight request (both phases)
        # to `on_fault` and flips healthy until restart().
        self.healthy = True
        self.on_fault: Optional[Callable] = None   # (requests, exc) -> None
        self.last_error: Optional[BaseException] = None
        self.last_progress = clock()
        self._inject: Optional[object] = None

        self._thread = threading.Thread(target=self._supervised, daemon=True,
                                        name="hybrid-instance")
        self._thread.start()

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request, tokens: np.ndarray) -> None:
        """Enqueue a request for prefill + (by default) local decode. Pool
        blocks for the WHOLE lifetime — prompt plus decode growth — are
        reserved here, so the later phase transition cannot fail."""
        tokens = np.asarray(tokens)
        req.state = RequestState.WAITING
        ps = _Prefill(request=req, tokens=tokens)
        self._acquire(ps)
        with self._cv:
            self._prefills[req.rid] = ps
            self._cv.notify_all()

    def probe_prefix(self, tokens: np.ndarray) -> int:
        """Cached-prefix tokens the shared pool holds for `tokens` (the
        prefix-affinity dispatch signal; same contract as PrefillInstance)."""
        tokens = np.asarray(tokens)
        return self.probe_keys(block_keys(tokens, self.kv_block_size),
                               int(tokens.size))

    def probe_keys(self, keys, num_tokens: int) -> int:
        if not self.prefix_share:
            return 0
        with self._kv_lock:
            hit = self.kv.probe(keys)
        return min(hit, max(num_tokens - 1, 0))

    def probe_keys_tiers(self, keys, num_tokens: int) -> Tuple[int, int, int]:
        """(warm, host, disk) cached tokens for this prompt, jointly capped
        at num_tokens - 1 (same contract as PrefillInstance)."""
        if not self.prefix_share:
            return (0, 0, 0)
        with self._kv_lock:
            warm, host, disk = self.kv.probe_tiers(keys)
        cap = max(num_tokens - 1, 0)
        warm = min(warm, cap)
        host = min(host, cap - warm)
        disk = min(disk, cap - warm - host)
        return warm, host, disk

    def promote_seconds(self, host_tokens: int, disk_tokens: int = 0) -> float:
        if not getattr(self.kv, "tiered", False):
            return 0.0
        return self.kv.promote_seconds(host_tokens, disk_tokens)

    def pending(self) -> int:
        with self._cv:
            return len(self._prefills)

    def resident(self) -> int:
        with self._cv:
            return len(self._jobs)

    def idle(self) -> bool:
        with self._cv:
            return not self._prefills and not self._jobs

    def compile_cache_size(self) -> int:
        size = getattr(self._step_ragged, "_cache_size", None)
        return int(size()) if callable(size) else -1

    def drain(self, timeout: float = 120.0) -> bool:
        """Wait until every submitted request finished both phases."""
        with self._cv:
            return self._cv.wait_for(
                lambda: not self._prefills and not self._jobs, timeout)

    def shutdown(self) -> None:
        self._shutdown = True
        with self._cv:
            self._cv.notify_all()
        self._thread.join(5.0)
        if getattr(self.kv, "tiered", False):
            # settle promotions whose prefill never started (abandoned
            # requests): abort reservations so the pool stays leak-free
            with self._cv:
                pending = [ps for ps in self._prefills.values()
                           if ps.ticket is not None]
            for ps in pending:
                ticket, ps.ticket = ps.ticket, None
                with self._kv_lock:
                    self.kv.promote_settle(ticket)
            self.kv.close()

    # ----------------------------------------------------------- supervision
    def _supervised(self) -> None:
        """Worker wrapper: catch any exception, strand the in-flight work to
        the Proxy and keep the THREAD alive so restart() is a state flip."""
        while True:
            try:
                self._run()
                return                      # clean shutdown
            except Exception as exc:
                self._on_worker_failure(exc)

    def _check_inject(self) -> None:
        """Chaos hook at the round boundary: ("hang", s) stalls the worker
        outside every lock (so the watchdog can strand it); an Exception
        crashes the round."""
        inj = self._inject
        if inj is None:
            return
        self._inject = None
        if isinstance(inj, tuple) and inj and inj[0] == "hang":
            time.sleep(float(inj[1]))
            return
        if isinstance(inj, BaseException):
            raise inj
        raise RuntimeError(f"injected fault: {inj!r}")

    def inject_fault(self, fault: object) -> None:
        """Deliver a chaos-harness fault to the worker (core/faults.py)."""
        with self._cv:
            self._inject = fault
            self._cv.notify_all()

    def _on_worker_failure(self, exc: BaseException) -> None:
        """Strand EVERY in-flight request (both phases) to on_fault. The
        pool KV for this instance is considered lost: the Proxy re-dispatches
        from scratch (recompute > resurrecting half-written pool blocks)."""
        with self._cv:
            if not self.healthy:
                return
            self.healthy = False
            self.last_error = exc
            stranded = [ps.request for ps in self._prefills.values()]
            stranded += [j.request for j in self._jobs.values()]
            self._prefills.clear()
            self._jobs.clear()
            self._resident.clear()
            self._cv.notify_all()
        cb = self.on_fault
        if cb is not None:
            cb(stranded, exc)

    def restart(self) -> None:
        """Revive after a failure: the worker thread survived the exception
        (supervised), so this is just the health flip + progress stamp."""
        with self._cv:
            self.healthy = True
            self.last_error = None
            self._inject = None
            self.last_progress = self.clock()
            self._cv.notify_all()

    @property
    def progress_ts(self) -> float:
        """Watchdog signal: wall-clock of the last observed forward step."""
        return self.last_progress

    # --------------------------------------------------------- KV lifecycle
    def _acquire(self, ps: _Prefill) -> None:
        """Arrival-time allocation: pin the cached prefix (share mode) and
        reserve prompt + decode-growth blocks. Grows the pool rather than
        declining — admission control is the dispatcher's job."""
        req = ps.request
        n = len(ps.tokens)
        local = self.on_decode_ready is None
        need = n + (max(req.output_tokens, 0) if local else 0) + 1
        keys = block_keys(ps.tokens, self.kv_block_size) \
            if self.prefix_share else None
        with self._kv_lock:
            try:
                table = self.kv.allocate(req.rid, need, keys=keys)
            except MemoryError:
                self.kv.grow_for(self.kv.blocks_needed(need))
                table = self.kv.allocate(req.rid, need, keys=keys)
            ps.hit = min(table.length, max(n - 1, 0))
            if getattr(self.kv, "tiered", False):
                ps.ticket = self._begin_promotion(keys, n, table.length)
        ps.keys = tuple(keys) if keys else ()
        ps.allocated = True
        req.prefix_hit = ps.hit
        if ps.hit:
            self.prefix_hits += 1
            self.prefix_hit_tokens += ps.hit

    def _begin_promotion(self, keys, n: int, warm: int):
        """Under _kv_lock at arrival: start promoting the prompt's cold-tier
        chain extension when the predicted copy beats the recompute it saves
        (PrefillInstance._begin_promotion's gate against this instance's
        own TTFT predictor). Returns a PromotionTicket or None."""
        _, host_t, disk_t = self.kv.probe_tiers(keys)
        cap = max(n - 1, 0) - warm
        cold = min(host_t + disk_t, cap)
        if cold <= 0:
            return None
        pred = self.predictor
        if pred is not None:
            saved = max(float(pred.predict(n - warm))
                        - float(pred.predict(n - warm - cold)), 0.0)
            host_use = min(host_t, cold)
            if self.kv.promote_seconds(host_use, cold - host_use) >= saved:
                return None
        bs = self.kv_block_size
        ticket = self.kv.promote_async(keys,
                                       max_blocks=(cold + bs - 1) // bs)
        return ticket if ticket.blocks else None

    def _settle_promotion(self, ps: _Prefill) -> None:
        """First-slice settle: wait for the arrival-time promotion copies
        OUTSIDE the kv lock (the prefill BLOCKS on a copy still in flight —
        never crashes into one), then commit under it and re-pin the longer
        prefix. Failures degrade to the arrival hit: timeouts abort back to
        their tier, corrupt copies are dropped and recomputed."""
        ticket, ps.ticket = ps.ticket, None
        ticket.wait(self.promote_wait_s)
        req = ps.request
        n = len(ps.tokens)
        local = self.on_decode_ready is None
        need = n + (max(req.output_tokens, 0) if local else 0) + 1
        with self._kv_lock:
            committed = self.kv.promote_settle(ticket)
            if committed <= 0:
                return
            old_hit = ps.hit
            self.kv.free(req.rid)
            try:
                table = self.kv.allocate(req.rid, need, keys=ps.keys)
            except MemoryError:
                self.kv.grow_for(self.kv.blocks_needed(need))
                table = self.kv.allocate(req.rid, need, keys=ps.keys)
            ps.hit = min(table.length, max(n - 1, 0))
        req.prefix_hit = ps.hit
        gained = max(ps.hit - old_hit, 0)
        self.prefix_promotions += committed
        self.prefix_promoted_tokens += gained
        if old_hit == 0 and ps.hit > 0:
            self.prefix_hits += 1
        self.prefix_hit_tokens += gained

    def _start_task(self, ps: _Prefill) -> None:
        """First admitted slice: build the device-resident prefill task,
        seeded from the pinned pool prefix on a hit (suffix-only compute)."""
        if ps.ticket is not None:
            self._settle_promotion(ps)
        req = ps.request
        arr = jnp.asarray(ps.tokens[None, :])
        lens = jnp.asarray([len(ps.tokens)])
        P = ps.hit
        if P > 0:
            with self._kv_lock:
                k, v, _ = self.kv.gather(req.rid)
            ps.task = self.executor.start(
                arr, lens=lens, prefix_len=P,
                prefix_k=k[:, None, :P], prefix_v=v[:, None, :P])
        else:
            ps.task = self.executor.start(arr, lens=lens)
        ps.done_tokens = P
        ps.started = self.clock()
        req.state = RequestState.RUNNING
        req.ops_total = ps.task.total_segments
        req.ops_done = 0

    def _publish(self, ps: _Prefill, now: float) -> int:
        """Prefill completion: scatter the computed suffix KV into the
        request's own pool blocks (the phase transition — the decode batch
        gathers from these same blocks next step) and register the prompt in
        the prefix trie. Returns the first decoded token."""
        req = ps.request
        st = ps.task.state
        n = int(st["lens"][0])
        with self._kv_lock:
            table = self.kv.table(req.rid)
            start = table.prefix_blocks * self.kv_block_size
            if start < n:
                self.kv.write_prompt(req.rid, st["k_cache"][:, 0, start:n],
                                     st["v_cache"][:, 0, start:n],
                                     start=start)
            if ps.keys:
                self.kv.insert(req.rid, ps.keys)
        req.first_token_time = now
        req.state = RequestState.DONE
        req.ops_done = req.ops_total
        observe = getattr(self.predictor, "observe", None)
        if observe is not None and ps.started > 0:
            # refit pair: suffix actually computed -> elapsed compute time
            observe(n - ps.hit, now - ps.started)
        return int(jnp.argmax(ps.task.logits[0]))

    def _offload(self, req: Request, first_token: int, now: float) -> None:
        """Mixed-pool handoff: extract the dense cache a DecodeInstance
        ingests and release the pool blocks (prompt blocks stay trie-cached
        in share mode)."""
        target = req.output_tokens if req.output_tokens > 0 \
            else self.decode_tokens
        with self._kv_lock:
            k, v, length = self.kv.gather(req.rid)
            k = jax.block_until_ready(k)
            v = jax.block_until_ready(v)
            self.kv.free(req.rid)
        n = req.num_tokens
        need = n + target + 1
        keep = max(n, int(length))
        k, v = k[:, None, :keep], v[:, None, :keep]
        if keep < need:
            pad = [(0, 0)] * k.ndim
            pad[2] = (0, need - keep)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        job = DecodeJob(request=req,
                        cache={"k": k, "v": v,
                               "pos": jnp.asarray(n, jnp.int32)},
                        first_token=first_token)
        self.on_decode_ready(job)

    def _join_local(self, req: Request, first_token: int, now: float) -> None:
        """No-handoff decode join: the KV is already in the shared pool."""
        target = req.output_tokens if req.output_tokens > 0 \
            else self.decode_tokens
        req.decode_start = now
        if req.output_tokens <= 0:
            req.output_tokens = target
        job = HybridJob(request=req, first_token=first_token, enqueued=now,
                        target=target, base_len=req.num_tokens,
                        last_emit=now, emitted=[first_token])
        with self._cv:
            job.order = self._order
            self._order += 1
            self._jobs[req.rid] = job

    # --------------------------------------------------------------- decode
    def _bucket(self, n: int) -> int:
        for b in self._b_buckets:
            if b >= n:
                return b
        return self._b_buckets[-1]

    def _t_step(self, b: int, ctx: float) -> float:
        if self.step_pred is not None:
            return self.step_pred.step_time(b, ctx)
        return self._tbt_ema

    def _observe(self, b: int, ctx: float, dt: float) -> None:
        a = 0.1 if self._tbt_ema > 0 else 1.0
        self._tbt_ema += a * (dt - self._tbt_ema)
        if self.step_pred is not None:
            self.step_pred.observe(b, ctx, dt)

    def _entry(self, job: HybridJob) -> DecodeEntry:
        return DecodeEntry(key=job.request.rid,
                           remaining_tokens=float(job.target
                                                  - job.tokens_done),
                           deadline=job.request.decode_deadline,
                           order=job.order)

    def _cadence(self, jobs: List[HybridJob]) -> float:
        """Seconds between woven decode steps: ``cadence_margin x`` the
        tightest resident TBT SLO (the margin absorbs the segment we are
        mid-way through when the cadence fires)."""
        if self.decode_cadence > 0:
            return self.decode_cadence
        slos = [j.request.tbt_slo for j in jobs
                if j.request.tbt_slo and j.request.tbt_slo > 0]
        if not slos:
            return 0.05
        return self.cadence_margin * min(slos)

    def _decode_step(self, jobs: List[HybridJob]) -> List[HybridJob]:
        """One jitted decode step over the resident batch against the
        SHARED pool (DecodeInstance's `_step_batch` shape). Returns the
        still-unfinished jobs."""
        jobs = [j for j in jobs if j.tokens_done < j.target]
        if not jobs:
            return jobs
        n = len(jobs)
        bb = self._bucket(n)
        seq_ids = [j.request.rid for j in jobs] + [_SCRATCH_SEQ] * (bb - n)
        kv_lens = np.zeros(bb, np.int32)
        tokens = np.zeros(bb, np.int32)
        for i, j in enumerate(jobs):
            kv_lens[i] = j.base_len + j.tokens_done
            tokens[i] = j.first_token if j.next_token is None else j.next_token
        t0 = self.clock()
        with self._kv_lock:
            # pow2 width over ALLOCATED blocks (not kv_len): per-stream
            # allocation sizes must not leak into the jitted shape
            need_blocks = max(
                (len(self.kv.table(j.request.rid).blocks) for j in jobs),
                default=1)
            width = 1
            while width < need_blocks:
                width *= 2
            k_g, v_g, _ = self.kv.gather_batch(seq_ids, width)
            logits, k_new, v_new = self._step_ragged(
                self.params, jnp.asarray(tokens), k_g, v_g,
                jnp.asarray(kv_lens))
            next_tokens = np.asarray(jnp.argmax(logits, -1))
            self.kv.write_tokens(seq_ids, kv_lens.tolist(), k_new, v_new)
        now = self.clock()
        self.steps += 1
        self._last_decode = now
        self.last_progress = now
        self._observe(n, float(kv_lens[:n].mean()), now - t0)
        alive: List[HybridJob] = []
        done: List[HybridJob] = []
        for i, j in enumerate(jobs):
            # TRUE inter-token gap (includes any weave pause) — the honest
            # TBT the fig24 attainment row gates on
            self.tbt_samples.append(now - j.last_emit)
            j.last_emit = now
            j.tokens_done += 1
            j.next_token = int(next_tokens[i])
            j.emitted.append(int(next_tokens[i]))
            (done if j.tokens_done >= j.target else alive).append(j)
        if done:
            with self._cv:
                for j in done:
                    rid = j.request.rid
                    if rid not in self._jobs:
                        # stranded mid-round (watchdog): the request was
                        # re-dispatched — completing it twice is the one
                        # thing the recovery invariant forbids
                        continue
                    j.request.finish_time = now
                    j.request.mean_tpot = (now - j.enqueued) \
                        / max(j.target, 1)
                    self.finished.append(j.request)
                    self.finished_jobs.append(j)
                    self._jobs.pop(rid, None)
                    self._resident.discard(rid)
                    with self._kv_lock:
                        # refcount decrement: trie-registered prompt blocks
                        # stay cached for the next matching prompt
                        self.kv.free(rid)
                self._cv.notify_all()
        return alive

    def _maybe_weave(self, jobs: List[HybridJob]) -> List[HybridJob]:
        """Between-segment cadence check: the operator boundary IS the
        preemption point — if the resident batch is due a token, run one
        decode step before the next segment."""
        if jobs and self.clock() - self._last_decode >= self._cadence(jobs):
            return self._decode_step(jobs)
        return jobs

    # ---------------------------------------------------------------- worker
    def _has_work_locked(self) -> bool:
        return bool(self._prefills) or bool(self._jobs)

    def _run(self) -> None:
        while True:
            with self._cv:
                while not (self._has_work_locked() and self.healthy) \
                        and not self._shutdown and self._inject is None:
                    self._cv.wait(0.1)
                if self._shutdown and not self._has_work_locked():
                    return
                if not self.healthy and self._inject is None:
                    continue                # zombie guard until restart()
                now = self.clock()
                prefills = [ps.request for ps in self._prefills.values()]
                done_map = {rid: ps.done_tokens
                            for rid, ps in self._prefills.items()}
                entries = [self._entry(j) for j in self._jobs.values()]
                resident = set(self._resident)
            self._check_inject()
            self.last_progress = self.clock()
            b = min(len(entries), self.decode_max_batch)
            ctx = (sum(j.base_len + j.tokens_done
                       for j in self._jobs.values()) / len(self._jobs)
                   if self._jobs else 0.0)
            plan = self.core.plan_step(
                now, prefill=prefills, prefill_done=done_map,
                decode_entries=entries, decode_resident=resident,
                t_step=self._t_step(max(b, 1), ctx))
            if plan.empty:
                continue
            self.rounds += 1
            with self._cv:
                for rid in plan.preempted_decode:
                    job = self._jobs.get(rid)
                    if job is not None:
                        job.request.decode_preemptions += 1
                        self.preemptions += 1
                self._resident = set(plan.decode_keys)
                jobs = [self._jobs[rid] for rid in plan.decode_keys
                        if rid in self._jobs]
            self._round(plan, jobs)

    def _round(self, plan, jobs: List[HybridJob]) -> None:
        """Execute one planned hybrid step: each prefill slice advances one
        chunk of operator segments with decode steps woven between them; a
        decode-only plan is a single batched step (a dedicated decode
        instance's cadence, exactly)."""
        decoded0 = self.steps
        for sl in plan.prefill_slices:
            with self._cv:
                ps = self._prefills.get(sl.key)
            if ps is None:
                continue
            if ps.task is None:
                self._start_task(ps)
            task = ps.task
            spc = self.executor._segments_per_chunk
            target = task.cursor + spc
            if target >= task.total_segments - 1:
                target = task.total_segments            # run the head too
            while task.cursor < target and not task.done:
                self.executor.step(task)
                self.last_progress = self.clock()
                if not task.done:
                    jobs = self._maybe_weave(jobs)
            chunks_done = task.cursor // spc
            ps.done_tokens = min(
                task.start_offset + chunks_done * task.chunk,
                ps.request.num_tokens)
            ps.request.ops_done = task.cursor
            if task.done:
                req = ps.request
                with self._cv:
                    if self._prefills.get(req.rid) is not ps:
                        # stranded mid-chunk: the request was re-dispatched
                        # — publishing this incarnation's result would race
                        # (or double) the recovery's
                        continue
                now = self.clock()
                first = self._publish(ps, now)
                with self._cv:
                    self._prefills.pop(req.rid, None)
                self.prefilled.append(req)
                if self.on_decode_ready is not None:
                    self._offload(req, first, now)
                elif req.output_tokens > 0:
                    self._join_local(req, first, now)
                    with self._cv:
                        job = self._jobs.get(req.rid)
                    # a fresh stream is owed its first token promptly: it
                    # joins the in-flight batch mid-round
                    if job is not None:
                        jobs = jobs + [job]
                        with self._cv:
                            self._resident.add(req.rid)
                else:
                    with self._kv_lock:
                        self.kv.free(req.rid)       # prefill-only request
                with self._cv:
                    self._cv.notify_all()
        if jobs and self.steps == decoded0:
            # the plan admitted these streams for this step — a round must
            # never complete without their token (the one-budget-token
            # promise the fairness property tests assert)
            self._decode_step(jobs)
