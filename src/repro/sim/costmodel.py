"""Analytic prefill cost model for the discrete-event simulator.

Per-operator durations from first principles: t_op = max(compute, memory)
+ launch overhead, with
  compute = FLOPs / (peak_flops * eff_c)
  memory  = bytes_touched / (hbm_bw * eff_b)   (weights re-read per chunk,
                                                KV prefix re-read by attention)
This reproduces the paper's motivating observations without fitting:
  * Fig. 3 — small chunks collapse throughput (per-chunk weight re-reads +
    launch overheads), large chunks recover it;
  * Fig. 4 — short prefills are memory-bound (batching ~free), long prefills
    compute-bound (batching inflates latency linearly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float                  # bf16 FLOP/s
    hbm_bw: float                      # bytes/s
    eff_c: float = 0.7                 # achievable compute fraction (saturated)
    eff_b: float = 0.8                 # achievable bandwidth fraction
    launch_overhead: float = 20e-6     # per fused-operator dispatch
    sat_tokens: int = 600              # tokens to reach ~50% of eff_c
                                       # (kernel tails / wave quantization:
                                       # small batches underutilize — Fig. 4a)
    kv_link_bw: float = 50e9           # inter-instance KV transfer bytes/s
                                       # (PCIe4 x16-class; prices decode
                                       # migration and PD handoff)
    kv_link_latency: float = 2e-3      # per-transfer setup latency (seconds)
    # tiered KV offload: device <-> host-memory staging link (H2D for
    # promotions; PCIe-class, typically ~half the raw link for pageable
    # copies) and host <-> local-disk spill (NVMe-class). Price promotion
    # of demoted prefix blocks back into HBM (PrefillCostModel.promote_time)
    host_bw: float = 25e9              # bytes/s host->device
    host_latency: float = 5e-4         # per-promotion setup (seconds)
    disk_bw: float = 3e9               # bytes/s disk->host->device
    disk_latency: float = 5e-3         # per-promotion disk setup (seconds)

    def eff_c_at(self, tokens: float) -> float:
        return self.eff_c * tokens / (tokens + self.sat_tokens)


A100 = HardwareSpec("A100-SXM4", peak_flops=312e12, hbm_bw=1.555e12)
A800 = HardwareSpec("A800-SXM4-80G", peak_flops=312e12, hbm_bw=2.0e12)
TPU_V5E = HardwareSpec("TPUv5e", peak_flops=197e12, hbm_bw=819e9)

# name -> spec lookup for CLI flags / hetero pool configs
HARDWARE_SPECS = {hw.name: hw for hw in (A100, A800, TPU_V5E)}
HARDWARE_ALIASES = {"a100": A100, "a800": A800, "tpu-v5e": TPU_V5E,
                    "tpu_v5e": TPU_V5E}


def resolve_hardware(hw) -> HardwareSpec:
    """Accept a HardwareSpec or a name/alias string ("a800", "A100-SXM4")."""
    if isinstance(hw, HardwareSpec):
        return hw
    key = str(hw)
    if key in HARDWARE_SPECS:
        return HARDWARE_SPECS[key]
    try:
        return HARDWARE_ALIASES[key.lower()]
    except KeyError:
        raise ValueError(
            f"unknown hardware {hw!r}; known: "
            f"{sorted(HARDWARE_SPECS) + sorted(HARDWARE_ALIASES)}") from None


@dataclass(frozen=True)
class ModelSpec:
    """The numbers the cost model needs, derived from a ModelConfig."""
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    num_experts: int = 0
    experts_per_token: int = 0
    tp: int = 1                         # tensor parallel degree

    @classmethod
    def from_config(cls, cfg: ModelConfig, tp: int = 1) -> "ModelSpec":
        return cls(name=cfg.name, num_layers=cfg.num_layers,
                   d_model=cfg.d_model, num_heads=cfg.num_heads,
                   num_kv_heads=cfg.num_kv_heads,
                   head_dim=cfg.resolved_head_dim, d_ff=cfg.d_ff,
                   num_experts=cfg.num_experts,
                   experts_per_token=cfg.experts_per_token, tp=tp)

    @property
    def op_names(self) -> Tuple[str, ...]:
        if self.num_experts:
            return ("qkv_proj", "attn", "o_proj", "gate", "experts")
        return ("qkv_proj", "attn", "o_proj", "gate_up_proj", "down_proj")


# published evaluation models (paper §6.1)
LLAMA3_8B = ModelSpec("llama3-8b", 32, 4096, 32, 8, 128, 14336)
QWEN25_14B = ModelSpec("qwen2.5-14b", 48, 5120, 40, 8, 128, 13824)
LLAMA3_70B = ModelSpec("llama3-70b", 80, 8192, 64, 8, 128, 28672)
QWEN3_30B_A3B = ModelSpec("qwen3-30b-a3b", 48, 2048, 32, 4, 128, 768,
                          num_experts=128, experts_per_token=8)

MODEL_SPECS = {m.name: m for m in
               (LLAMA3_8B, QWEN25_14B, LLAMA3_70B, QWEN3_30B_A3B)}
MODEL_TP = {"llama3-8b": 1, "qwen2.5-14b": 2, "llama3-70b": 4,
            "qwen3-30b-a3b": 2}


def kv_bytes_per_token(m: ModelSpec) -> float:
    """bf16 K and V bytes one token's cache occupies — shared by decode
    migration pricing and tiered-KV promotion pricing."""
    return 2.0 * 2 * m.num_layers * m.num_kv_heads * m.head_dim


class PrefillCostModel:
    def __init__(self, model: ModelSpec, hw: HardwareSpec = A800):
        self.m = model
        self.hw = hw

    # --- per-operator FLOPs/bytes for a chunk of c tokens at prefix offset o ---
    def _op_cost(self, name: str, c: int, o: int) -> Tuple[float, float]:
        m = self.m
        d, H, K, hd, f = (m.d_model, m.num_heads, m.num_kv_heads,
                          m.head_dim, m.d_ff)
        if name == "qkv_proj":
            fl = 2 * c * d * (H + 2 * K) * hd
            by = 2 * d * (H + 2 * K) * hd
        elif name == "attn":
            fl = 4 * c * (o + c / 2) * H * hd
            by = 2 * 2 * (o + c) * K * hd + 2 * 2 * c * K * hd
        elif name == "o_proj":
            fl = 2 * c * H * hd * d
            by = 2 * H * hd * d
        elif name == "gate_up_proj":
            fl = 4 * c * d * f
            by = 2 * d * 2 * f
        elif name == "down_proj":
            fl = 2 * c * f * d
            by = 2 * f * d
        elif name == "gate":
            fl = 2 * c * d * m.num_experts
            by = 2 * d * m.num_experts
        elif name == "experts":
            k = m.experts_per_token
            fl = 6 * c * k * d * f
            touched = min(c * k, m.num_experts)
            by = 2 * 3 * d * f * touched
        else:
            raise ValueError(name)
        return fl, by

    # --- vectorized counterpart: c, o are float64 arrays over all chunks.
    # Formulas and evaluation order mirror `_op_cost` exactly — every
    # intermediate is an integer-valued float64 < 2^53, so the batched path is
    # bit-identical to the scalar one (pinned by tests/test_costmodel_vec.py).
    def _op_cost_vec(self, name: str, c: np.ndarray,
                     o: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        m = self.m
        d, H, K, hd, f = (m.d_model, m.num_heads, m.num_kv_heads,
                          m.head_dim, m.d_ff)
        one = np.ones_like(c)             # broadcast helper for constant bytes
        if name == "qkv_proj":
            fl = 2 * c * d * (H + 2 * K) * hd
            by = (2 * d * (H + 2 * K) * hd) * one
        elif name == "attn":
            fl = 4 * c * (o + c / 2) * H * hd
            by = 2 * 2 * (o + c) * K * hd + 2 * 2 * c * K * hd
        elif name == "o_proj":
            fl = 2 * c * H * hd * d
            by = (2 * H * hd * d) * one
        elif name == "gate_up_proj":
            fl = 4 * c * d * f
            by = (2 * d * 2 * f) * one
        elif name == "down_proj":
            fl = 2 * c * f * d
            by = (2 * f * d) * one
        elif name == "gate":
            fl = 2 * c * d * m.num_experts
            by = (2 * d * m.num_experts) * one
        elif name == "experts":
            k = m.experts_per_token
            fl = 6 * c * k * d * f
            touched = np.minimum(c * k, float(m.num_experts))
            by = 2 * 3 * d * f * touched
        else:
            raise ValueError(name)
        return fl, by

    def op_duration(self, name: str, c: int, o: int) -> float:
        fl, by = self._op_cost(name, c, o)
        tp = self.m.tp
        t = max(fl / tp / (self.hw.peak_flops * self.hw.eff_c_at(c)),
                by / tp / (self.hw.hbm_bw * self.hw.eff_b))
        return t + self.hw.launch_overhead

    def _op_duration_vec(self, name: str, c: np.ndarray,
                         o: np.ndarray) -> np.ndarray:
        fl, by = self._op_cost_vec(name, c, o)
        tp = self.m.tp
        eff_c = self.hw.eff_c * c / (c + self.hw.sat_tokens)
        t = np.maximum(fl / tp / (self.hw.peak_flops * eff_c),
                       by / tp / (self.hw.hbm_bw * self.hw.eff_b))
        return t + self.hw.launch_overhead

    def _chunk_grid(self, tokens: int, chunk_tokens: int,
                    prefix: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """(sizes, offsets) of every chunk of a `tokens`-long prefill whose
        first `prefix` tokens are served from a prefix cache: chunks cover
        only [prefix, tokens), each at its true KV offset — the attention
        term still reads the cached prefix (o grows from `prefix`), but its
        compute/weight traffic is skipped entirely."""
        chunk = chunk_tokens or (tokens - prefix)
        o = np.arange(prefix, tokens, chunk, dtype=np.float64)
        c = np.minimum(float(chunk), tokens - o)
        return c, o

    def op_durations(self, tokens: int, chunk_tokens: int = 0,
                     prefix: int = 0) -> np.ndarray:
        """Per-operator durations for a prefill (all layers x all chunks),
        in execution order. Shape: (n_chunks * L * n_ops,).

        Batched over all (chunk, layer, op) triples — the simulator hot path
        (every SUBMIT builds one of these arrays); bit-identical to the scalar
        reference `op_durations_scalar`. ``prefix`` > 0 prices a
        prefix-cache hit: the first `prefix` tokens' chunks vanish and the
        suffix chunks run at their cached-KV offsets (`_chunk_grid`).
        ``prefix=0`` (default) is the exact original path."""
        m = self.m
        prefix = min(max(int(prefix), 0), max(tokens - 1, 0))
        c, o = self._chunk_grid(tokens, chunk_tokens, prefix)
        if c.size <= 1:
            # numpy overhead loses on a single chunk (the unchunked presets):
            # the scalar reference is bit-identical and faster there
            return self.op_durations_scalar(tokens, chunk_tokens, prefix)
        # (n_chunks, n_ops): one column per operator, rows in chunk order
        per_chunk = np.stack(
            [self._op_duration_vec(nm, c, o) for nm in m.op_names], axis=1)
        # execution order = chunk-major, the op row repeated once per layer
        return np.tile(per_chunk[:, None, :],
                       (1, m.num_layers, 1)).reshape(-1)

    def op_durations_scalar(self, tokens: int, chunk_tokens: int = 0,
                            prefix: int = 0) -> np.ndarray:
        """Reference implementation (per-chunk Python loop) kept as the ground
        truth the vectorized `op_durations` is pinned against."""
        m = self.m
        prefix = min(max(int(prefix), 0), max(tokens - 1, 0))
        chunk = chunk_tokens or (tokens - prefix)
        out: List[float] = []
        o = prefix
        while o < tokens:
            c = min(chunk, tokens - o)
            per_layer = [self.op_duration(nm, c, o) for nm in m.op_names]
            out.extend(per_layer * m.num_layers)
            o += c
        return np.asarray(out)

    def prefill_time(self, tokens: int, chunk_tokens: int = 0,
                     prefix: int = 0) -> float:
        return float(self.op_durations(tokens, chunk_tokens, prefix).sum())

    def throughput(self, tokens: int, chunk_tokens: int = 0) -> float:
        return tokens / self.prefill_time(tokens, chunk_tokens)

    def promote_time(self, host_tokens: float,
                     disk_tokens: float = 0.0) -> float:
        """Seconds to promote that many cold prefix tokens back into HBM
        from the host (and disk) tier — the copy side of the tiered-KV
        promote-vs-recompute gate. The recompute side is the prefill time
        the hit saves (`op_durations` with/without the cold prefix), so
        the sim's gating decision matches the runtime's
        `PagedKVCache.promote_seconds` in structure: per-tier setup latency
        plus bytes over the staging link, divided by tensor parallelism
        (each shard moves its own KV slice)."""
        t = 0.0
        bpt = kv_bytes_per_token(self.m) / self.m.tp
        if host_tokens > 0:
            t += self.hw.host_latency + host_tokens * bpt / self.hw.host_bw
        if disk_tokens > 0:
            t += self.hw.disk_latency + disk_tokens * bpt / self.hw.disk_bw
        return t


class DecodeCostModel:
    """Analytic decode-step latency for the cluster simulator's decode phase.

    Decode is memory-bound: every step streams the full weight set once
    (continuous batching amortizes it over the batch) plus each request's KV
    prefix. Step latency for a batch of B requests with mean context C:

        t_step = (W_bytes + B * C * kv_bytes_per_token) / (tp * bw * eff_b)
                 + L * n_ops * launch_overhead

    which yields the familiar shape: near-flat latency at small B (weights
    dominate), linear growth once aggregate KV reads take over — i.e. TBT
    degrades as a decode instance's batch grows, which is exactly the signal
    the cluster-level TPOT/TBT SLO accounting needs.
    """

    def __init__(self, model: ModelSpec, hw: HardwareSpec = A800):
        self.m = model
        self.hw = hw

    @property
    def weight_bytes(self) -> float:
        m = self.m
        attn = m.d_model * (m.num_heads + 2 * m.num_kv_heads) * m.head_dim \
            + m.num_heads * m.head_dim * m.d_model
        if m.num_experts:
            ffn = m.d_model * m.num_experts \
                + 3 * m.d_model * m.d_ff * m.experts_per_token
        else:
            ffn = 3 * m.d_model * m.d_ff
        return 2.0 * m.num_layers * (attn + ffn)       # bf16

    @property
    def kv_bytes_per_token(self) -> float:
        return kv_bytes_per_token(self.m)              # bf16 K and V

    def step_time(self, batch_size: int, mean_context: float) -> float:
        if batch_size <= 0:
            return 0.0
        by = self.weight_bytes + batch_size * mean_context \
            * self.kv_bytes_per_token
        t = by / self.m.tp / (self.hw.hbm_bw * self.hw.eff_b)
        return t + self.m.num_layers * len(self.m.op_names) \
            * self.hw.launch_overhead

    def kv_transfer_time(self, context_tokens: float) -> float:
        """Seconds to hand a stream's KV cache to another instance over the
        inter-instance link — the price of a decode migration (and of the PD
        prefill->decode handoff, which the fluid sim folds into step times).
        KV bytes scale with the context; the fixed setup latency keeps tiny
        transfers from looking free."""
        by = max(context_tokens, 0.0) * self.kv_bytes_per_token
        return self.hw.kv_link_latency + by / self.hw.kv_link_bw
