"""Cluster-scale discrete-event simulator: N prefill instances + dispatch +
a decode-phase cost model, on ONE shared event heap.

Each prefill instance is an `InstanceEngine` (the exact state machine behind
`PrefillSim` — a 1-instance round-robin cluster reproduces the single-instance
simulator event-for-event). Arrivals are routed by a pluggable dispatch policy
from `repro.core.dispatch` — the same policy objects the real `Proxy` uses —
and completed prefills hand over to decode instances modeled as
continuous-batching processor sharing with TPOT/TBT SLO accounting
(`DecodeCostModel`), so the cluster reports *end-to-end* goodput.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dispatch import DispatchPolicy, InstanceLoad, make_dispatch
from repro.core.predictor import OnlineTTFTPredictor, TTFTPredictor
from repro.core.request import Request
from repro.sim.costmodel import (DecodeCostModel, HardwareSpec,
                                 PrefillCostModel, resolve_hardware)
from repro.sim.simulator import (ARRIVAL, DECODE_DONE, InstanceEngine,
                                 SimConfig, handle_event, reset_requests)

# token count at which per-instance peak prefill throughput (the
# capacity-weighted dispatch normalizer) is probed: long enough to saturate
# compute on every supported hardware generation
CAPACITY_PROBE_TOKENS = 8192


@dataclass
class _DecodeJob:
    request: Request
    joined: float
    done: float = 0.0                     # tokens decoded (fractional)


class DecodeSim:
    """One decode instance: a continuous batch in which all resident requests
    advance together at 1/t_step(B, mean_context) tokens/sec (processor
    sharing). Batch changes re-rate everyone; stale completion events are
    invalidated by an epoch counter, so events are O(joins + leaves)."""

    def __init__(self, cost: DecodeCostModel, heap: List, seq,
                 instance_id: int = 0):
        self.cost = cost
        self.heap = heap
        self.seq = seq
        self.instance_id = instance_id
        self.jobs: Dict[int, _DecodeJob] = {}
        self.epoch = 0
        self.last_update = 0.0
        self.finished: List[Request] = []

    def _step_time(self) -> float:
        if not self.jobs:
            return 0.0
        ctx = sum(j.request.num_tokens + j.done for j in self.jobs.values())
        return self.cost.step_time(len(self.jobs), ctx / len(self.jobs))

    def _advance(self, now: float) -> None:
        dt = now - self.last_update
        self.last_update = now
        if dt <= 0 or not self.jobs:
            return
        t_step = self._step_time()
        gained = dt / t_step if t_step > 0 else float("inf")
        for j in self.jobs.values():
            j.done = min(j.done + gained, float(j.request.output_tokens))

    def _reschedule(self, now: float) -> None:
        self.epoch += 1
        if not self.jobs:
            return
        t_step = self._step_time()
        t_next = min((j.request.output_tokens - j.done) * t_step
                     for j in self.jobs.values())
        heapq.heappush(self.heap, (now + max(t_next, 0.0), next(self.seq),
                                   DECODE_DONE, (self, self.epoch)))

    def pressure(self, req: Request, now: float) -> float:
        """Predicted TBT pressure were `req`'s decode to join this instance
        now: the analytic step time at batch B+1 over the candidate's TBT SLO
        (1.0 = exactly at the SLO knee). Read-only — uses the jobs' last
        materialized progress, which only perturbs the mean context."""
        if req.tbt_slo <= 0 or not math.isfinite(req.tbt_slo):
            return 0.0
        b = len(self.jobs) + 1
        ctx = sum(j.request.num_tokens + j.done for j in self.jobs.values()) \
            + req.num_tokens
        return self.cost.step_time(b, ctx / b) / req.tbt_slo

    def join(self, req: Request, now: float) -> None:
        self._advance(now)
        self.jobs[req.rid] = _DecodeJob(request=req, joined=now)
        self._reschedule(now)

    def on_decode_done(self, payload, now: float) -> List[Request]:
        _, epoch = payload
        if epoch != self.epoch:
            return []                                  # stale
        self._advance(now)
        done = [j for j in self.jobs.values()
                if j.done >= j.request.output_tokens - 1e-6]
        for j in done:
            r = j.request
            r.finish_time = now
            r.mean_tpot = (now - j.joined) / max(r.output_tokens, 1)
            del self.jobs[r.rid]
            self.finished.append(r)
        self._reschedule(now)
        return [j.request for j in done]


@dataclass
class ClusterResult:
    requests: List[Request]
    blocking_times: List[float]
    rounds: int
    preemptions: int
    makespan: float
    dispatched: List[int]                 # requests routed per prefill instance
    decoded: int = 0

    @property
    def attainment(self) -> float:
        """TTFT-SLO attainment (comparable with single-instance SimResult)."""
        met = sum(1 for r in self.requests if r.slo_met)
        return met / max(len(self.requests), 1)

    @property
    def e2e_attainment(self) -> float:
        """End-to-end goodness: TTFT and decode-TBT SLOs both attained."""
        met = sum(1 for r in self.requests if r.e2e_met)
        return met / max(len(self.requests), 1)

    @property
    def imbalance(self) -> float:
        """max/mean dispatched requests across instances (1.0 = perfect)."""
        mean = sum(self.dispatched) / max(len(self.dispatched), 1)
        return max(self.dispatched) / max(mean, 1e-9)


class ClusterSim:
    """N-instance prefill cluster + dispatch + decode phase, one event heap.

    Heterogeneous pools: pass ``hardware`` (one HardwareSpec per prefill
    instance — ``num_instances`` is then taken from its length). Each instance
    gets its own cost model, its own TTFT predictor fitted to its hardware
    (shared across same-spec instances), and a capacity (peak prefill
    throughput) surfaced to dispatch via ``InstanceLoad.capacity``. The
    dispatch-level predictor stays the reference one — load-blind JSQ on a
    mixed pool prices every instance's backlog at the same speed, which is
    exactly the failure mode capacity-weighted dispatch fixes.

    ``online_refit=True`` replaces each instance's predictor with an
    `OnlineTTFTPredictor` seeded from the reference fit: engines feed observed
    batch latencies back, so per-instance feasibility pricing converges to the
    instance's true speed even when the prior was fitted elsewhere.

    Decode stage: with ``decode-aware`` dispatch (or ``decode_affinity=True``)
    completed prefills hand over to the PAIRED decode instance (prefill i ->
    decode i mod D, the disaggregated-pool wiring that makes downstream
    pressure attributable); otherwise they join the least-loaded decode batch
    as before. ``decode_hardware`` heterogenizes the decode pool the same way.
    """

    def __init__(self, cost: PrefillCostModel, sim_cfg: SimConfig, *,
                 num_instances: int = 2,
                 dispatch: str = "round-robin",
                 predictor: Optional[TTFTPredictor] = None,
                 decode_instances: int = 0,
                 decode_cost: Optional[DecodeCostModel] = None,
                 hardware: Optional[Sequence[HardwareSpec]] = None,
                 decode_hardware: Optional[Sequence[HardwareSpec]] = None,
                 online_refit: bool = False,
                 decode_affinity: Optional[bool] = None):
        if hardware is not None:
            hardware = [resolve_hardware(hw) for hw in hardware]
            num_instances = len(hardware)
        if num_instances < 1:
            raise ValueError("num_instances must be >= 1")
        self.cost = cost
        self.cfg = sim_cfg
        chunk = sim_cfg.chunk_tokens
        self.predictor = predictor or TTFTPredictor.from_cost_model(
            lambda n: cost.prefill_time(n, chunk), max_tokens=32768)
        self.num_instances = num_instances
        self.policy: DispatchPolicy = make_dispatch(dispatch, self.predictor)
        self.online_refit = online_refit

        # per-instance cost models + predictors (predictors cached per
        # hardware spec so a 4x-same-card pool fits once)
        if hardware is not None:
            self.instance_costs = [PrefillCostModel(cost.m, hw)
                                   for hw in hardware]
        else:
            self.instance_costs = [cost] * num_instances
        fits: Dict[str, TTFTPredictor] = {cost.hw.name: self.predictor}
        self.instance_predictors: List[TTFTPredictor] = []
        for c in self.instance_costs:
            if c.hw.name not in fits:
                fits[c.hw.name] = TTFTPredictor.from_cost_model(
                    lambda n, c=c: c.prefill_time(n, chunk), max_tokens=32768)
            self.instance_predictors.append(fits[c.hw.name])
        self.capacities = [c.throughput(CAPACITY_PROBE_TOKENS, chunk)
                           for c in self.instance_costs]

        self.num_decode = decode_instances
        if decode_hardware is not None:
            decode_hardware = [resolve_hardware(hw) for hw in decode_hardware]
            if decode_instances and len(decode_hardware) != decode_instances:
                raise ValueError("decode_hardware length must match "
                                 "decode_instances")
            self.num_decode = len(decode_hardware)
            self.decode_costs = [DecodeCostModel(cost.m, hw)
                                 for hw in decode_hardware]
        else:
            self.decode_costs = [decode_cost
                                 or DecodeCostModel(cost.m, cost.hw)] \
                * self.num_decode
        if decode_affinity is None:
            decode_affinity = self.policy.needs_decode_pressure
        self.decode_affinity = decode_affinity and self.num_decode > 0

    def run(self, requests: Sequence[Request]) -> ClusterResult:
        heap: List[Tuple[float, int, int, object]] = []
        seq = itertools.count()
        predictors = self.instance_predictors
        if self.online_refit:
            predictors = [OnlineTTFTPredictor.from_predictor(p)
                          for p in predictors]
        self.run_predictors = predictors      # exposed for refit inspection
        engines = [InstanceEngine(self.instance_costs[i], self.cfg,
                                  predictors[i], heap, seq, instance_id=i,
                                  capacity=self.capacities[i])
                   for i in range(self.num_instances)]
        decodes = [DecodeSim(self.decode_costs[i], heap, seq, instance_id=i)
                   for i in range(self.num_decode)]
        reset_requests(requests)
        for r in requests:
            heapq.heappush(heap, (r.arrival, next(seq), ARRIVAL, r))
        # load-oblivious policies (round-robin) skip snapshot building
        idle_loads = [InstanceLoad(instance_id=e.instance_id,
                                   capacity=e.capacity)
                      for e in engines]
        with_pressure = self.policy.needs_decode_pressure and decodes

        now = 0.0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                req: Request = payload
                if self.policy.needs_loads:
                    loads = [e.snapshot_load(req, now) for e in engines]
                else:
                    loads = idle_loads
                if with_pressure:
                    loads = [replace(
                        ld, decode_pressure=decodes[
                            i % len(decodes)].pressure(req, now))
                        for i, ld in enumerate(loads)]
                engines[self.policy.select(req, loads, now)].on_arrival(
                    req, now)
            elif kind == DECODE_DONE:
                payload[0].on_decode_done(payload, now)
            else:
                engine: InstanceEngine = payload[0]
                for r in handle_event(kind, payload, now):
                    if decodes and r.output_tokens > 0:
                        if self.decode_affinity:
                            # paired handoff: prefill i -> decode i mod D
                            dec = decodes[engine.instance_id % len(decodes)]
                        else:
                            # join the decode instance with the smallest batch
                            dec = min(decodes, key=lambda d: (len(d.jobs),
                                                              d.instance_id))
                        dec.join(r, now)

        return ClusterResult(
            requests=list(requests),
            blocking_times=[b for e in engines for b in e.blocking],
            rounds=sum(e.rounds for e in engines),
            preemptions=sum(e.preemptions for e in engines),
            makespan=now,
            dispatched=[e.n_dispatched for e in engines],
            decoded=sum(len(d.finished) for d in decodes),
        )


def simulate_cluster(system: str, requests: Sequence[Request], *,
                     model: str = "llama3-8b",
                     num_instances: int = 2,
                     dispatch: str = "round-robin",
                     decode_instances: int = 0,
                     hw=None, hardware=None, decode_hardware=None,
                     online_refit: bool = False,
                     decode_affinity: Optional[bool] = None,
                     **overrides) -> ClusterResult:
    """Cluster counterpart of `repro.sim.policies.simulate` — same baseline
    presets, same fresh-copy semantics, plus instance count, dispatch, and
    heterogeneous pool layout (`hardware` / `decode_hardware` accept
    HardwareSpecs or names like "a800")."""
    import copy

    from repro.sim.costmodel import A800, MODEL_SPECS, MODEL_TP
    from repro.sim.policies import preset

    spec = replace(MODEL_SPECS[model], tp=MODEL_TP.get(model, 1))
    cost = PrefillCostModel(spec, resolve_hardware(hw) if hw else A800)
    sim = ClusterSim(cost, preset(system, **overrides),
                     num_instances=num_instances, dispatch=dispatch,
                     decode_instances=decode_instances,
                     hardware=hardware, decode_hardware=decode_hardware,
                     online_refit=online_refit,
                     decode_affinity=decode_affinity)
    return sim.run([copy.copy(r) for r in requests])
