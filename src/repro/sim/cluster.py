"""Cluster-scale discrete-event simulator: N prefill instances + dispatch +
a decode-phase cost model, on ONE shared event heap.

Each prefill instance is an `InstanceEngine` (the exact state machine behind
`PrefillSim` — a 1-instance round-robin cluster reproduces the single-instance
simulator event-for-event). Arrivals are routed by a pluggable dispatch policy
from `repro.core.dispatch` — the same policy objects the real `Proxy` uses —
and completed prefills hand over to decode instances modeled as
continuous-batching processor sharing with TPOT/TBT SLO accounting
(`DecodeCostModel`), so the cluster reports *end-to-end* goodput.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dispatch import DispatchPolicy, InstanceLoad, make_dispatch
from repro.core.predictor import TTFTPredictor
from repro.core.request import Request
from repro.sim.costmodel import DecodeCostModel, PrefillCostModel
from repro.sim.simulator import (ARRIVAL, DECODE_DONE, InstanceEngine,
                                 SimConfig, handle_event, reset_requests)


@dataclass
class _DecodeJob:
    request: Request
    joined: float
    done: float = 0.0                     # tokens decoded (fractional)


class DecodeSim:
    """One decode instance: a continuous batch in which all resident requests
    advance together at 1/t_step(B, mean_context) tokens/sec (processor
    sharing). Batch changes re-rate everyone; stale completion events are
    invalidated by an epoch counter, so events are O(joins + leaves)."""

    def __init__(self, cost: DecodeCostModel, heap: List, seq,
                 instance_id: int = 0):
        self.cost = cost
        self.heap = heap
        self.seq = seq
        self.instance_id = instance_id
        self.jobs: Dict[int, _DecodeJob] = {}
        self.epoch = 0
        self.last_update = 0.0
        self.finished: List[Request] = []

    def _step_time(self) -> float:
        if not self.jobs:
            return 0.0
        ctx = sum(j.request.num_tokens + j.done for j in self.jobs.values())
        return self.cost.step_time(len(self.jobs), ctx / len(self.jobs))

    def _advance(self, now: float) -> None:
        dt = now - self.last_update
        self.last_update = now
        if dt <= 0 or not self.jobs:
            return
        t_step = self._step_time()
        gained = dt / t_step if t_step > 0 else float("inf")
        for j in self.jobs.values():
            j.done = min(j.done + gained, float(j.request.output_tokens))

    def _reschedule(self, now: float) -> None:
        self.epoch += 1
        if not self.jobs:
            return
        t_step = self._step_time()
        t_next = min((j.request.output_tokens - j.done) * t_step
                     for j in self.jobs.values())
        heapq.heappush(self.heap, (now + max(t_next, 0.0), next(self.seq),
                                   DECODE_DONE, (self, self.epoch)))

    def join(self, req: Request, now: float) -> None:
        self._advance(now)
        self.jobs[req.rid] = _DecodeJob(request=req, joined=now)
        self._reschedule(now)

    def on_decode_done(self, payload, now: float) -> List[Request]:
        _, epoch = payload
        if epoch != self.epoch:
            return []                                  # stale
        self._advance(now)
        done = [j for j in self.jobs.values()
                if j.done >= j.request.output_tokens - 1e-6]
        for j in done:
            r = j.request
            r.finish_time = now
            r.mean_tpot = (now - j.joined) / max(r.output_tokens, 1)
            del self.jobs[r.rid]
            self.finished.append(r)
        self._reschedule(now)
        return [j.request for j in done]


@dataclass
class ClusterResult:
    requests: List[Request]
    blocking_times: List[float]
    rounds: int
    preemptions: int
    makespan: float
    dispatched: List[int]                 # requests routed per prefill instance
    decoded: int = 0

    @property
    def attainment(self) -> float:
        """TTFT-SLO attainment (comparable with single-instance SimResult)."""
        met = sum(1 for r in self.requests if r.slo_met)
        return met / max(len(self.requests), 1)

    @property
    def e2e_attainment(self) -> float:
        """End-to-end goodness: TTFT and decode-TBT SLOs both attained."""
        met = sum(1 for r in self.requests if r.e2e_met)
        return met / max(len(self.requests), 1)

    @property
    def imbalance(self) -> float:
        """max/mean dispatched requests across instances (1.0 = perfect)."""
        mean = sum(self.dispatched) / max(len(self.dispatched), 1)
        return max(self.dispatched) / max(mean, 1e-9)


class ClusterSim:
    """N-instance prefill cluster + dispatch + decode phase, one event heap."""

    def __init__(self, cost: PrefillCostModel, sim_cfg: SimConfig, *,
                 num_instances: int = 2,
                 dispatch: str = "round-robin",
                 predictor: Optional[TTFTPredictor] = None,
                 decode_instances: int = 0,
                 decode_cost: Optional[DecodeCostModel] = None):
        if num_instances < 1:
            raise ValueError("num_instances must be >= 1")
        self.cost = cost
        self.cfg = sim_cfg
        chunk = sim_cfg.chunk_tokens
        self.predictor = predictor or TTFTPredictor.from_cost_model(
            lambda n: cost.prefill_time(n, chunk), max_tokens=32768)
        self.num_instances = num_instances
        self.policy: DispatchPolicy = make_dispatch(dispatch, self.predictor)
        self.num_decode = decode_instances
        self.decode_cost = decode_cost or DecodeCostModel(cost.m, cost.hw)

    def run(self, requests: Sequence[Request]) -> ClusterResult:
        heap: List[Tuple[float, int, int, object]] = []
        seq = itertools.count()
        engines = [InstanceEngine(self.cost, self.cfg, self.predictor,
                                  heap, seq, instance_id=i)
                   for i in range(self.num_instances)]
        decodes = [DecodeSim(self.decode_cost, heap, seq, instance_id=i)
                   for i in range(self.num_decode)]
        reset_requests(requests)
        for r in requests:
            heapq.heappush(heap, (r.arrival, next(seq), ARRIVAL, r))
        # load-oblivious policies (round-robin) skip snapshot building
        idle_loads = [InstanceLoad(instance_id=e.instance_id)
                      for e in engines]

        now = 0.0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                req: Request = payload
                if self.policy.needs_loads:
                    loads = [e.snapshot_load(req, now) for e in engines]
                else:
                    loads = idle_loads
                engines[self.policy.select(req, loads, now)].on_arrival(
                    req, now)
            elif kind == DECODE_DONE:
                payload[0].on_decode_done(payload, now)
            else:
                for r in handle_event(kind, payload, now):
                    if decodes and r.output_tokens > 0:
                        # join the decode instance with the smallest batch
                        dec = min(decodes, key=lambda d: (len(d.jobs),
                                                          d.instance_id))
                        dec.join(r, now)

        return ClusterResult(
            requests=list(requests),
            blocking_times=[b for e in engines for b in e.blocking],
            rounds=sum(e.rounds for e in engines),
            preemptions=sum(e.preemptions for e in engines),
            makespan=now,
            dispatched=[e.n_dispatched for e in engines],
            decoded=sum(len(d.finished) for d in decodes),
        )


def simulate_cluster(system: str, requests: Sequence[Request], *,
                     model: str = "llama3-8b",
                     num_instances: int = 2,
                     dispatch: str = "round-robin",
                     decode_instances: int = 0,
                     hw=None, **overrides) -> ClusterResult:
    """Cluster counterpart of `repro.sim.policies.simulate` — same baseline
    presets, same fresh-copy semantics, plus instance count and dispatch."""
    import copy
    from dataclasses import replace

    from repro.sim.costmodel import A800, MODEL_SPECS, MODEL_TP
    from repro.sim.policies import preset

    spec = replace(MODEL_SPECS[model], tp=MODEL_TP.get(model, 1))
    cost = PrefillCostModel(spec, hw or A800)
    sim = ClusterSim(cost, preset(system, **overrides),
                     num_instances=num_instances, dispatch=dispatch,
                     decode_instances=decode_instances)
    return sim.run([copy.copy(r) for r in requests])
