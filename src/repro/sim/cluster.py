"""Cluster-scale discrete-event simulator: N prefill instances + dispatch +
decode instances with TBT-slack-aware scheduling, on ONE shared event heap.

Each prefill instance is an `InstanceEngine` (the exact state machine behind
`PrefillSim` — a 1-instance round-robin cluster reproduces the single-instance
simulator event-for-event). Arrivals are routed by a pluggable dispatch policy
from `repro.core.dispatch` — the same policy objects the real `Proxy` uses —
and completed prefills hand over to decode instances (`DecodeSim`) modeled as
continuous-batching processor sharing with TPOT/TBT SLO accounting
(`DecodeCostModel`), so the cluster reports *end-to-end* goodput.

The decode stage is schedulable, not just accounted (docs/SCHEDULING.md):

  * With a KV slot cap (``decode_max_batch``) a decode instance admits at most
    B streams; the rest queue. Admission is a `DecodeSchedulerCore` policy —
    FCFS (the paper's deliberately-plain decode) or decode S-EDF, which ranks
    by TBT-deadline slack using `DecodeCostModel.step_time` predictions via a
    `DecodeStepPredictor`.
  * Decode S-EDF preempts at token boundaries: a near-deadline queued stream
    displaces the most slack-rich resident (progress kept, resumed later) —
    the decode analogue of the paper's operator-level prefill preemption.
  * Decode *migration* (``decode_migration=True``): queued decodes are moved
    off an instance whose effective TBT pressure crossed the SLO knee, KV
    handoff priced by `DecodeCostModel.kv_transfer_time`, planned by the
    cost-gated `plan_decode_migrations` (shared with the real Proxy).
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.dispatch import (DecodeCandidate, DecodeLoad, DispatchPolicy,
                                 InstanceLoad, competing_tokens,
                                 make_dispatch, plan_decode_migrations,
                                 predicted_ttft)
from repro.core.faults import FaultPlan
from repro.core.metrics import percentile_report, slo_frac_percentile
from repro.core.predictor import (DecodeStepPredictor, OnlineTTFTPredictor,
                                  expected_accept_tokens,
                                  TTFTPredictor)
from repro.core.prefixcache import PrefixBlockManager
from repro.core.tieredcache import TieredBlockManager
from repro.core.request import Request, RequestState
from repro.core.scheduler import (DecodeEntry, DecodeSchedulerCore,
                                  HybridSchedulerCore, SchedulerCore)
from repro.sim.costmodel import (DecodeCostModel, HardwareSpec,
                                 PrefillCostModel, resolve_hardware)
from repro.sim.simulator import (ARRIVAL, DECODE_DONE, DECODE_JOIN,
                                 InstanceEngine, SimConfig, handle_event,
                                 reset_requests)

# hybrid-instance step completion (the colocated engine self-chains these;
# prefill/decode event kinds 0..4 live in repro.sim.simulator)
HYBRID_STEP = 5
# tiered prefix cache: a request whose cold (host/disk-resident) prefix won
# the promote-vs-recompute gate arrives at its instance only after the copy
# lands — TTFT includes the promotion latency by construction
PROMOTE_DONE = 6
# instance churn (core/faults.py FaultPlan): an instance leaves the pool
# (crash / spot kill / watchdog-detected hang / link drop) or rejoins it.
# Payload is (phase, FaultEvent); phases: "drain" (spot notice), "freeze"
# (hang onset), "kill" (strand queued+running work), "slow"/"unslow"
# (gray slowdown), "link"/"unlink" (decode kv_link drop), "up" (rejoin).
INSTANCE_DOWN = 7
INSTANCE_UP = 8

# token count at which per-instance peak prefill throughput (the
# capacity-weighted dispatch normalizer) is probed: long enough to saturate
# compute on every supported hardware generation
CAPACITY_PROBE_TOKENS = 8192


@dataclass
class _DecodeJob:
    request: Request
    joined: float                         # first enqueue (fixes the deadline)
    done: float = 0.0                     # tokens decoded (fractional)
    order: int = 0                        # admission order (FCFS / tiebreak)

    @property
    def context(self) -> float:
        """Current context (prompt + decoded) — KV held / to hand off."""
        return self.request.num_tokens + self.done

    @property
    def remaining(self) -> float:
        return self.request.output_tokens - self.done


class DecodeSim:
    """One decode instance: a continuous batch in which all RESIDENT requests
    advance together at 1/t_step(B, mean_context) tokens/sec (processor
    sharing). Batch changes re-rate everyone; stale completion events are
    invalidated by an epoch counter, so events are O(joins + leaves).

    With ``max_batch > 0`` at most that many streams are resident (KV slot
    cap); the rest wait in an admission queue ordered by the scheduler policy
    (`DecodeSchedulerCore`): FCFS, or decode S-EDF with token-boundary
    preemption. ``max_batch = 0`` (default) reproduces the original unbounded
    processor-sharing decode event-for-event."""

    def __init__(self, cost: DecodeCostModel, heap: List, seq,
                 instance_id: int = 0, *, max_batch: int = 0,
                 scheduler: Optional[DecodeSchedulerCore] = None,
                 step_predictor: Optional[DecodeStepPredictor] = None,
                 spec_decode: bool = False, draft_k: int = 4,
                 spec_accept: float = 0.0):
        self.cost = cost
        self.heap = heap
        self.seq = seq
        self.instance_id = instance_id
        self.max_batch = max_batch
        self.sched = scheduler or DecodeSchedulerCore(policy="fcfs")
        self.step_pred = step_predictor \
            or DecodeStepPredictor(prior=cost.step_time)
        # speculative decoding (fluid model): a stream with per-token accept
        # probability `a` (Request.spec_accept, falling back to the
        # instance-wide `spec_accept`) advances expected_accept_tokens(a, k)
        # tokens per step — the SAME analytic accept surface the runtime's
        # EMA converges to (evaluated-is-deployed). Off by default: every
        # rate below multiplies/divides by exactly 1.0, bit-identical.
        self.spec_decode = spec_decode
        self.draft_k = draft_k
        self.spec_accept = spec_accept
        self.jobs: Dict[int, _DecodeJob] = {}      # resident batch
        self.waiting: Dict[int, _DecodeJob] = {}   # queued for admission
        self.epoch = 0
        self.last_update = 0.0
        self.finished: List[Request] = []
        self.preemptions = 0
        self.frozen = False     # hung (fault injection): no progress, no
                                # completion events, until killed or revived
        self._order = itertools.count()

    def _step_time(self) -> float:
        if not self.jobs:
            return 0.0
        ctx = sum(j.request.num_tokens + j.done for j in self.jobs.values())
        return self.cost.step_time(len(self.jobs), ctx / len(self.jobs))

    def _e_of(self, job: _DecodeJob) -> float:
        """E[tokens committed per step] for one stream (1.0 = plain)."""
        if not self.spec_decode:
            return 1.0
        a = float(getattr(job.request, "spec_accept", 0.0) or self.spec_accept)
        return expected_accept_tokens(a, self.draft_k)

    def _e_mean(self, jobs) -> float:
        jobs = list(jobs)
        if not self.spec_decode or not jobs:
            return 1.0
        return sum(self._e_of(j) for j in jobs) / len(jobs)

    def _advance(self, now: float) -> None:
        dt = now - self.last_update
        self.last_update = now
        if dt <= 0 or not self.jobs or self.frozen:
            return
        t_step = self._step_time()
        gained = dt / t_step if t_step > 0 else float("inf")
        for j in self.jobs.values():
            j.done = min(j.done + gained * self._e_of(j),
                         float(j.request.output_tokens))

    def _reschedule(self, now: float) -> None:
        self.epoch += 1
        if not self.jobs or self.frozen:
            return
        t_step = self._step_time()
        t_next = min((j.request.output_tokens - j.done) * t_step
                     / self._e_of(j)
                     for j in self.jobs.values())
        heapq.heappush(self.heap, (now + max(t_next, 0.0), next(self.seq),
                                   DECODE_DONE, (self, self.epoch)))

    def _rebatch(self, now: float) -> None:
        """Re-run batch admission after a membership change. Residents keep
        insertion order (the float-sum order of `_step_time`); preempted
        streams keep their progress and re-queue."""
        everyone = {**self.jobs, **self.waiting}
        if self.max_batch <= 0:
            self.jobs = everyone          # unbounded: plain processor sharing
            self.waiting = {}
            return
        if not everyone:
            return
        total = len(everyone)
        b_eff = min(self.max_batch, total)
        ctx = sum(j.context for j in everyone.values())
        # per-accepted-token pricing for S-EDF slack (speculation commits
        # E[tokens/step] tokens per step; /1.0 without it)
        t_step = self.step_pred.step_time(b_eff, ctx / total) \
            / self._e_mean(everyone.values())
        entries = [DecodeEntry(key=rid, remaining_tokens=j.remaining,
                               deadline=j.request.decode_deadline,
                               order=j.order)
                   for rid, j in everyone.items()]
        batch, preempted = self.sched.select_batch(
            entries, set(self.jobs), self.max_batch, now, t_step)
        for rid in preempted:
            self.preemptions += 1
            everyone[rid].request.decode_preemptions += 1
        self.jobs = {rid: everyone[rid] for rid in batch}
        self.waiting = {rid: j for rid, j in everyone.items()
                        if rid not in self.jobs}

    # ------------------------------------------------------------- pressure
    def pressure(self, req: Request, now: float) -> float:
        """Predicted TBT pressure were `req`'s decode to join this instance
        now: the effective step time (`DecodeLoad.effective_step` — the ONE
        slot-cap + queue-time-sharing formula, shared with the migration
        planner) at population N+1 over the candidate's TBT SLO (1.0 =
        exactly at the SLO knee). Read-only — uses the jobs' last
        materialized progress, which only perturbs the mean context."""
        if req.tbt_slo <= 0 or not math.isfinite(req.tbt_slo):
            return 0.0
        return self.snapshot_load().effective_step(
            1, float(req.num_tokens)) / req.tbt_slo

    @property
    def backlog(self) -> int:
        """Streams held (resident + queued) — the least-batch join signal."""
        return len(self.jobs) + len(self.waiting)

    def snapshot_load(self) -> DecodeLoad:
        """Migration-planner view of this instance (core/dispatch.py)."""
        ctx = sum(j.context for j in self.jobs.values()) \
            + sum(j.context for j in self.waiting.values())
        step_time = self.step_pred.step_time
        if self.spec_decode:
            # migration gating prices the per-ACCEPTED-token service rate
            e = self._e_mean(list(self.jobs.values())
                             + list(self.waiting.values()))
            if e > 1.0:
                raw = step_time
                step_time = lambda b, c, _f=raw, _e=e: _f(b, c) / _e  # noqa: E731
        return DecodeLoad(instance_id=self.instance_id,
                          n_resident=len(self.jobs),
                          n_waiting=len(self.waiting),
                          ctx_tokens=ctx, max_batch=self.max_batch,
                          step_time=step_time)

    # --------------------------------------------------------------- events
    def join(self, req: Request, now: float) -> None:
        if req.decode_start is None:
            req.decode_start = now        # fixes Request.decode_deadline
        job = _DecodeJob(request=req, joined=now, order=next(self._order))
        self._admit(job, now)

    def migrate_in(self, job: _DecodeJob, now: float) -> None:
        """Arrival of a migrated stream (KV transfer done): re-enters
        admission with its progress and ORIGINAL deadline intact."""
        job.order = next(self._order)
        self._admit(job, now)

    def _admit(self, job: _DecodeJob, now: float) -> None:
        self._advance(now)
        self.waiting[job.request.rid] = job
        self._rebatch(now)
        self._reschedule(now)

    def pop_waiting(self, rid: int) -> _DecodeJob:
        """Remove a QUEUED stream (migration departure). Never touches the
        resident batch, so no re-rate or reschedule is needed."""
        return self.waiting.pop(rid)

    # ------------------------------------------------------- fault injection
    def freeze(self, now: float) -> None:
        """Hang onset: materialize progress up to now, then stop — pending
        completion events go stale (epoch bump) and no new ones schedule
        until the instance is killed (strand) or thaws."""
        self._advance(now)
        self.frozen = True
        self.epoch += 1                   # invalidates in-flight DECODE_DONE

    def thaw(self, now: float) -> None:
        self.frozen = False
        self.last_update = now
        self._rebatch(now)
        self._reschedule(now)

    def strand(self, now: float) -> List[Request]:
        """Instance death: every held stream (resident + queued) loses its
        KV and is returned to the cluster for recovery. Leaves the instance
        empty and un-frozen (ready for a later rejoin)."""
        if not self.frozen:
            self._advance(now)
        victims = [j.request for j in self.jobs.values()] \
            + [j.request for j in self.waiting.values()]
        self.jobs.clear()
        self.waiting.clear()
        self.epoch += 1
        self.frozen = False
        self.last_update = now
        return victims

    def on_decode_done(self, payload, now: float) -> List[Request]:
        _, epoch = payload
        if epoch != self.epoch:
            return []                                  # stale
        self._advance(now)
        done = [j for j in self.jobs.values()
                if j.done >= j.request.output_tokens - 1e-6]
        for j in done:
            r = j.request
            r.finish_time = now
            r.mean_tpot = (now - j.joined) / max(r.output_tokens, 1)
            del self.jobs[r.rid]
            self.finished.append(r)
        self._rebatch(now)                # freed slots admit from the queue
        self._reschedule(now)
        return [j.request for j in done]


class _SlowedCost:
    """Gray-failure wrapper around a PrefillCostModel: every operator takes
    ``factor``x as long. The task already running when the slowdown fires
    keeps its scheduled completion (the factor applies from the next task),
    and dispatch sees the de-rated capacity immediately."""

    def __init__(self, base, factor: float):
        self._base = base
        self.factor = factor
        self.m = base.m
        self.hw = base.hw

    def op_durations(self, tokens, chunk_tokens=0, prefix=0):
        return self._base.op_durations(tokens, chunk_tokens, prefix) \
            * self.factor


@dataclass
class _HybridPrefill:
    """One prompt mid-prefill on a hybrid instance: `done` tokens computed
    so far — the resume offset the next admitted slice starts at."""
    request: Request
    done: int = 0


class HybridSim:
    """One colocated (prefill + decode) instance: the unified token-budget
    runtime's cost-model twin (serving/hybrid_instance.py — evaluated is
    deployed: both drive the SAME `HybridSchedulerCore`).

    Round-driven rather than task-driven: each self-chained HYBRID_STEP event
    executes one `plan_step` round — the admitted prefill slices run as
    operator-chunked compute, and decode steps are WOVEN between operators at
    an SLO-derived cadence (the colocation payoff of operator-level
    interruption: a prefill chunk yields to decode within ~1 operator, so
    decode TBT is set by the weave cadence, not by whole-chunk serialization).
    With C = sum of slice costs, s = DecodeCostModel.step_time(B, mean_ctx),
    and cadence target tau = margin * min resident tbt_slo (clamped to
    s + one operator — the true yield latency floor), the round prices as

        k      = ceil(C / (tau - s))     woven decode steps (>= 1)
        t_round = round_overhead + C + k*s

    so every admitted decode stream advances k tokens with TPOT ~= tau, and
    phase interference is the measured-model cost of real work serialized at
    operator granularity — not fig16's hard-coded 0.65 utilization tax. A
    prefill-completed request joins THIS instance's decode phase directly
    (its KV is already in the shared pool — no PD handoff, no
    `kv_transfer_time`)."""

    # decode cadence targets this fraction of the tightest resident TBT SLO,
    # leaving headroom for round overheads and plan jitter
    CADENCE_MARGIN = 0.8

    def __init__(self, cost: PrefillCostModel, decode_cost: DecodeCostModel,
                 heap: List, seq, instance_id: int = 0, *,
                 token_budget: int = 4096, chunk_tokens: int = 512,
                 decode_max_batch: int = 0, policy: str = "s-edf",
                 decode_policy: str = "s-edf",
                 decode_preempt: Optional[bool] = None,
                 predictor: Optional[TTFTPredictor] = None,
                 round_overhead: float = 100e-6, capacity: float = 1.0,
                 spec_decode: bool = False, draft_k: int = 4,
                 spec_accept: float = 0.0):
        self.cost = cost
        self.decode_cost = decode_cost
        # speculative decoding (fluid model) — same accept surface as
        # DecodeSim: each woven decode step advances E[a, k] tokens and each
        # admitted stream prices E budget tokens in plan_step
        self.spec_decode = spec_decode
        self.draft_k = draft_k
        self.spec_accept = spec_accept
        self.heap = heap
        self.seq = seq
        self.instance_id = instance_id
        self.capacity = capacity
        self.predictor = predictor
        self.chunk_tokens = chunk_tokens
        self.round_overhead = round_overhead
        self.core = HybridSchedulerCore(
            prefill=SchedulerCore(predictor=predictor, policy=policy,
                                  enable_batching=False),
            decode=DecodeSchedulerCore(
                policy=decode_policy,
                preempt=(decode_policy == "s-edf") if decode_preempt is None
                else decode_preempt),
            token_budget=token_budget, chunk_tokens=chunk_tokens,
            decode_max_batch=decode_max_batch)
        # yield latency floor: the longest single operator of a budget-sized
        # chunk — decode can interrupt prefill no faster than one operator
        probe = chunk_tokens if chunk_tokens > 0 else 512
        self.op_yield = float(max(cost.op_durations(probe, chunk_tokens)))
        self.prefills: Dict[int, _HybridPrefill] = {}
        self.jobs: Dict[int, _DecodeJob] = {}     # every local decode stream
        self.resident: Set[int] = set()           # last step's decode batch
        self.busy = False
        self.epoch = 0
        self.steps = 0
        self.preemptions = 0                      # decode displacements
        self.finished: List[Request] = []
        self.n_dispatched = 0
        self.blocking: List[float] = []           # kept for result plumbing
        self._order = itertools.count()
        # mixed-pool wiring (set by ClusterSim.run when a dedicated decode
        # pool exists and hybrid_decode_offload is on): completed prefills
        # hand off instead of decoding locally, so the hybrid stays a
        # weave-tax-free prefill absorber and decode consolidates on the
        # dedicated cards
        self.offload: Optional[Callable[[Request, float], None]] = None
        # tiered prefix residency (set by ClusterSim.run in tiered mode):
        # called when a prefill finishes so the cluster can commit the
        # prompt's chain keys to THIS instance's block manager
        self.on_prefill_done: Optional[Callable[[Request, float],
                                                None]] = None

    # ---------------------------------------------------------------- load
    def snapshot_load(self, candidate: Request, now: float) -> InstanceLoad:
        items = [(float(p.request.num_tokens - p.done),
                  p.request.deadline) for p in self.prefills.values()]
        predict = self.predictor.predict if self.predictor is not None \
            else None
        return InstanceLoad(
            instance_id=self.instance_id,
            queued_tokens=competing_tokens(items, candidate, now, predict),
            n_outstanding=len(self.prefills),
            capacity=self.capacity)

    def pressure(self, req: Request, now: float) -> float:
        """Predicted TBT pressure were this request decoded here — the SAME
        `DecodeLoad.effective_step` formula DecodeSim/the migration planner
        price with, over the local decode population."""
        if req.tbt_slo <= 0 or not math.isfinite(req.tbt_slo):
            return 0.0
        cap = self.core.decode_max_batch
        n = len(self.jobs)
        n_res = min(n, cap) if cap > 0 else n
        load = DecodeLoad(instance_id=self.instance_id, n_resident=n_res,
                          n_waiting=n - n_res,
                          ctx_tokens=sum(j.context for j in self.jobs.values()),
                          max_batch=cap, step_time=self.decode_cost.step_time)
        return load.effective_step(1, float(req.num_tokens)) / req.tbt_slo

    # --------------------------------------------------------------- events
    def on_arrival(self, req: Request, now: float) -> None:
        self.n_dispatched += 1
        # prefix-cache hit (set by the cluster's residency model, 0 without
        # sharing): those tokens' KV is already resident, so the first
        # admitted slice resumes past them — same as the runtime's
        # table.length-seeded chunk offset
        done = min(int(getattr(req, "prefix_hit", 0)),
                   max(req.num_tokens - 1, 0))
        self.prefills[req.rid] = _HybridPrefill(request=req, done=done)
        if not self.busy:
            self._start_step(now)

    def _decode_entries(self) -> List[DecodeEntry]:
        return [DecodeEntry(key=rid, remaining_tokens=j.remaining,
                            deadline=j.request.decode_deadline, order=j.order)
                for rid, j in self.jobs.items()]

    def _e_of(self, job) -> float:
        """Expected accepted tokens per decode step for one stream."""
        if not self.spec_decode:
            return 1.0
        a = float(getattr(job.request, "spec_accept", 0.0) or self.spec_accept)
        return expected_accept_tokens(a, self.draft_k)

    def _e_mean(self, jobs) -> float:
        jobs = list(jobs)
        if not self.spec_decode or not jobs:
            return 1.0
        return sum(self._e_of(j) for j in jobs) / len(jobs)

    def _start_step(self, now: float) -> None:
        """Plan one hybrid step and schedule its completion event."""
        entries = self._decode_entries()
        e_mean = self._e_mean(self.jobs.values())
        t_hint = 0.0
        if entries:
            cap = self.core.decode_max_batch
            b = min(len(entries), cap) if cap > 0 else len(entries)
            ctx = sum(j.context for j in self.jobs.values()) / len(self.jobs)
            # slack hint prices the per-ACCEPTED-token rate, matching the
            # runtime's `_t_token` (decode_instance.py)
            t_hint = self.decode_cost.step_time(b, ctx) / e_mean
        plan = self.core.plan_step(
            now, prefill=[p.request for p in self.prefills.values()],
            prefill_done={rid: p.done for rid, p in self.prefills.items()},
            decode_entries=entries, decode_resident=self.resident,
            t_step=t_hint, decode_cost=e_mean)
        if plan.empty:
            self.busy = False
            return
        for rid in plan.preempted_decode:
            self.preemptions += 1
            self.jobs[rid].request.decode_preemptions += 1
        s_dec = 0.0
        if plan.decode_keys:
            ctx = sum(self.jobs[k].context for k in plan.decode_keys) \
                / len(plan.decode_keys)
            s_dec = self.decode_cost.step_time(len(plan.decode_keys), ctx)
        c_pre = 0.0
        for s in plan.prefill_slices:
            # incremental resumed-chunk cost: compute [offset, offset+n)
            # with the first `offset` tokens' KV already present
            c_pre += self.cost.prefill_time(s.offset + s.n_tokens,
                                            self.chunk_tokens, prefix=s.offset)
        # weave k decode steps through the round's prefill compute at the
        # SLO-derived cadence (see class docstring); pure decode rounds and
        # pure prefill rounds degenerate to k=1 / k=0
        k = 0
        if plan.decode_keys:
            if c_pre > 0:
                tau = self.CADENCE_MARGIN * min(
                    (self.jobs[key].request.tbt_slo
                     for key in plan.decode_keys
                     if math.isfinite(self.jobs[key].request.tbt_slo)
                     and self.jobs[key].request.tbt_slo > 0),
                    default=math.inf)
                gap = max(tau - s_dec, self.op_yield)
                k = max(1, math.ceil(c_pre / gap)) if math.isfinite(gap) \
                    else 1
            else:
                k = 1
        t = self.round_overhead + c_pre + k * s_dec
        self.busy = True
        self.epoch += 1
        heapq.heappush(self.heap, (now + t, next(self.seq), HYBRID_STEP,
                                   (self, self.epoch, plan, k)))

    def on_step(self, payload, now: float) -> None:
        _, epoch, plan, k = payload
        if epoch != self.epoch:
            return                                 # stale (defensive)
        self.steps += 1
        done_decode: List[int] = []
        for key in plan.decode_keys:
            j = self.jobs[key]
            j.done += min(float(k) * self._e_of(j), j.remaining)
            if j.done >= j.request.output_tokens:
                r = j.request
                r.finish_time = now
                r.mean_tpot = (now - j.joined) / max(r.output_tokens, 1)
                done_decode.append(key)
                self.finished.append(r)
        gone = set(done_decode)
        for key in gone:
            del self.jobs[key]
        self.resident = {k for k in plan.decode_keys if k not in gone}
        for s in plan.prefill_slices:
            p = self.prefills[s.key]
            p.done += s.n_tokens
            r = p.request
            # remaining-work basis for S-EDF ranking (ops_total stays 0, so
            # Request.remaining_tokens() reads batch_tokens directly)
            r.batch_tokens = max(r.num_tokens - p.done, 1)
            if p.done >= r.num_tokens:
                r.first_token_time = now
                r.state = RequestState.DONE
                del self.prefills[s.key]
                if self.on_prefill_done is not None:
                    self.on_prefill_done(r, now)
                if r.output_tokens > 0:
                    if self.offload is not None:
                        self.offload(r, now)
                    else:
                        # local decode join: the KV is already resident —
                        # no PD handoff, no transfer pricing
                        r.decode_start = now
                        self.jobs[r.rid] = _DecodeJob(
                            request=r, joined=now, order=next(self._order))
        self._start_step(now)


@dataclass
class ClusterResult:
    requests: List[Request]
    blocking_times: List[float]
    rounds: int
    preemptions: int
    makespan: float
    dispatched: List[int]                 # requests routed per prefill instance
    decoded: int = 0
    decode_preemptions: int = 0           # token-boundary batch displacements
    migrations: int = 0                   # decode streams moved cross-instance
    prefix_hit_tokens: int = 0            # prompt tokens served from prefix
                                          # caches (skipped recompute)
    prefix_evictions: int = 0             # cache blocks LRU-evicted
    prefix_promoted_tokens: int = 0       # hit tokens that had to be copied
                                          # up from host/disk first (tiered)
    tier_demotions: int = 0               # blocks demoted HBM -> host tier
    retries: int = 0                      # stranded-work re-dispatches (churn)
    shed_requests: int = 0                # rejected at admission (shedding)
    lost_requests: int = 0                # stranded forever: naive mode, or
                                          # retry budget exhausted

    @property
    def attainment(self) -> float:
        """TTFT-SLO attainment (comparable with single-instance SimResult)."""
        met = sum(1 for r in self.requests if r.slo_met)
        return met / max(len(self.requests), 1)

    @property
    def tbt_attainment(self) -> float:
        """Decode-phase TBT/TPOT-SLO attainment (prefill-only requests are
        vacuously met, mirroring Request.tbt_met)."""
        met = sum(1 for r in self.requests if r.tbt_met)
        return met / max(len(self.requests), 1)

    @property
    def e2e_attainment(self) -> float:
        """End-to-end goodness: TTFT and decode-TBT SLOs both attained."""
        met = sum(1 for r in self.requests if r.e2e_met)
        return met / max(len(self.requests), 1)

    @property
    def ttft_p99_norm(self) -> float:
        """p99 of TTFT/SLO over all requests (<= 1.0: the 99th-percentile
        request met its TTFT SLO; unfinished requests count as +inf). The
        tail-gated statistic fig23 frontiers are built from."""
        return slo_frac_percentile(self.requests, 99.0, "ttft")

    @property
    def tbt_p99_norm(self) -> float:
        """p99 of mean-TPOT/tbt_slo over decoding requests."""
        return slo_frac_percentile(self.requests, 99.0, "tbt")

    @property
    def e2e_p99_norm(self) -> float:
        """p99 of max(TTFT/SLO, TPOT/TBT-SLO) per request — the end-to-end
        tail counterpart of `e2e_attainment`."""
        return slo_frac_percentile(self.requests, 99.0, "e2e")

    def percentiles(self, by_task: bool = True) -> dict:
        """Full percentile families (p50/p90/p99 TTFT & TBT, aggregate and
        per task class) — `repro.core.metrics.percentile_report` shape,
        identical to `Proxy.report()['percentiles']`."""
        return percentile_report(self.requests, by_task=by_task)

    @property
    def admitted(self) -> List[Request]:
        """Requests NOT rejected by admission control (shedding). Shed
        requests get an explicit rejection, so they are not tail events for
        the clients the system chose to serve — the admitted-view metrics
        are what the overload panel of fig26 gates."""
        return [r for r in self.requests if not r.shed]

    @property
    def admitted_attainment(self) -> float:
        adm = self.admitted
        met = sum(1 for r in adm if r.slo_met)
        return met / max(len(adm), 1)

    @property
    def admitted_ttft_p99_norm(self) -> float:
        return slo_frac_percentile(self.admitted, 99.0, "ttft")

    @property
    def imbalance(self) -> float:
        """max/mean dispatched requests across instances (1.0 = perfect)."""
        mean = sum(self.dispatched) / max(len(self.dispatched), 1)
        return max(self.dispatched) / max(mean, 1e-9)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from per-instance prefix caches
        (0.0 with sharing disabled)."""
        total = sum(r.num_tokens for r in self.requests)
        return self.prefix_hit_tokens / max(total, 1)

    @property
    def promote_hit_rate(self) -> float:
        """Fraction of prefix-hit tokens that were cold — served by paying
        a host/disk promotion copy rather than from warm HBM (0.0 when
        untiered: every hit is warm)."""
        return self.prefix_promoted_tokens / max(self.prefix_hit_tokens, 1)


class ClusterSim:
    """N-instance prefill cluster + dispatch + decode phase, one event heap.

    Heterogeneous pools: pass ``hardware`` (one HardwareSpec per prefill
    instance — ``num_instances`` is then taken from its length). Each instance
    gets its own cost model, its own TTFT predictor fitted to its hardware
    (shared across same-spec instances), and a capacity (peak prefill
    throughput) surfaced to dispatch via ``InstanceLoad.capacity``. The
    dispatch-level predictor stays the reference one — load-blind JSQ on a
    mixed pool prices every instance's backlog at the same speed, which is
    exactly the failure mode capacity-weighted dispatch fixes.

    ``online_refit=True`` replaces each instance's predictor with an
    `OnlineTTFTPredictor` seeded from the reference fit: engines feed observed
    batch latencies back, so per-instance feasibility pricing converges to the
    instance's true speed even when the prior was fitted elsewhere.

    Decode stage: with ``decode-aware`` dispatch (or ``decode_affinity=True``)
    completed prefills hand over to the PAIRED decode instance (prefill i ->
    decode i mod D, the disaggregated-pool wiring that makes downstream
    pressure attributable); otherwise they join the least-loaded decode batch
    as before. ``decode_hardware`` heterogenizes the decode pool the same way.

    Decode scheduling (see module docstring / docs/SCHEDULING.md):
    ``decode_max_batch`` caps each decode instance's continuous batch (KV
    slots; 0 = unbounded processor sharing, the original model);
    ``decode_policy`` picks the admission order ("fcfs" | "s-edf");
    ``decode_preempt`` enables token-boundary displacement (defaults to True
    exactly when the policy is "s-edf"); ``decode_migration`` turns on
    cost-gated migration of queued decodes off over-the-knee instances
    (``migration_knee``, ``max_migrations`` tune the gates).
    """

    def __init__(self, cost: PrefillCostModel, sim_cfg: SimConfig, *,
                 num_instances: int = 2,
                 dispatch: str = "round-robin",
                 predictor: Optional[TTFTPredictor] = None,
                 decode_instances: int = 0,
                 decode_cost: Optional[DecodeCostModel] = None,
                 hardware: Optional[Sequence[HardwareSpec]] = None,
                 decode_hardware: Optional[Sequence[HardwareSpec]] = None,
                 online_refit: bool = False,
                 decode_affinity: Optional[bool] = None,
                 decode_max_batch: int = 0,
                 decode_policy: str = "fcfs",
                 decode_preempt: Optional[bool] = None,
                 decode_migration: bool = False,
                 migration_knee: float = 0.85,
                 max_migrations: int = 1,
                 prefix_cache_blocks: int = 0,
                 prefix_block: int = 128,
                 host_cache_blocks: int = 0,
                 disk_cache_blocks: int = 0,
                 hybrid_instances: int = 0,
                 hybrid_token_budget: Optional[int] = None,
                 hybrid_chunk_tokens: Optional[int] = None,
                 hybrid_decode_offload: bool = False,
                 fault_plan: Optional["FaultPlan"] = None,
                 recovery: str = "retry",
                 max_retries: int = 3,
                 retry_backoff: float = 0.05,
                 retry_backoff_cap: float = 2.0,
                 watchdog_s: float = 1.0,
                 shed_policy: str = "off",
                 shed_budget: float = 2.0,
                 spec_decode: bool = False,
                 draft_k: int = 4,
                 spec_accept: float = 0.0):
        if hardware is not None:
            hardware = [resolve_hardware(hw) for hw in hardware]
            num_instances = len(hardware)
        if num_instances < 1 and hybrid_instances < 1:
            raise ValueError("need at least one prefill or hybrid instance")
        self.cost = cost
        self.cfg = sim_cfg
        chunk = sim_cfg.chunk_tokens
        self.predictor = predictor or TTFTPredictor.from_cost_model(
            lambda n: cost.prefill_time(n, chunk), max_tokens=32768)
        self.num_instances = num_instances
        self.policy: DispatchPolicy = make_dispatch(dispatch, self.predictor)
        self.online_refit = online_refit

        # per-instance cost models + predictors (predictors cached per
        # hardware spec so a 4x-same-card pool fits once)
        if hardware is not None:
            self.instance_costs = [PrefillCostModel(cost.m, hw)
                                   for hw in hardware]
        else:
            self.instance_costs = [cost] * num_instances
        fits: Dict[str, TTFTPredictor] = {cost.hw.name: self.predictor}
        self.instance_predictors: List[TTFTPredictor] = []
        for c in self.instance_costs:
            if c.hw.name not in fits:
                fits[c.hw.name] = TTFTPredictor.from_cost_model(
                    lambda n, c=c: c.prefill_time(n, chunk), max_tokens=32768)
            self.instance_predictors.append(fits[c.hw.name])
        self.capacities = [c.throughput(CAPACITY_PROBE_TOKENS, chunk)
                           for c in self.instance_costs]

        self.num_decode = decode_instances
        if decode_hardware is not None:
            decode_hardware = [resolve_hardware(hw) for hw in decode_hardware]
            if decode_instances and len(decode_hardware) != decode_instances:
                raise ValueError("decode_hardware length must match "
                                 "decode_instances")
            self.num_decode = len(decode_hardware)
            self.decode_costs = [DecodeCostModel(cost.m, hw)
                                 for hw in decode_hardware]
        else:
            self.decode_costs = [decode_cost
                                 or DecodeCostModel(cost.m, cost.hw)] \
                * self.num_decode
        if decode_affinity is None:
            decode_affinity = self.policy.needs_decode_pressure
        self.decode_affinity = decode_affinity and self.num_decode > 0
        if decode_policy not in ("fcfs", "s-edf"):
            raise ValueError(f"unknown decode_policy {decode_policy!r}; "
                             f"known: ['fcfs', 's-edf']")
        self.decode_max_batch = decode_max_batch
        self.decode_policy = decode_policy
        self.decode_preempt = (decode_policy == "s-edf") \
            if decode_preempt is None else decode_preempt
        if decode_migration and decode_max_batch <= 0:
            # migration moves QUEUED decodes; an unbounded instance admits
            # everything immediately, so the flag would be a silent no-op
            raise ValueError("decode_migration requires a decode_max_batch "
                             "slot cap (> 0): unbounded decode never queues")
        self.decode_migration = decode_migration and self.num_decode > 1
        self.migration_knee = migration_knee
        self.max_migrations = max_migrations
        # prefix sharing: per-instance cache-residency model (the SAME
        # PrefixBlockManager the real PagedKVCache delegates to — evaluated
        # is deployed), `prefix_cache_blocks` capacity each, keyed on
        # Request.prefix_hash at `prefix_block` tokens per block. 0 = no
        # sharing: every request prefills from token 0 (the original model).
        self.prefix_cache_blocks = prefix_cache_blocks
        self.prefix_block = prefix_block
        # tiered residency: evicted blocks demote into a `host_cache_blocks`
        # host tier (then a `disk_cache_blocks` disk tier) instead of
        # vanishing, and dispatch prices warm/cold/absent as three prices —
        # a cold hit is taken only when the predictor says the promotion
        # copy (HardwareSpec.host_bw/disk_bw links) beats recompute. 0 host
        # blocks = the single-tier model above, byte-identical.
        if host_cache_blocks > 0 and prefix_cache_blocks <= 0:
            raise ValueError("host_cache_blocks requires prefix sharing "
                             "(prefix_cache_blocks > 0)")
        self.host_cache_blocks = host_cache_blocks
        self.disk_cache_blocks = disk_cache_blocks
        self.tiered = prefix_cache_blocks > 0 and host_cache_blocks > 0
        # colocated pool: `hybrid_instances` HybridSim engines appended after
        # the prefill pool in dispatch order (indices num_instances..), each
        # running prefill chunks + local decode in one token-budget step.
        # Budget defaults to the sim batch budget, slice quantum to the
        # prefill chunk size; 0 instances leaves every legacy path untouched.
        self.num_hybrid = hybrid_instances
        self.hybrid_token_budget = sim_cfg.batch_budget \
            if hybrid_token_budget is None else hybrid_token_budget
        self.hybrid_chunk_tokens = sim_cfg.chunk_tokens \
            if hybrid_chunk_tokens is None else hybrid_chunk_tokens
        self.hybrid_decode_cost = decode_cost \
            or DecodeCostModel(cost.m, cost.hw)
        self.hybrid_capacity = cost.throughput(CAPACITY_PROBE_TOKENS, chunk) \
            if hybrid_instances > 0 else 0.0
        # mixed pools: hand hybrid-prefilled streams to the dedicated decode
        # pool (requires one) instead of decoding them locally
        self.hybrid_decode_offload = hybrid_decode_offload \
            and hybrid_instances > 0 and self.num_decode > 0
        # instance churn (core/faults.py): a FaultPlan schedules per-instance
        # crash/hang/slowdown/spot/kv_link faults. `recovery="retry"` strands
        # a dying instance's work back to the dispatch layer and re-dispatches
        # with capped exponential backoff under a per-request retry budget;
        # `recovery="none"` is the naive baseline (stranded = lost, +inf tail
        # events). With `fault_plan=None` (default) every churn branch is
        # unreachable — committed fig9..fig25 baselines stay byte-equal.
        if recovery not in ("none", "retry"):
            raise ValueError(f"unknown recovery mode {recovery!r}; "
                             f"known: ['none', 'retry']")
        if shed_policy not in ("off", "doomed-only", "budget"):
            raise ValueError(
                f"unknown shed_policy {shed_policy!r}; "
                f"known: ['off', 'doomed-only', 'budget']")
        self.fault_plan = fault_plan
        self.recovery = recovery
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.retry_backoff_cap = retry_backoff_cap
        self.watchdog_s = watchdog_s
        # SLO-aware admission control (graceful degradation): "doomed-only"
        # sheds a fresh arrival when every live instance predicts a TTFT past
        # its SLO AND the pool is saturated; "budget" sheds when the best
        # predicted TTFT exceeds shed_budget * slo. Off by default.
        self.shed_policy = shed_policy
        self.shed_budget = shed_budget
        # speculative decoding (fluid model): decode/hybrid engines advance
        # expected_accept_tokens(a, draft_k) tokens per step, with `a` read
        # from Request.spec_accept (falling back to the cluster-wide
        # spec_accept). Off by default — every E factor is exactly 1.0 and
        # committed fig9..fig26 baselines stay byte-equal.
        self.spec_decode = spec_decode
        self.draft_k = draft_k
        self.spec_accept = spec_accept

    def run(self, requests: Sequence[Request]) -> ClusterResult:
        heap: List[Tuple[float, int, int, object]] = []
        seq = itertools.count()
        predictors = self.instance_predictors
        if self.online_refit:
            predictors = [OnlineTTFTPredictor.from_predictor(p)
                          for p in predictors]
        self.run_predictors = predictors      # exposed for refit inspection
        engines = [InstanceEngine(self.instance_costs[i], self.cfg,
                                  predictors[i], heap, seq, instance_id=i,
                                  capacity=self.capacities[i])
                   for i in range(self.num_instances)]
        decodes = [DecodeSim(self.decode_costs[i], heap, seq, instance_id=i,
                             max_batch=self.decode_max_batch,
                             scheduler=DecodeSchedulerCore(
                                 policy=self.decode_policy,
                                 preempt=self.decode_preempt),
                             spec_decode=self.spec_decode,
                             draft_k=self.draft_k,
                             spec_accept=self.spec_accept)
                   for i in range(self.num_decode)]
        hybrids = [HybridSim(self.cost, self.hybrid_decode_cost, heap, seq,
                             instance_id=self.num_instances + i,
                             token_budget=self.hybrid_token_budget,
                             chunk_tokens=self.hybrid_chunk_tokens,
                             decode_max_batch=self.decode_max_batch,
                             policy=self.cfg.policy,
                             decode_policy=self.decode_policy,
                             decode_preempt=self.decode_preempt,
                             predictor=self.predictor,
                             round_overhead=self.cfg.round_overhead,
                             capacity=self.hybrid_capacity,
                             spec_decode=self.spec_decode,
                             draft_k=self.draft_k,
                             spec_accept=self.spec_accept)
                   for i in range(self.num_hybrid)]
        n_migrations = 0
        reset_requests(requests)
        for r in requests:
            heapq.heappush(heap, (r.arrival, next(seq), ARRIVAL, r))

        # ---------------------------------------------------- instance churn
        # pool-membership state driven by INSTANCE_DOWN/INSTANCE_UP events.
        # All sets stay empty with fault_plan=None, so the legacy event loop
        # is untouched (committed baselines byte-equal).
        down_p: Set[int] = set()        # dead prefill engines
        drain_p: Set[int] = set()       # spot notice: no new dispatch
        frozen_p: Set[int] = set()      # hung: events dropped until killed
        down_dec: Set[int] = set()      # dead decode instances
        drain_dec: Set[int] = set()
        link_down: Set[int] = set()     # kv_link drop: no handoffs land
        slowed: Dict[int, Tuple[object, float]] = {}  # idx -> (cost, cap)
        # a kill starts a new engine incarnation: any event the old one
        # pushed (COMPLETION / PREEMPT_AT, identified by heap seq < the seq
        # consumed at kill time) is dropped outright. The per-task
        # running/tid/epoch stale checks are NOT enough across a kill — a
        # leftover PREEMPT_AT firing post-rejoin clears the NEW task's
        # pending_preempt flag and lets stale decisions interleave.
        killed_seq: Dict[int, int] = {}
        n_retries = n_shed = n_lost = 0
        prefill_up_times: List[float] = []
        if self.fault_plan is not None:
            for ev in self.fault_plan:
                pool_n = len(engines) if ev.target == "prefill" \
                    else len(decodes)
                if ev.instance >= pool_n:
                    continue             # plan sized for a bigger pool
                if ev.kind == "slowdown":
                    heapq.heappush(heap, (ev.time, next(seq), INSTANCE_DOWN,
                                          ("slow", ev)))
                    if math.isfinite(ev.duration):
                        heapq.heappush(heap, (ev.time + ev.duration,
                                              next(seq), INSTANCE_UP,
                                              ("unslow", ev)))
                    continue
                if ev.kind == "kv_link":
                    heapq.heappush(heap, (ev.time, next(seq), INSTANCE_DOWN,
                                          ("link", ev)))
                    if math.isfinite(ev.duration):
                        heapq.heappush(heap, (ev.time + ev.duration,
                                              next(seq), INSTANCE_UP,
                                              ("unlink", ev)))
                    continue
                kill_at = ev.down_at
                if ev.kind == "spot":
                    heapq.heappush(heap, (ev.time, next(seq), INSTANCE_DOWN,
                                          ("drain", ev)))
                elif ev.kind == "hang":
                    # undetected until the watchdog deadline: the instance
                    # keeps accepting dispatch but completes nothing
                    heapq.heappush(heap, (ev.time, next(seq), INSTANCE_DOWN,
                                          ("freeze", ev)))
                    kill_at = ev.time + self.watchdog_s
                heapq.heappush(heap, (kill_at, next(seq), INSTANCE_DOWN,
                                      ("kill", ev)))
                if math.isfinite(ev.duration):
                    up = max(ev.up_at, kill_at + 1e-9)
                    heapq.heappush(heap, (up, next(seq), INSTANCE_UP,
                                          ("up", ev)))
                    if ev.target == "prefill":
                        prefill_up_times.append(up)
        prefill_up_times.sort()

        def recover(victims: Sequence[Request], now: float) -> None:
            """Stranded work returns to the dispatch layer: progress and KV
            died with the instance, so the request resets to scratch and
            re-enters as a delayed ARRIVAL (capped exponential backoff)
            until its retry budget runs out. recovery="none" is the naive
            baseline: stranded requests are simply lost (+inf tail)."""
            nonlocal n_retries, n_lost
            for r in victims:
                r.state = RequestState.WAITING
                r.ops_done = 0
                r.ops_total = 0
                r.batch_tokens = r.num_tokens
                r.prefix_hit = 0
                r.first_token_time = None
                r.decode_start = None
                r.mean_tpot = None
                if self.recovery == "none":
                    n_lost += 1
                    continue
                r.retries += 1
                if r.retries > self.max_retries:
                    r.state = RequestState.DROPPED   # retries exhausted
                    n_lost += 1
                    continue
                n_retries += 1
                delay = min(self.retry_backoff * (2 ** (r.retries - 1)),
                            self.retry_backoff_cap)
                heapq.heappush(heap, (now + delay, next(seq), ARRIVAL, r))

        def strand_engine(e: InstanceEngine) -> List[Request]:
            """A dying engine's queued + preempted + running requests, with
            its scheduling state cleared (leftover heap events go stale via
            the existing running/tid/epoch checks)."""
            victims: List[Request] = list(e.waiting)
            for t in e.preempted.values():
                victims.extend(t.requests)
            if e.running is not None:
                victims.extend(e.running.requests)
            e.waiting.clear()
            e.preempted.clear()
            e.running = None
            e.pending_preempt = None
            return victims

        # load-oblivious policies (round-robin) skip snapshot building
        idle_loads = [InstanceLoad(instance_id=e.instance_id,
                                   capacity=e.capacity)
                      for e in engines]
        idle_hloads = [InstanceLoad(instance_id=h.instance_id,
                                    capacity=h.capacity)
                       for h in hybrids]
        with_pressure = self.policy.needs_decode_pressure and decodes
        # per-instance prefix-cache residency (None = sharing disabled);
        # exposed as `prefix_managers` for leak/invariant inspection.
        # Tiered mode swaps in TieredBlockManagers (eviction demotes through
        # host/disk instead of dropping) and extends coverage to the hybrid
        # pool — colocated instances share the same residency vocabulary.
        mgrs = None
        if self.tiered:
            mgrs = [TieredBlockManager(self.prefix_cache_blocks,
                                       host_blocks=self.host_cache_blocks,
                                       disk_blocks=self.disk_cache_blocks)
                    for _ in range(len(engines) + len(hybrids))]
            for hi, h in enumerate(hybrids):
                h.on_prefill_done = (
                    lambda r, t, m=mgrs[len(engines) + hi]:
                    m.commit(r.rid, r.prefix_hash or ()))
        elif self.prefix_cache_blocks > 0:
            mgrs = [PrefixBlockManager(self.prefix_cache_blocks)
                    for _ in engines]
        self.prefix_managers = mgrs
        bs = self.prefix_block
        n_promoted = 0

        # streams mid-KV-transfer, per destination: [count, ctx tokens].
        # They are invisible to the destination's snapshot until DECODE_JOIN
        # lands, so the planner must count them as queued there or two plans
        # within one transfer window would over-dump the same destination
        # past the knee (each stream's migration budget then strands it).
        in_flight: Dict[int, List[float]] = {}

        def migrate_from(src: DecodeSim, now: float) -> int:
            """Plan + enact cost-gated migrations of `src`'s queued decodes
            (KV handoff = a DECODE_JOIN event after the transfer delay)."""
            if not src.waiting:
                return 0
            loads = [d.snapshot_load() for d in decodes]
            for dst_id, (cnt, ctx) in in_flight.items():
                loads[dst_id].n_waiting += int(cnt)
                loads[dst_id].ctx_tokens += ctx
            cands = [DecodeCandidate(key=rid, context_tokens=j.context,
                                     remaining_tokens=j.remaining,
                                     deadline=j.request.decode_deadline,
                                     migrations=j.request.decode_migrations)
                     for rid, j in src.waiting.items()]
            plan = plan_decode_migrations(
                loads[src.instance_id], cands, loads, now,
                transfer_time=src.cost.kv_transfer_time,
                knee=self.migration_knee, max_migrations=self.max_migrations)
            for rid, dst_id, xfer in plan:
                if dst_id in down_dec or dst_id in drain_dec \
                        or dst_id in link_down:
                    continue             # planner is churn-blind: veto here
                job = src.pop_waiting(rid)
                job.request.decode_migrations += 1
                fl = in_flight.setdefault(dst_id, [0, 0.0])
                fl[0] += 1
                fl[1] += job.context
                heapq.heappush(heap, (now + xfer, next(seq), DECODE_JOIN,
                                      (decodes[dst_id], job)))
            return len(plan)

        if self.hybrid_decode_offload and decodes:
            def hybrid_offload(r: Request, t: float) -> None:
                nonlocal n_migrations
                live = [d for d in decodes
                        if d.instance_id not in down_dec
                        and d.instance_id not in drain_dec
                        and d.instance_id not in link_down] or decodes
                dec = min(live, key=lambda d: (d.backlog, d.instance_id))
                dec.join(r, t)
                if self.decode_migration:
                    n_migrations += migrate_from(dec, t)
            for h in hybrids:
                h.offload = hybrid_offload

        now = 0.0
        while heap:
            now, sq, kind, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                req: Request = payload
                # admission control needs a real backlog view even under
                # load-oblivious dispatch (round-robin)
                if self.policy.needs_loads or self.shed_policy != "off":
                    loads = [e.snapshot_load(req, now) for e in engines]
                else:
                    loads = idle_loads
                if with_pressure:
                    loads = [replace(
                        ld, decode_pressure=decodes[
                            i % len(decodes)].pressure(req, now))
                        for i, ld in enumerate(loads)]
                hits = None
                if mgrs is not None and not self.tiered:
                    # per-instance cached-prefix length of THIS prompt,
                    # capped so at least one token is always computed (the
                    # first output token needs a live forward pass)
                    keys = req.prefix_hash or ()
                    cap = max(req.num_tokens - 1, 0)
                    hits = [min(m.probe_len(keys) * bs, cap) for m in mgrs]
                    if self.policy.needs_prefix:
                        n = req.num_tokens
                        loads = [replace(
                            ld, prefix_hit=hits[i],
                            ttft_saved=max(
                                predictors[i].predict(n)
                                - predictors[i].predict(n - hits[i]), 0.0))
                            for i, ld in enumerate(loads)]
                if hybrids:
                    # colocated pool joins the dispatch decision after the
                    # prefill pool: same policy, same load vocabulary (queued
                    # prefill tokens + own decode pressure)
                    if self.policy.needs_loads:
                        hloads = [h.snapshot_load(req, now) for h in hybrids]
                    else:
                        hloads = idle_hloads
                    if self.policy.needs_decode_pressure:
                        hloads = [replace(ld, decode_pressure=hybrids[
                            i].pressure(req, now))
                            for i, ld in enumerate(hloads)]
                    loads = list(loads) + hloads
                colds = promos = None
                if self.tiered:
                    # three prices per instance: warm tokens are free,
                    # cold (host/disk) tokens cost a promotion copy and are
                    # counted only when that copy beats recompute, absent
                    # tokens cost full recompute. `ttft_saved` is already
                    # NET of the copy; `prefix_hit_cold`/`promote_time` are
                    # the observability split.
                    keys = req.prefix_hash or ()
                    cap = max(req.num_tokens - 1, 0)
                    n = req.num_tokens
                    hits, colds, promos, saveds = [], [], [], []
                    for i, m in enumerate(mgrs):
                        th = m.probe_tiers(keys)
                        warm = min(th.hbm_blocks * bs, cap)
                        host_t = min(th.host_blocks * bs, cap - warm)
                        disk_t = min(th.disk_blocks * bs,
                                     max(cap - warm - host_t, 0))
                        pred = predictors[i] if i < len(engines) \
                            else self.predictor
                        cost_i = self.instance_costs[i] \
                            if i < len(engines) else self.cost
                        saved = max(pred.predict(n)
                                    - pred.predict(n - warm), 0.0)
                        cold = host_t + disk_t
                        promote_s = 0.0
                        if cold > 0:
                            promote_s = cost_i.promote_time(host_t, disk_t)
                            gain = max(pred.predict(n - warm)
                                       - pred.predict(n - warm - cold), 0.0)
                            if gain > promote_s:
                                saved += gain - promote_s
                            else:            # recompute is cheaper: skip it
                                cold, promote_s = 0, 0.0
                        hits.append(warm)
                        colds.append(cold)
                        promos.append(promote_s)
                        saveds.append(saved)
                    if self.policy.needs_prefix:
                        loads = [replace(
                            ld, prefix_hit=hits[i] + colds[i],
                            ttft_saved=saveds[i],
                            prefix_hit_cold=colds[i],
                            promote_time=promos[i])
                            for i, ld in enumerate(loads)]
                excluded = down_p | drain_p
                if excluded:
                    # dispatch never routes to a known-down or draining
                    # instance. A HUNG one still receives work until the
                    # watchdog flags it (hangs are undetected by design —
                    # that is what makes them worse than crashes). NOTE: the
                    # per-instance arrays above (hits/colds/promos) stay
                    # indexed by instance_id, and every policy returns
                    # ld.instance_id, so filtering the load list is enough.
                    loads = [ld for ld in loads
                             if ld.instance_id not in excluded]
                    if not loads:
                        # whole pool down: park until the next rejoin, or
                        # lose the request if nothing ever comes back
                        t_up = next((t for t in prefill_up_times
                                     if t > now + 1e-12), None)
                        if t_up is None:
                            req.state = RequestState.DROPPED
                            n_lost += 1
                        else:
                            heapq.heappush(heap, (t_up, next(seq),
                                                  ARRIVAL, req))
                        continue
                if self.shed_policy != "off" and req.retries == 0:
                    # SLO-aware admission control: shed a doomed fresh
                    # arrival with an explicit rejection instead of letting
                    # it queue, miss, and poison the p99 tail. Retried
                    # (stranded-then-recovered) requests are never shed —
                    # the no-request-lost invariant outranks the tail.
                    best = min(predicted_ttft(req, ld, self.predictor)
                               for ld in loads)
                    if self.shed_policy == "doomed-only":
                        doomed = best > req.slo and \
                            all(ld.n_outstanding > 0 for ld in loads)
                    else:                                       # "budget"
                        doomed = best > self.shed_budget * req.slo
                    if doomed:
                        req.state = RequestState.DROPPED
                        req.shed = True
                        n_shed += 1
                        continue
                idx = self.policy.select(req, loads, now)
                if self.tiered:
                    m = mgrs[idx]
                    keys = req.prefix_hash or ()
                    cap = max(req.num_tokens - 1, 0)
                    warm = hits[idx]
                    if colds[idx] > 0:
                        # residency flips instantly; the copy's latency is
                        # priced by delaying the arrival (PROMOTE_DONE
                        # below), mirroring the runtime where the promotion
                        # ticket settles before the prefill resumes
                        for key, _b, _t in m.promote_begin(
                                keys,
                                max_blocks=(colds[idx] + bs - 1) // bs):
                            m.promote_commit(key)
                    # re-probe: promotion may have landed fewer blocks than
                    # planned (pool pressure) — pin what actually exists
                    hit = min(m.probe_len(keys) * bs, cap)
                    req.prefix_hit = hit
                    n_promoted += max(hit - warm, 0)
                    m.lock_prefix(req.rid, keys,
                                  max_blocks=(hit + bs - 1) // bs)
                    target = engines[idx] if idx < len(engines) \
                        else hybrids[idx - len(engines)]
                    if hit > warm and promos[idx] > 0:
                        # the promoted blocks stay pinned while the copy is
                        # in flight (lock_prefix above); the request itself
                        # is invisible to later load snapshots until it
                        # lands — same convention as mid-transfer decode
                        # streams
                        heapq.heappush(heap, (now + promos[idx], next(seq),
                                              PROMOTE_DONE, (target, req)))
                    else:
                        target.on_arrival(req, now)
                else:
                    if hits is not None and idx < len(engines):
                        # pin the hit until the dependent prefill completes
                        # — eviction must never pull KV out from under it
                        req.prefix_hit = hits[idx]
                        mgrs[idx].lock_prefix(
                            req.rid, req.prefix_hash or (),
                            max_blocks=(hits[idx] + bs - 1) // bs)
                    if idx < len(engines):
                        engines[idx].on_arrival(req, now)
                    else:
                        hybrids[idx - len(engines)].on_arrival(req, now)
            elif kind == DECODE_DONE:
                dec: DecodeSim = payload[0]
                if dec.on_decode_done(payload, now) and self.decode_migration:
                    # freed slots elsewhere may now clear a queued stream's
                    # cost gate; re-plan for THIS instance's remaining queue
                    n_migrations += migrate_from(dec, now)
            elif kind == DECODE_JOIN:
                dec, job = payload
                fl = in_flight[dec.instance_id]
                fl[0] -= 1
                fl[1] -= job.context
                if dec.instance_id in down_dec \
                        or dec.instance_id in link_down:
                    # the KV transfer failed mid-flight (dead destination or
                    # dropped kv_link): retry the handoff into a live
                    # instance, else full recovery (re-prefill from scratch)
                    alts = [d for d in decodes
                            if d.instance_id not in down_dec
                            and d.instance_id not in link_down
                            and d.instance_id != dec.instance_id]
                    if alts and self.recovery != "none":
                        alt = min(alts, key=lambda d: (d.backlog,
                                                       d.instance_id))
                        n_retries += 1
                        xfer = alt.cost.kv_transfer_time(job.context)
                        fl2 = in_flight.setdefault(alt.instance_id,
                                                   [0, 0.0])
                        fl2[0] += 1
                        fl2[1] += job.context
                        heapq.heappush(heap, (now + xfer, next(seq),
                                              DECODE_JOIN, (alt, job)))
                    else:
                        recover([job.request], now)
                else:
                    dec.migrate_in(job, now)
            elif kind == HYBRID_STEP:
                payload[0].on_step(payload, now)
            elif kind == PROMOTE_DONE:
                # the cold prefix finished copying up — the request enters
                # its instance now, so its TTFT includes the promotion
                target, r = payload
                if isinstance(target, InstanceEngine) \
                        and target.instance_id in down_p:
                    recover([r], now)   # destination died mid-promotion
                else:
                    target.on_arrival(r, now)
            elif kind == INSTANCE_DOWN:
                phase, ev = payload
                i = ev.instance
                if ev.target == "prefill":
                    if phase == "drain":
                        drain_p.add(i)
                    elif phase == "freeze":
                        frozen_p.add(i)
                    elif phase == "slow":
                        e = engines[i]
                        slowed[i] = (e.cost, e.capacity)
                        e.cost = _SlowedCost(e.cost, ev.factor)
                        e.capacity = e.capacity / ev.factor
                    else:                                   # kill
                        down_p.add(i)
                        drain_p.discard(i)
                        frozen_p.discard(i)
                        killed_seq[i] = next(seq)   # new incarnation
                        victims = strand_engine(engines[i])
                        if mgrs is not None:
                            # the instance's memory died with it — HBM
                            # prefix cache, host/disk staging tiers, and
                            # every arrival-time pin. Chains committed on
                            # OTHER instances survive, so re-dispatched
                            # requests can still resume from their caches.
                            mgrs[i] = TieredBlockManager(
                                self.prefix_cache_blocks,
                                host_blocks=self.host_cache_blocks,
                                disk_blocks=self.disk_cache_blocks) \
                                if self.tiered else \
                                PrefixBlockManager(self.prefix_cache_blocks)
                        recover(victims, now)
                else:
                    if phase == "drain":
                        drain_dec.add(i)
                    elif phase == "freeze":
                        decodes[i].freeze(now)
                    elif phase == "link":
                        link_down.add(i)
                    elif phase == "slow":
                        pass          # decode slowdown not modeled
                    else:                                   # kill
                        down_dec.add(i)
                        drain_dec.discard(i)
                        recover(decodes[i].strand(now), now)
            elif kind == INSTANCE_UP:
                phase, ev = payload
                i = ev.instance
                if phase == "unslow":
                    if i in slowed:
                        engines[i].cost, engines[i].capacity = slowed.pop(i)
                elif phase == "unlink":
                    link_down.discard(i)
                elif ev.target == "prefill":
                    down_p.discard(i)   # rejoins empty (cleared at kill)
                else:
                    down_dec.discard(i)
                    decodes[i].thaw(now)
            else:
                engine: InstanceEngine = payload[0]
                if engine.instance_id in frozen_p:
                    continue            # hung: no progress until the kill
                if sq < killed_seq.get(engine.instance_id, -1):
                    continue            # pushed by a dead incarnation
                for r in handle_event(kind, payload, now):
                    if mgrs is not None:
                        # completion: the prompt's KV now exists on this
                        # instance — cache it (best-effort under capacity)
                        # and drop the arrival-time pins
                        mgrs[engine.instance_id].commit(
                            r.rid, r.prefix_hash or ())
                    if decodes and r.output_tokens > 0:
                        if self.decode_affinity:
                            # paired handoff: prefill i -> decode i mod D
                            dec = decodes[engine.instance_id % len(decodes)]
                        else:
                            # join the decode instance holding the fewest
                            # streams (resident + queued)
                            dec = min(decodes, key=lambda d: (d.backlog,
                                                              d.instance_id))
                        no_join = down_dec | drain_dec | link_down
                        if dec.instance_id in no_join:
                            # affinity/least-backlog chose an unreachable
                            # decode: fall to the least-loaded live one, or
                            # full recovery when the decode pool is gone
                            live = [d for d in decodes
                                    if d.instance_id not in no_join]
                            if not live:
                                recover([r], now)
                                continue
                            dec = min(live, key=lambda d: (d.backlog,
                                                           d.instance_id))
                        dec.join(r, now)
                        if self.decode_migration:
                            n_migrations += migrate_from(dec, now)
                if self.fault_plan is not None \
                        and engine.running is None \
                        and engine.pending_preempt is None \
                        and (engine.waiting or engine.preempted):
                    # un-wedge a latent engine tail race that churn exposes:
                    # a cooperative preempt scheduled at the task's FINAL
                    # boundary ties with its completion; completion pops
                    # first, and its _round early-returns (pending_preempt
                    # still set); the now-stale PREEMPT_AT clears the flag
                    # but never re-rounds — idle engine, queued work, no
                    # future events. Fault-free traces always rescue it with
                    # a later arrival (committed baselines stay byte-equal
                    # behind the fault_plan gate); churn's backoff-delayed
                    # tail can leave it terminal, so kick the round here.
                    engine._round(now)

        return ClusterResult(
            requests=list(requests),
            blocking_times=[b for e in engines for b in e.blocking],
            rounds=sum(e.rounds for e in engines)
            + sum(h.steps for h in hybrids),
            preemptions=sum(e.preemptions for e in engines),
            makespan=now,
            dispatched=[e.n_dispatched for e in engines]
            + [h.n_dispatched for h in hybrids],
            decoded=sum(len(d.finished) for d in decodes)
            + sum(len(h.finished) for h in hybrids),
            decode_preemptions=sum(d.preemptions for d in decodes)
            + sum(h.preemptions for h in hybrids),
            migrations=n_migrations,
            prefix_hit_tokens=sum(r.prefix_hit for r in requests),
            prefix_evictions=sum(m.evictions for m in mgrs) if mgrs else 0,
            prefix_promoted_tokens=n_promoted,
            tier_demotions=sum(getattr(m, "demotions", 0)
                               for m in mgrs) if mgrs else 0,
            retries=n_retries,
            shed_requests=n_shed,
            lost_requests=n_lost,
        )


def simulate_cluster(system: str, requests: Sequence[Request], *,
                     model: str = "llama3-8b",
                     num_instances: int = 2,
                     dispatch: str = "round-robin",
                     decode_instances: int = 0,
                     hw=None, hardware=None, decode_hardware=None,
                     online_refit: bool = False,
                     decode_affinity: Optional[bool] = None,
                     decode_max_batch: int = 0,
                     decode_policy: str = "fcfs",
                     decode_preempt: Optional[bool] = None,
                     decode_migration: bool = False,
                     migration_knee: float = 0.85,
                     max_migrations: int = 1,
                     prefix_cache_blocks: int = 0,
                     prefix_block: int = 128,
                     host_cache_blocks: int = 0,
                     disk_cache_blocks: int = 0,
                     hybrid_instances: int = 0,
                     hybrid_token_budget: Optional[int] = None,
                     hybrid_chunk_tokens: Optional[int] = None,
                     hybrid_decode_offload: bool = False,
                     fault_plan: Optional[FaultPlan] = None,
                     recovery: str = "retry",
                     max_retries: int = 3,
                     retry_backoff: float = 0.05,
                     retry_backoff_cap: float = 2.0,
                     watchdog_s: float = 1.0,
                     shed_policy: str = "off",
                     shed_budget: float = 2.0,
                     spec_decode: bool = False,
                     draft_k: int = 4,
                     spec_accept: float = 0.0,
                     **overrides) -> ClusterResult:
    """Cluster counterpart of `repro.sim.policies.simulate` — same baseline
    presets, same fresh-copy semantics, plus instance count, dispatch,
    heterogeneous pool layout (`hardware` / `decode_hardware` accept
    HardwareSpecs or names like "a800"), decode scheduling
    (`decode_max_batch` / `decode_policy` / `decode_preempt` /
    `decode_migration`), prefix-cache sharing (`prefix_cache_blocks`
    per-instance residency capacity + the `prefix-affinity` dispatch;
    `host_cache_blocks` / `disk_cache_blocks` add demotion tiers and a
    promote-vs-recompute gate instead of dropping evictions), and
    colocated pools (`hybrid_instances` unified prefill+decode engines —
    pool layouts mix freely: `num_instances=0, hybrid_instances=4` is fully
    colocated, `num_instances=1, decode_instances=1, hybrid_instances=2`
    is a mixed pool at the same card count as 2P+2D disaggregation), and
    speculative decoding (`spec_decode` + `draft_k` + `spec_accept`: fluid
    multi-token advancement off the analytic accept surface the runtime's
    per-stream EMA converges to)."""
    import copy

    from repro.sim.costmodel import A800, MODEL_SPECS, MODEL_TP
    from repro.sim.policies import preset

    spec = replace(MODEL_SPECS[model], tp=MODEL_TP.get(model, 1))
    cost = PrefillCostModel(spec, resolve_hardware(hw) if hw else A800)
    sim = ClusterSim(cost, preset(system, **overrides),
                     num_instances=num_instances, dispatch=dispatch,
                     decode_instances=decode_instances,
                     hardware=hardware, decode_hardware=decode_hardware,
                     online_refit=online_refit,
                     decode_affinity=decode_affinity,
                     decode_max_batch=decode_max_batch,
                     decode_policy=decode_policy,
                     decode_preempt=decode_preempt,
                     decode_migration=decode_migration,
                     migration_knee=migration_knee,
                     max_migrations=max_migrations,
                     prefix_cache_blocks=prefix_cache_blocks,
                     prefix_block=prefix_block,
                     host_cache_blocks=host_cache_blocks,
                     disk_cache_blocks=disk_cache_blocks,
                     hybrid_instances=hybrid_instances,
                     hybrid_token_budget=hybrid_token_budget,
                     hybrid_chunk_tokens=hybrid_chunk_tokens,
                     hybrid_decode_offload=hybrid_decode_offload,
                     fault_plan=fault_plan,
                     recovery=recovery,
                     max_retries=max_retries,
                     retry_backoff=retry_backoff,
                     retry_backoff_cap=retry_backoff_cap,
                     watchdog_s=watchdog_s,
                     shed_policy=shed_policy,
                     shed_budget=shed_budget,
                     spec_decode=spec_decode,
                     draft_k=draft_k,
                     spec_accept=spec_accept)
    return sim.run([copy.copy(r) for r in requests])
