"""Baseline system presets for the simulator (paper §6.1 Baselines)."""
from __future__ import annotations

from typing import Sequence

from repro.core.request import Request
from repro.sim.costmodel import (MODEL_SPECS, MODEL_TP, A800, HardwareSpec,
                                 PrefillCostModel)
from repro.sim.simulator import PrefillSim, SimConfig, SimResult


def preset(name: str, **overrides) -> SimConfig:
    presets = {
        # DistServe default: FCFS, run-to-completion, no SLO awareness
        "distserve": SimConfig(policy="fcfs", granularity="whole",
                               preempt=False, enable_batching=False),
        # DistServe + Chunked Prefill + EDF (chunk-boundary preemption;
        # scheduling decision at every chunk boundary; vLLM-style greedy
        # token-budget batching up to the chunk size)
        "distserve-cp2k": SimConfig(policy="edf", granularity="chunk",
                                    chunk_tokens=2048, enable_batching=True,
                                    batching_mode="greedy", batch_budget=2048,
                                    check_overhead=200e-6),
        "distserve-cp8k": SimConfig(policy="edf", granularity="chunk",
                                    chunk_tokens=8192, enable_batching=True,
                                    batching_mode="greedy", batch_budget=8192,
                                    check_overhead=200e-6),
        # layer-level scheduling (Laser/Layered-Prefill style): preemption at
        # layer boundaries, scheduling check polled at every boundary
        "layer-level": SimConfig(policy="edf", granularity="layer",
                                 enable_batching=False,
                                 check_overhead=200e-6),
        # FlowPrefill: operator boundaries, event-driven (no polling cost),
        # S-EDF + SLO-aware batching
        "flowprefill": SimConfig(policy="s-edf", granularity="op",
                                 enable_batching=True, batch_budget=4096),
        # ablations
        "flowprefill-edf": SimConfig(policy="edf", granularity="op",
                                     enable_batching=True, batch_budget=4096),
        "flowprefill-dedf": SimConfig(policy="d-edf", granularity="op",
                                      enable_batching=True, batch_budget=4096),
        "flowprefill-nobatch": SimConfig(policy="s-edf", granularity="op",
                                         enable_batching=False),
    }
    cfg = presets[name]
    if overrides:
        from dataclasses import replace
        cfg = replace(cfg, **overrides)
    return cfg


def simulate(system: str, requests: Sequence[Request], model: str = "llama3-8b",
             hw: HardwareSpec = A800, **overrides) -> SimResult:
    spec = MODEL_SPECS[model]
    from dataclasses import replace as _r
    spec = _r(spec, tp=MODEL_TP.get(model, 1))
    cost = PrefillCostModel(spec, hw)
    sim = PrefillSim(cost, preset(system, **overrides))
    # simulate on fresh copies so sweeps don't share Request state
    import copy
    reqs = [copy.copy(r) for r in requests]
    return sim.run(reqs)
