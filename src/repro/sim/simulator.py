"""Discrete-event simulator of prefill instances (cluster-scale evaluation).

The simulator drives the SAME SchedulerCore as the real runtime — only the
executor is simulated. Each device is a serial processor executing operator
units whose durations come from the analytic cost model; preemption takes
effect at the next boundary of the configured granularity (op / layer / chunk /
whole), exactly like the cooperative protocol. Events are lazily invalidated
via task epochs, so the event count is O(actions), not O(operators).

The per-instance state machine lives in `InstanceEngine`, which pushes its
events into a caller-owned heap: `PrefillSim` runs ONE engine on a private
heap (the single-device study), while `repro.sim.cluster.ClusterSim` runs N
engines plus dispatch and a decode-phase model on one shared heap — both paths
execute identical engine code, so a 1-instance cluster reproduces `PrefillSim`
event-for-event.

Baseline systems are expressed as SimConfig presets (policies.py):
DistServe (FCFS), DistServe-CP2K/8K (chunk boundaries + EDF), layer-level
(layer boundaries + per-boundary polling cost), and FlowPrefill.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dispatch import InstanceLoad, competing_tokens
from repro.core.predictor import TTFTPredictor
from repro.core.request import Request, RequestState
from repro.core.scheduler import Action, SchedulerCore
from repro.sim.costmodel import PrefillCostModel

# event kinds (shared heap: (time, seq, kind, payload))
ARRIVAL, COMPLETION, PREEMPT_AT, DECODE_DONE, DECODE_JOIN = 0, 1, 2, 3, 4


@dataclass
class SimTask:
    requests: List[Request]
    tokens: int
    op_ends: np.ndarray                  # cumulative op end offsets (exec secs)
    boundary_ends: np.ndarray            # preemption boundaries (exec secs)
    exec_offset: float = 0.0             # completed execution seconds
    resume_time: float = 0.0             # sim time of last (re)start
    epoch: int = 0                       # invalidates stale events
    tid: int = field(default_factory=itertools.count().__next__)
    min_deadline: float = 0.0            # over members (fixed once built)
    preempted_tokens: float = 0.0        # remaining tokens frozen at preempt

    def __post_init__(self):
        self.min_deadline = min(r.deadline for r in self.requests)

    @property
    def head(self) -> Request:
        return self.requests[0]

    @property
    def total(self) -> float:
        return float(self.op_ends[-1])

    def position(self, now: float) -> float:
        return self.exec_offset + (now - self.resume_time)

    def remaining_fraction(self, now: float, running: bool) -> float:
        pos = self.position(now) if running else self.exec_offset
        return max(0.0, 1.0 - pos / max(self.total, 1e-12))

    def next_boundary(self, now: float) -> float:
        """Execution offset of the first boundary at/after `now`."""
        pos = self.position(now)
        i = int(np.searchsorted(self.boundary_ends, pos - 1e-12))
        i = min(i, len(self.boundary_ends) - 1)
        return float(self.boundary_ends[i])


@dataclass
class SimConfig:
    policy: str = "s-edf"
    granularity: str = "op"              # op | layer | chunk | whole
    chunk_tokens: int = 0                # >0: chunked prefill
    batch_budget: int = 4096
    enable_batching: bool = True
    batching_mode: str = "slo"           # "slo" (Alg. 1) | "greedy" (vLLM-like)
    preempt: bool = True
    check_overhead: float = 0.0          # per-boundary scheduling cost (layer-
                                         # level polling baselines)
    round_overhead: float = 100e-6       # per scheduling round
    submit_overhead: float = 8e-3        # per execution task (cache alloc,
                                         # runner setup) — amortized by batching


@dataclass
class SimResult:
    requests: List[Request]
    blocking_times: List[float]
    rounds: int
    preemptions: int
    makespan: float

    @property
    def attainment(self) -> float:
        met = sum(1 for r in self.requests if r.slo_met)
        return met / max(len(self.requests), 1)


class InstanceEngine:
    """One prefill instance's scheduling + execution state machine.

    Pushes COMPLETION / PREEMPT_AT events (tagged with itself) into the
    owner's heap; the owner pops events and routes them back via the
    ``on_*`` handlers. The owner also decides which engine receives each
    ARRIVAL (that is the cluster dispatch decision).
    """

    def __init__(self, cost: PrefillCostModel, cfg: SimConfig,
                 predictor: TTFTPredictor, heap: List, seq: Iterator[int],
                 instance_id: int = 0, capacity: float = 1.0):
        self.cost = cost
        self.cfg = cfg
        self.predictor = predictor
        self.heap = heap
        self.seq = seq
        self.instance_id = instance_id
        self.capacity = capacity        # peak prefill throughput (tokens/s);
                                        # 1.0 = uniform pool (capacity unused)
        # online predictor feedback: engines feed observed (tokens, latency)
        # into predictors that expose observe() (OnlineTTFTPredictor)
        self._observe = getattr(predictor, "observe", None)
        self.core = SchedulerCore(
            predictor=predictor, policy=cfg.policy,
            batch_budget=cfg.batch_budget,
            enable_batching=cfg.enable_batching,
            batching_mode=cfg.batching_mode)
        self.waiting: List[Request] = []
        self.preempted: Dict[int, SimTask] = {}      # tid -> task
        self.running: Optional[SimTask] = None
        self.pending_preempt: Optional[Tuple] = None
        self.blocking: List[float] = []
        self.rounds = 0
        self.preemptions = 0
        self.n_dispatched = 0

    # ---------------------------------------------------------------- load
    def outstanding_tokens(self, now: float) -> float:
        """Raw token-equivalent backlog (waiting + preempted + running)."""
        n = float(sum(r.num_tokens for r in self.waiting))
        for t in self.preempted.values():
            n += t.tokens * t.remaining_fraction(now, running=False)
        if self.running is not None:
            n += self.running.tokens * self.running.remaining_fraction(
                now, running=True)
        return n

    def snapshot_load(self, candidate: Request, now: float) -> InstanceLoad:
        """InstanceLoad snapshot relative to `candidate`, counting only
        competing work (repro.core.dispatch.competing_tokens): queued items
        filtered by deadline + feasibility; the running task included when its
        batch deadline is earlier (it finishes first — otherwise it yields
        within one boundary)."""
        # a waiting request's actual work is its suffix: the dispatched-on
        # prefix hit is never recomputed (prefix_hit = 0 without sharing)
        items = [(float(r.num_tokens - r.prefix_hit), r.deadline)
                 for r in self.waiting]
        items += [(t.preempted_tokens, t.min_deadline)
                  for t in self.preempted.values()]
        queued = competing_tokens(items, candidate, now, self.predictor.predict)
        running = 0.0
        if self.running is not None:
            t = self.running
            if t.min_deadline <= candidate.deadline:
                running = t.tokens * t.remaining_fraction(now, running=True)
        return InstanceLoad(
            instance_id=self.instance_id, queued_tokens=queued,
            running_tokens=running,
            n_outstanding=len(self.waiting) + len(self.preempted)
            + (self.running is not None),
            capacity=self.capacity)

    # --------------------------------------------------------------- build
    def _boundaries(self, op_ends: np.ndarray, tokens: int) -> np.ndarray:
        g = self.cfg.granularity
        m = self.cost.m
        n_ops = len(m.op_names)
        if g == "op":
            return op_ends
        if g == "layer":
            return op_ends[n_ops - 1::n_ops]
        if g == "chunk":
            per_chunk = m.num_layers * n_ops
            return op_ends[per_chunk - 1::per_chunk]
        if g == "whole":
            return op_ends[-1:]
        raise ValueError(g)

    def _make_task(self, batch: List[Request], now: float) -> SimTask:
        tokens = sum(r.num_tokens for r in batch)
        # prefix-cache hits (set at dispatch): the cached leading tokens'
        # chunks are skipped outright — the batch executes as one prefill
        # starting at the aggregate cached offset (suffix-only compute,
        # attention still reading the cached prefix KV). prefix=0 (no
        # sharing, the default) is the exact original path.
        prefix = min(sum(r.prefix_hit for r in batch), tokens - 1)
        op_ends = np.cumsum(self.cost.op_durations(tokens,
                                                   self.cfg.chunk_tokens,
                                                   prefix))
        op_ends = op_ends + self.cfg.submit_overhead
        boundaries = self._boundaries(op_ends, tokens)
        if self.cfg.check_overhead:
            # polling cost at every boundary (coupled scheduling baselines)
            op_ends = op_ends + self.cfg.check_overhead * (
                1 + np.searchsorted(boundaries, op_ends - 1e-12))
            boundaries = self._boundaries(op_ends, tokens)
        t = SimTask(requests=batch, tokens=tokens - prefix, op_ends=op_ends,
                    boundary_ends=boundaries, resume_time=now)
        for r in batch:
            r.ops_total = len(op_ends)
            r.ops_done = 0
            r.batch_tokens = tokens - prefix  # remaining-work basis (S-EDF)
        return t

    # ------------------------------------------------------------ execution
    def _schedule_completion(self, task: SimTask, t0: float) -> None:
        t_done = t0 + (task.total - task.exec_offset)
        heapq.heappush(self.heap, (t_done, next(self.seq), COMPLETION,
                                   (self, task, task.epoch)))

    def _enact(self, decision, t0: float) -> None:
        if decision.action == Action.SUBMIT:
            batch = decision.batch
            for r in batch:
                r.state = RequestState.RUNNING
            ids = {r.rid for r in batch}
            self.waiting[:] = [r for r in self.waiting if r.rid not in ids]
            task = self._make_task(batch, t0)
            self.running = task
            self._schedule_completion(task, t0)
        elif decision.action == Action.RESUME:
            rid = decision.target.rid
            tid = next(t for t, task_ in self.preempted.items()
                       if any(r.rid == rid for r in task_.requests))
            task = self.preempted.pop(tid)
            for r in task.requests:
                r.state = RequestState.RUNNING
            task.resume_time = t0
            task.epoch += 1
            self.running = task
            self._schedule_completion(task, t0)

    def _preempted_reps(self, t0: float) -> List[Request]:
        """Each preempted TASK is represented by its highest-priority member
        (Alg. 2's Q_all contains requests, not tasks — a batch must not
        starve because its head went infeasible). Unbatched tasks need no
        priority evaluation; batched ones share one vectorized pass
        (np.argmax takes the first maximum, exactly like max())."""
        tasks = list(self.preempted.values())
        multi = [t for t in tasks if len(t.requests) > 1]
        if not multi:
            return [t.requests[0] for t in tasks]
        members = [r for t in multi for r in t.requests]
        vec = self.core._priorities_vec(members, t0) \
            if len(members) >= 16 else None
        if vec is None:
            return [t.requests[0] if len(t.requests) == 1
                    else max(t.requests,
                             key=lambda r: self.core.priority(r, t0))
                    for t in tasks]
        pri = vec[0]
        best: Dict[int, Request] = {}
        i = 0
        for t in multi:
            k = len(t.requests)
            best[t.tid] = t.requests[int(np.argmax(pri[i:i + k]))]
            i += k
        return [best[t.tid] if t.tid in best else t.requests[0]
                for t in tasks]

    def _round(self, t0: float) -> None:
        cfg = self.cfg
        self.rounds += 1
        if self.pending_preempt is not None:
            return                          # round resumes after the ACK
        running = self.running
        running_head = running.head if running is not None else None
        reps = self._preempted_reps(t0)
        decision = self.core.schedule_round(
            t0 + cfg.round_overhead, self.waiting, reps, running_head)
        if decision.is_noop:
            return
        if decision.preempt is not None and running is not None:
            if not cfg.preempt:
                return                      # baseline without preemption
            # effective at the next boundary (cooperative)
            b = running.next_boundary(t0)
            t_eff = running.resume_time + (b - running.exec_offset)
            heapq.heappush(self.heap, (t_eff, next(self.seq), PREEMPT_AT,
                                       (self, running, running.epoch,
                                        decision)))
            self.pending_preempt = (running, running.epoch, decision)
            self.preemptions += 1
            self.blocking.append(t_eff - t0)
            return
        self._enact(decision, t0 + cfg.round_overhead)

    # -------------------------------------------------------- event handlers
    def on_arrival(self, req: Request, now: float) -> None:
        self.n_dispatched += 1
        self.waiting.append(req)
        self._round(now)

    def on_completion(self, payload, now: float) -> List[Request]:
        """Returns the completed requests ([] if the event was stale)."""
        _, task, epoch = payload
        if self.running is None or task.tid != self.running.tid or \
                epoch != task.epoch:
            return []                       # stale
        for r in task.requests:
            r.first_token_time = now
            r.state = RequestState.DONE
            r.ops_done = r.ops_total
        if self._observe is not None:
            # observed service time for the batch — the quantity the TTFT
            # predictor models (queueing is priced separately by dispatch)
            self._observe(task.tokens, task.total)
        self.running = None
        self._round(now)
        return list(task.requests)

    def on_preempt_at(self, payload, now: float) -> None:
        _, task, epoch, decision = payload
        if self.running is None or task.tid != self.running.tid or \
                epoch != task.epoch:
            self.pending_preempt = None
            return
        task.epoch += 1                 # cancels its completion event
        task.exec_offset = task.next_boundary(now)
        task.preempted_tokens = task.tokens * task.remaining_fraction(
            now, running=False)         # frozen until resume (load snapshots)
        # boundary index -> ops completed (for S-EDF remaining work)
        ops_done = int(np.searchsorted(
            task.op_ends, task.exec_offset - 1e-12) + 1)
        for r in task.requests:
            r.state = RequestState.PREEMPTED
            r.ops_done = ops_done
        self.preempted[task.tid] = task
        self.running = None
        self.pending_preempt = None
        self._enact(decision, now)


def handle_event(kind: int, payload, now: float) -> List[Request]:
    """Route one popped engine event (COMPLETION / PREEMPT_AT) to its engine.
    Returns requests whose prefill completed at this event."""
    engine: InstanceEngine = payload[0]
    if kind == COMPLETION:
        return engine.on_completion(payload, now)
    if kind == PREEMPT_AT:
        engine.on_preempt_at(payload, now)
        return []
    raise ValueError(kind)


def reset_requests(requests: Sequence[Request]) -> None:
    for r in requests:
        r.state = RequestState.WAITING
        r.first_token_time = None
        r.finish_time = None
        r.mean_tpot = None
        r.ops_done = 0
        r.ops_total = 0
        r.batch_tokens = r.num_tokens
        r.prefix_hit = 0
        r.decode_start = None
        r.decode_migrations = 0
        r.decode_preemptions = 0
        r.retries = 0
        r.shed = False


class PrefillSim:
    """Single-instance simulator (the paper's per-device study)."""

    def __init__(self, cost: PrefillCostModel, sim_cfg: SimConfig,
                 predictor: Optional[TTFTPredictor] = None):
        self.cost = cost
        self.cfg = sim_cfg
        chunk = sim_cfg.chunk_tokens
        self.predictor = predictor or TTFTPredictor.from_cost_model(
            lambda n: cost.prefill_time(n, chunk), max_tokens=32768)

    def run(self, requests: Sequence[Request]) -> SimResult:
        heap: List[Tuple[float, int, int, object]] = []
        seq = itertools.count()
        engine = InstanceEngine(self.cost, self.cfg, self.predictor,
                                heap, seq)
        reset_requests(requests)
        for r in requests:
            heapq.heappush(heap, (r.arrival, next(seq), ARRIVAL, r))

        now = 0.0
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                engine.on_arrival(payload, now)
            else:
                handle_event(kind, payload, now)

        return SimResult(requests=list(requests),
                         blocking_times=engine.blocking,
                         rounds=engine.rounds,
                         preemptions=engine.preemptions,
                         makespan=now)
