"""Discrete-event simulator of one prefill instance (cluster-scale evaluation).

The simulator drives the SAME SchedulerCore as the real runtime — only the
executor is simulated. The device is a serial processor executing operator
units whose durations come from the analytic cost model; preemption takes
effect at the next boundary of the configured granularity (op / layer / chunk /
whole), exactly like the cooperative protocol. Events are lazily invalidated
via task epochs, so the event count is O(actions), not O(operators).

Baseline systems are expressed as SimConfig presets (policies.py):
DistServe (FCFS), DistServe-CP2K/8K (chunk boundaries + EDF), layer-level
(layer boundaries + per-boundary polling cost), and FlowPrefill.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.predictor import TTFTPredictor
from repro.core.request import Request, RequestState
from repro.core.scheduler import Action, SchedulerCore
from repro.sim.costmodel import PrefillCostModel


@dataclass
class SimTask:
    requests: List[Request]
    tokens: int
    op_ends: np.ndarray                  # cumulative op end offsets (exec secs)
    boundary_ends: np.ndarray            # preemption boundaries (exec secs)
    exec_offset: float = 0.0             # completed execution seconds
    resume_time: float = 0.0             # sim time of last (re)start
    epoch: int = 0                       # invalidates stale events
    tid: int = field(default_factory=itertools.count().__next__)

    @property
    def head(self) -> Request:
        return self.requests[0]

    @property
    def total(self) -> float:
        return float(self.op_ends[-1])

    def position(self, now: float) -> float:
        return self.exec_offset + (now - self.resume_time)

    def next_boundary(self, now: float) -> float:
        """Execution offset of the first boundary at/after `now`."""
        pos = self.position(now)
        i = int(np.searchsorted(self.boundary_ends, pos - 1e-12))
        i = min(i, len(self.boundary_ends) - 1)
        return float(self.boundary_ends[i])


@dataclass
class SimConfig:
    policy: str = "s-edf"
    granularity: str = "op"              # op | layer | chunk | whole
    chunk_tokens: int = 0                # >0: chunked prefill
    batch_budget: int = 4096
    enable_batching: bool = True
    batching_mode: str = "slo"           # "slo" (Alg. 1) | "greedy" (vLLM-like)
    preempt: bool = True
    check_overhead: float = 0.0          # per-boundary scheduling cost (layer-
                                         # level polling baselines)
    round_overhead: float = 100e-6       # per scheduling round
    submit_overhead: float = 8e-3        # per execution task (cache alloc,
                                         # runner setup) — amortized by batching


@dataclass
class SimResult:
    requests: List[Request]
    blocking_times: List[float]
    rounds: int
    preemptions: int
    makespan: float

    @property
    def attainment(self) -> float:
        done = [r for r in self.requests if r.first_token_time is not None]
        met = sum(1 for r in self.requests if r.slo_met)
        return met / max(len(self.requests), 1)


class PrefillSim:
    ARRIVAL, COMPLETION, PREEMPT_AT = 0, 1, 2

    def __init__(self, cost: PrefillCostModel, sim_cfg: SimConfig,
                 predictor: Optional[TTFTPredictor] = None):
        self.cost = cost
        self.cfg = sim_cfg
        chunk = sim_cfg.chunk_tokens
        self.predictor = predictor or TTFTPredictor.from_cost_model(
            lambda n: cost.prefill_time(n, chunk), max_tokens=32768)
        self.core = SchedulerCore(
            predictor=self.predictor, policy=sim_cfg.policy,
            batch_budget=sim_cfg.batch_budget,
            enable_batching=sim_cfg.enable_batching,
            batching_mode=sim_cfg.batching_mode)

    # ------------------------------------------------------------------ build
    def _boundaries(self, op_ends: np.ndarray, tokens: int) -> np.ndarray:
        g = self.cfg.granularity
        m = self.cost.m
        n_ops = len(m.op_names)
        if g == "op":
            return op_ends
        if g == "layer":
            return op_ends[n_ops - 1::n_ops]
        if g == "chunk":
            per_chunk = m.num_layers * n_ops
            return op_ends[per_chunk - 1::per_chunk]
        if g == "whole":
            return op_ends[-1:]
        raise ValueError(g)

    def _make_task(self, batch: List[Request], now: float) -> SimTask:
        tokens = sum(r.num_tokens for r in batch)
        op_ends = np.cumsum(self.cost.op_durations(tokens,
                                                   self.cfg.chunk_tokens))
        op_ends = op_ends + self.cfg.submit_overhead
        boundaries = self._boundaries(op_ends, tokens)
        if self.cfg.check_overhead:
            # polling cost at every boundary (coupled scheduling baselines)
            op_ends = op_ends + self.cfg.check_overhead * (
                1 + np.searchsorted(boundaries, op_ends - 1e-12))
            boundaries = self._boundaries(op_ends, tokens)
        t = SimTask(requests=batch, tokens=tokens, op_ends=op_ends,
                    boundary_ends=boundaries, resume_time=now)
        for r in batch:
            r.ops_total = len(op_ends)
            r.ops_done = 0
            r.batch_tokens = tokens      # remaining-work basis for S-EDF
        return t

    # -------------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> SimResult:
        cfg = self.cfg
        heap: List[Tuple[float, int, int, object]] = []
        seq = itertools.count()
        for r in requests:
            r.state = RequestState.WAITING
            r.first_token_time = None
            r.ops_done = 0
            r.ops_total = 0
            r.batch_tokens = r.num_tokens
            heapq.heappush(heap, (r.arrival, next(seq), self.ARRIVAL, r))

        waiting: List[Request] = []
        preempted: Dict[int, SimTask] = {}     # head rid -> task
        running: Optional[SimTask] = None
        pending_preempt: Optional[Tuple[SimTask, int, object]] = None
        blocking: List[float] = []
        rounds = 0
        preemptions = 0
        now = 0.0

        def schedule_completion(task: SimTask, t0: float):
            t_done = t0 + (task.total - task.exec_offset)
            heapq.heappush(heap, (t_done, next(seq), self.COMPLETION,
                                  (task, task.epoch)))

        def enact(decision, t0: float):
            nonlocal running
            if decision.action == Action.SUBMIT:
                batch = decision.batch
                for r in batch:
                    r.state = RequestState.RUNNING
                ids = {r.rid for r in batch}
                waiting[:] = [r for r in waiting if r.rid not in ids]
                task = self._make_task(batch, t0)
                running = task
                schedule_completion(task, t0)
            elif decision.action == Action.RESUME:
                rid = decision.target.rid
                tid = next(t for t, task_ in preempted.items()
                           if any(r.rid == rid for r in task_.requests))
                task = preempted.pop(tid)
                for r in task.requests:
                    r.state = RequestState.RUNNING
                task.resume_time = t0
                task.epoch += 1
                running = task
                schedule_completion(task, t0)

        def do_round(t0: float):
            nonlocal running, pending_preempt, rounds, preemptions
            rounds += 1
            if pending_preempt is not None:
                return                          # round resumes after the ACK
            running_head = running.head if running is not None else None
            # each preempted TASK is represented by its highest-priority member
            # (Alg. 2's Q_all contains requests, not tasks — a batch must not
            # starve because its head went infeasible)
            reps = [max(t.requests, key=lambda r: self.core.priority(r, t0))
                    for t in preempted.values()]
            decision = self.core.schedule_round(
                t0 + cfg.round_overhead, waiting, reps, running_head)
            if decision.is_noop:
                return
            if decision.preempt is not None and running is not None:
                if not cfg.preempt:
                    return                      # baseline without preemption
                # effective at the next boundary (cooperative)
                b = running.next_boundary(t0)
                t_eff = running.resume_time + (b - running.exec_offset)
                heapq.heappush(heap, (t_eff, next(seq), self.PREEMPT_AT,
                                      (running, running.epoch, decision)))
                pending_preempt = (running, running.epoch, decision)
                preemptions += 1
                blocking.append(t_eff - t0)
                return
            enact(decision, t0 + cfg.round_overhead)

        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == self.ARRIVAL:
                r: Request = payload
                waiting.append(r)
                do_round(now)
            elif kind == self.COMPLETION:
                task, epoch = payload
                if running is None or task.tid != running.tid or \
                        epoch != task.epoch:
                    continue                    # stale
                for r in task.requests:
                    r.first_token_time = now
                    r.state = RequestState.DONE
                    r.ops_done = r.ops_total
                running = None
                do_round(now)
            elif kind == self.PREEMPT_AT:
                task, epoch, decision = payload
                if running is None or task.tid != running.tid or \
                        epoch != task.epoch:
                    pending_preempt = None
                    continue
                task.epoch += 1                 # cancels its completion event
                task.exec_offset = task.next_boundary(now)
                # boundary index -> ops completed (for S-EDF remaining work)
                ops_done = int(np.searchsorted(
                    task.op_ends, task.exec_offset - 1e-12) + 1)
                for r in task.requests:
                    r.state = RequestState.PREEMPTED
                    r.ops_done = ops_done
                preempted[task.tid] = task
                running = None
                pending_preempt = None
                enact(decision, now)

        makespan = now
        return SimResult(requests=list(requests), blocking_times=blocking,
                         rounds=rounds, preemptions=preemptions,
                         makespan=makespan)
