"""Chunked-causal flash attention Pallas TPU kernel.

This is the `attn` operator of FlowPrefill's operator-level preemption set —
the dominant compute in prefill. One call processes a *query chunk* (the unit
chunked prefill executes between preemption checks) against the full prior
KV prefix, so the kernel natively supports q_offset > 0 resumption.

TPU mapping:
  grid = (B, H, n_q_blocks, n_kv_blocks), kv innermost ("arbitrary" semantics,
  sequential accumulation); q/k/v tiles live in VMEM via BlockSpec; the online
  softmax state (m, l, acc) lives in VMEM scratch that persists across the kv
  grid dimension. GQA is handled by the k/v index_map (kv head = q head // Qg)
  — no KV repetition in HBM. block_q x block_k default 128x128 to align the
  MXU (128x128 systolic array) and keep the working set
  (3 * 128 * head_dim * 4B + scores) well under VMEM (~16 MB).

Scalar prefetch carries (q_offset, kv_len) so one compiled kernel serves every
chunk position — preemption/resume never recompiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(scalars_ref,            # SMEM: [q_offset, kv_len]
                  q_ref, k_ref, v_ref,    # VMEM tiles
                  o_ref,                  # VMEM out tile
                  m_ref, l_ref, acc_ref,  # VMEM scratch
                  *, causal: bool, local_window: int,
                  block_q: int, block_k: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    q_offset = scalars_ref[0]
    kv_len = scalars_ref[1]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip tests (work avoidance: causal upper triangle, beyond
    # kv_len, or entirely below the local window)
    q_lo = q_offset + iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    skip = k_lo >= kv_len
    if causal:
        skip |= k_lo > q_hi
    if local_window:
        skip |= k_hi <= q_lo - local_window

    @pl.when(jnp.logical_not(skip))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_lo + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if local_window:
            mask &= k_pos > q_pos - local_window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                             # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)                       # kill -1e30 rows exactly
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "local_window", "block_q", "block_k", "interpret"))
def flash_prefill_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, T, K, hd)
    v: jax.Array,            # (B, T, K, hd)
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | int | None = None,
    *,
    causal: bool = True,
    local_window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Chunked-causal flash attention. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    qg = H // K
    scale = 1.0 / math.sqrt(hd)
    kv_len = T if kv_len is None else kv_len

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(T, 128))

    # pad to block multiples
    sq_pad = -Sq % block_q
    t_pad = -T % block_k
    qt = jnp.moveaxis(q, 2, 1)                            # (B, H, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1)                            # (B, K, T, hd)
    vt = jnp.moveaxis(v, 2, 1)
    if sq_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if t_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    Sq_p, T_p = Sq + sq_pad, T + t_pad
    nq, nk = Sq_p // block_q, T_p // block_k

    scalars = jnp.array([q_offset, kv_len], dtype=jnp.int32)

    kernel = functools.partial(
        _flash_kernel, causal=causal, local_window=local_window,
        block_q=block_q, block_k=block_k, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik, *_: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, *_: (b, h // qg, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, *_: (b, h // qg, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik, *_: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),      # m
            pltpu.VMEM((block_q, 128), jnp.float32),      # l
            pltpu.VMEM((block_q, hd), jnp.float32),       # acc
        ],
    )

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    except AttributeError:  # older naming
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, hd), q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(scalars, qt, kt, vt)

    out = jnp.moveaxis(out, 1, 2)                         # (B, Sq_p, H, hd)
    return out[:, :Sq]
