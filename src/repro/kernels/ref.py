"""Pure-jnp oracles for the Pallas kernels. These are the ground truth the
kernel tests sweep against (shapes x dtypes, interpret mode)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def chunked_prefill_attention_ref(
    q: jax.Array,            # (B, Sq, H, hd) — current prefill chunk
    k: jax.Array,            # (B, T, K, hd)  — all KV up to chunk end
    v: jax.Array,            # (B, T, K, hd)
    *,
    q_offset: int | jax.Array = 0,   # absolute position of q[0]
    kv_len: Optional[int | jax.Array] = None,
    causal: bool = True,
    local_window: int = 0,
) -> jax.Array:
    """Naive reference: materializes the full score matrix in f32."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    qg = q.reshape(B, Sq, K, H // K, hd).astype(jnp.float32)
    scores = jnp.einsum("bskqh,btkh->bkqst", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    # q_offset / kv_len may be per-row (B,) arrays (ragged decode batches);
    # scalars broadcast over the leading batch axis exactly as before
    q_pos = jnp.arange(Sq)[:, None] \
        + jnp.asarray(q_offset).reshape(-1, 1, 1)          # (B or 1, Sq, 1)
    k_pos = jnp.arange(T)[None, None, :]                   # (1, 1, T)
    mask = jnp.ones((1, Sq, T), dtype=bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if local_window:
        mask = mask & (k_pos > q_pos - local_window)
    if kv_len is not None:
        mask = mask & (k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1))
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    # rows that are fully masked produce 0 (matches kernel's guarded division)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m_safe)
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bkqst,btkh->bskqh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # (B, H, hd) — single new token
    k: jax.Array,            # (B, T, K, hd)
    v: jax.Array,            # (B, T, K, hd)
    *,
    kv_len: int | jax.Array,           # number of valid cache entries
) -> jax.Array:
    out = chunked_prefill_attention_ref(
        q[:, None], k, v, q_offset=jnp.asarray(kv_len) - 1, causal=True)
    return out[:, 0]
