"""Flash-decode GQA attention Pallas TPU kernel.

serve_step hot-spot: one new query token per request attending to a long KV
cache. Decode is bandwidth-bound (the cache is streamed once), so the kernel:
  * parallelizes over (batch, kv_head) and streams KV blocks sequentially with
    online-softmax state in VMEM scratch;
  * processes all Qg = H/K query heads of a kv head together as the rows of a
    (Qg_pad x hd) tile so each streamed KV block is used by every query head
    that needs it (maximizes arithmetic intensity at fixed bandwidth);
  * Qg is padded to the f32 sublane minimum (8) — garbage rows are sliced off
    by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _decode_kernel(scalars_ref,           # SMEM: per-row [kv_len] * B
                   q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, block_k: int, scale: float):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = scalars_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_lo = ik * block_k

    @pl.when(k_lo < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (qg_pad, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
        k_pos = k_lo + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def _verify_kernel(scalars_ref,           # SMEM: per-row [kv_len] * B
                   q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref,
                   *, block_k: int, scale: float, qg: int, s_seq: int):
    """Multi-query variant of `_decode_kernel` for speculative verify: the
    (rows_pad, hd) query tile holds S consecutive positions x Qg heads, row
    r = s * qg + g scoring draft position s. Per-row causal bound: query s
    sits at absolute position kv_len + s, so its keys are k_pos <= kv_len + s
    — which both admits the earlier draft keys (written into the gathered
    view by the verify step) and excludes the later ones plus the padding
    tail past kv_len + S."""
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = scalars_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_lo = ik * block_k

    @pl.when(k_lo < kv_len + s_seq)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (rows_pad, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
        k_pos = k_lo + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        row = lax.broadcasted_iota(jnp.int32, s.shape, 0)
        q_pos = kv_len + row // qg                        # absolute query pos
        mask = k_pos <= q_pos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_verify_attention(
    q: jax.Array,            # (B, S, H, hd) — S = draft_k + 1 query positions
    k: jax.Array,            # (B, T, K, hd) with draft K/V already written
    v: jax.Array,            # (B, T, K, hd)
    kv_len: jax.Array,       # (B,) committed prefix length (query 0's pos)
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Speculative-verify flash decode: row b scores S consecutive query
    positions kv_len[b] .. kv_len[b] + S - 1 against its own KV view in one
    launch. Same grid/streaming structure as `flash_decode_attention` — the
    query tile just grows from Qg to S * Qg rows, so the drafted block rides
    the same KV bandwidth the single query already paid for. Returns
    (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0
    qg = H // K
    rows = S * qg
    rows_pad = max(8, -(-rows // 8) * 8)                   # f32 sublane minimum
    scale = 1.0 / (hd ** 0.5)

    block_k = min(block_k, max(T, 128))
    t_pad = -T % block_k
    qt = jnp.moveaxis(q.reshape(B, S, K, qg, hd), 2, 1)    # (B, K, S, qg, hd)
    qt = qt.reshape(B, K, rows, hd)
    if rows_pad != rows:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, rows_pad - rows), (0, 0)))
    kt = jnp.moveaxis(k, 2, 1)                             # (B, K, T, hd)
    vt = jnp.moveaxis(v, 2, 1)
    if t_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    nk = (T + t_pad) // block_k

    scalars = jnp.broadcast_to(
        jnp.asarray(kv_len, dtype=jnp.int32).reshape(-1), (B,))
    kernel = functools.partial(_verify_kernel, block_k=block_k, scale=scale,
                               qg=qg, s_seq=S)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1, rows_pad, hd), lambda b, kh, ik, *_: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kh, ik, *_: (b, kh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kh, ik, *_: (b, kh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows_pad, hd),
                               lambda b, kh, ik, *_: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows_pad, 128), jnp.float32),
            pltpu.VMEM((rows_pad, 128), jnp.float32),
            pltpu.VMEM((rows_pad, hd), jnp.float32),
        ],
    )

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, rows_pad, hd), q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(scalars, qt, kt, vt)

    out = out[:, :, :rows].reshape(B, K, S, qg, hd)
    return jnp.moveaxis(out, 1, 2).reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_attention(
    q: jax.Array,            # (B, H, hd) — one new token per request
    k: jax.Array,            # (B, T, K, hd)
    v: jax.Array,            # (B, T, K, hd)
    kv_len: jax.Array | int,
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode. Returns (B, H, hd).

    ``kv_len`` is either a scalar (every row attends to the same prefix — the
    original contract) or a (B,)-shaped array of per-row valid lengths, the
    ragged continuous-batching case: each resident stream masks its own KV
    tail, so one kernel launch serves the whole batch."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0
    qg = H // K
    qg_pad = max(8, qg)                                    # f32 sublane minimum
    scale = 1.0 / (hd ** 0.5)

    block_k = min(block_k, max(T, 128))
    t_pad = -T % block_k
    qt = q.reshape(B, K, qg, hd)
    if qg_pad != qg:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, qg_pad - qg), (0, 0)))
    kt = jnp.moveaxis(k, 2, 1)                            # (B, K, T, hd)
    vt = jnp.moveaxis(v, 2, 1)
    if t_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    nk = (T + t_pad) // block_k

    # one kv_len per batch row (a scalar broadcasts to every row)
    scalars = jnp.broadcast_to(
        jnp.asarray(kv_len, dtype=jnp.int32).reshape(-1), (B,))
    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qg_pad, hd), lambda b, kh, ik, *_: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kh, ik, *_: (b, kh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kh, ik, *_: (b, kh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qg_pad, hd),
                               lambda b, kh, ik, *_: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qg_pad, 128), jnp.float32),
            pltpu.VMEM((qg_pad, 128), jnp.float32),
            pltpu.VMEM((qg_pad, hd), jnp.float32),
        ],
    )

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, qg_pad, hd), q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(scalars, qt, kt, vt)

    return out[:, :, :qg].reshape(B, H, hd)
