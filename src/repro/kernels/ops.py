"""Jit'd dispatch wrappers for the Pallas kernels.

`impl` selects the execution path:
  "pallas"            — the TPU kernel (real hardware)
  "pallas_interpret"  — same kernel body, interpreted on CPU (tests)
  "xla"               — blocked pure-JAX flash (dry-run lowering path)
  "ref"               — naive oracle (small shapes only)
On this container (CPU) the default is interpret for small shapes and xla
otherwise; on a TPU runtime the default is the kernel.
"""
from __future__ import annotations

import jax

from repro.kernels import ref as ref_mod
from repro.kernels.decode_attn import flash_decode_attention, flash_verify_attention
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.models.layers import blocked_attention, naive_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_impl() -> str:
    return "pallas" if _on_tpu() else "xla"


def prefill_attention(q, k, v, *, q_offset=0, kv_len=None, causal=True,
                      local_window=0, impl: str | None = None,
                      block_q=128, block_k=128):
    """q: (B,Sq,H,hd); k/v: (B,T,K,hd) -> (B,Sq,H,hd)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return flash_prefill_attention(
            q, k, v, q_offset, kv_len, causal=causal, local_window=local_window,
            block_q=block_q, block_k=block_k)
    if impl == "pallas_interpret":
        return flash_prefill_attention(
            q, k, v, q_offset, kv_len, causal=causal, local_window=local_window,
            block_q=block_q, block_k=block_k, interpret=True)
    if impl == "xla":
        return blocked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 local_window=local_window, kv_len=kv_len,
                                 block=min(1024, max(k.shape[1], 1)))
    if impl == "ref":
        return ref_mod.chunked_prefill_attention_ref(
            q, k, v, q_offset=q_offset, kv_len=kv_len, causal=causal,
            local_window=local_window)
    raise ValueError(f"unknown impl {impl!r}")


def decode_attention(q, k, v, kv_len, *, impl: str | None = None, block_k=512):
    """q: (B,H,hd); k/v: (B,T,K,hd) -> (B,H,hd)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return flash_decode_attention(q, k, v, kv_len, block_k=block_k)
    if impl == "pallas_interpret":
        return flash_decode_attention(q, k, v, kv_len, block_k=block_k,
                                      interpret=True)
    if impl == "xla":
        out = blocked_attention(q[:, None], k, v, causal=False, kv_len=kv_len,
                                block=min(1024, max(k.shape[1], 1)))
        return out[:, 0]
    if impl == "ref":
        return ref_mod.decode_attention_ref(q, k, v, kv_len=kv_len)
    raise ValueError(f"unknown impl {impl!r}")


def verify_attention(q, k, v, kv_len, *, impl: str | None = None, block_k=512):
    """Speculative-verify attention: q (B,S,H,hd) holds S consecutive query
    positions kv_len[b]..kv_len[b]+S-1 per row; k/v (B,T,K,hd) already carry
    the draft K/V at those positions. Per-row causal masking -> (B,S,H,hd).

    The "xla" path routes through the naive reference rather than
    `blocked_attention`: the blocked flash mask lacks the per-row
    q_offset/kv_len broadcast the verify step needs, while
    `naive_attention` supports (B,)-shaped offsets natively and S is tiny
    (draft_k + 1), so the quadratic cost is irrelevant.
    """
    impl = impl or default_impl()
    if impl == "pallas":
        return flash_verify_attention(q, k, v, kv_len, block_k=block_k)
    if impl == "pallas_interpret":
        return flash_verify_attention(q, k, v, kv_len, block_k=block_k,
                                      interpret=True)
    if impl in ("xla", "ref"):
        return naive_attention(q, k, v, causal=True, q_offset=kv_len)
    raise ValueError(f"unknown impl {impl!r}")
