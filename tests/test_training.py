"""Training substrate tests: loss goes down, checkpoint restart equivalence,
elastic re-mesh restore, straggler watchdog, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_tiny_config
from repro.models import init_params
from repro.training import checkpoint as ckpt
from repro.training.compression import (ErrorFeedbackCompressor,
                                        dequantize_int8, quantize_int8)
from repro.training.data import DataConfig, data_iterator, make_batch
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train import LoopConfig, make_train_step, train_loop

CFG = get_tiny_config("llama3_2_1b")
OPT = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40, weight_decay=0.0)
DATA = DataConfig(seq_len=32, global_batch=4, vocab_size=CFG.vocab_size, seed=0)


def setup(tmpdir):
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt_state = init_state(params)
    step = jax.jit(make_train_step(CFG, OPT, remat="none"))
    return params, opt_state, step


def test_loss_decreases(tmp_path):
    params, opt_state, step = setup(tmp_path)
    it = data_iterator(DATA)
    first = last = None
    for i in range(20):
        params, opt_state, m = step(params, opt_state, next(it))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_equivalence(tmp_path):
    """Crash/restart must reproduce the uninterrupted run bit-for-bit."""
    d = str(tmp_path / "ck")
    params, opt_state, step = setup(tmp_path)
    loop = LoopConfig(total_steps=12, checkpoint_every=6, checkpoint_dir=d,
                      log_every=100)
    p_full, s_full, _ = train_loop(
        CFG, params, opt_state, step, data_iterator(DATA), loop,
        log=lambda *_: None)

    # "crash" after step 6: restore from the step-6 checkpoint and continue
    params2, opt_state2, _ = setup(tmp_path)
    last = ckpt.latest_step(d)
    assert last == 12
    mid = ckpt.restore(d, 6, {"params": params2, "opt_state": opt_state2})
    p_res, s_res, _ = train_loop(
        CFG, mid["params"], mid["opt_state"], step,
        data_iterator(DATA, start_step=6), loop, start_step=6,
        log=lambda *_: None)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_keep(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.arange(10), "nested": {"y": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.list_steps(d) == [4, 5]
    back = ckpt.restore(d, 5, tree)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.arange(10))


def test_elastic_remesh_restore(tmp_path):
    """Save unsharded, restore onto an explicit sharding (1-device mesh here;
    the dry-run exercises the 512-device path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = ckpt.restore(d, 1, tree, shardings=sh)
    assert back["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.arange(16.0).reshape(4, 4))


def test_data_determinism_and_host_sharding():
    b1 = make_batch(DATA, step=7)
    b2 = make_batch(DATA, step=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # two-host split covers the same global batch
    h0 = make_batch(DataConfig(**{**DATA.__dict__, "num_hosts": 2,
                                  "host_id": 0}), step=7)
    h1 = make_batch(DataConfig(**{**DATA.__dict__, "num_hosts": 2,
                                  "host_id": 1}), step=7)
    full = np.asarray(b1["tokens"])
    np.testing.assert_array_equal(np.asarray(h0["tokens"]), full[:2])
    np.testing.assert_array_equal(np.asarray(h1["tokens"]), full[2:])


def test_straggler_watchdog(tmp_path):
    import time as _time
    params, opt_state, step = setup(tmp_path)
    seen = []

    def slow_step(p, s, b):
        out = step(p, s, b)
        if len(seen_steps) == 8:            # one artificially slow step
            _time.sleep(0.5)
        seen_steps.append(1)
        return out

    seen_steps = []
    loop = LoopConfig(total_steps=12, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "ck"), log_every=100,
                      watchdog_factor=3.0,
                      on_straggler=lambda st, dt, med: seen.append(st))
    _, _, info = train_loop(CFG, params, opt_state, slow_step,
                            data_iterator(DATA), loop, log=lambda *_: None)
    assert info["stragglers"] >= 1
    assert seen


def test_int8_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = quantize_int8(x, block=128)
    back = dequantize_int8(q, s, x.shape, x.size)
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_compressed_training_still_converges(tmp_path):
    params, opt_state, _ = setup(tmp_path)
    comp = ErrorFeedbackCompressor(block=128)
    grads_like = params
    residual = comp.init(grads_like)
    state = {"residual": residual}

    base_step = make_train_step(CFG, OPT, remat="none")

    def compressed_step(p, s, batch):
        # recompute grads with compression inline (purely for the test loop)
        from repro.training.train import loss_fn
        loss, grads = jax.value_and_grad(
            lambda q: loss_fn(q, CFG, batch, remat="none"))(p)
        cg, state["residual"] = comp.transform(grads, state["residual"])
        from repro.training.optimizer import apply_updates
        p2, s2, m = apply_updates(OPT, p, cg, s)
        return p2, s2, dict(m, loss=loss)

    it = data_iterator(DATA)
    first = last = None
    for i in range(15):
        params, opt_state, m = compressed_step(params, opt_state, next(it))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.1, (first, last)
