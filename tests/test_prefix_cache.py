"""Prefix-sharing paged KV: refcount/trie/LRU invariants (hypothesis
properties), copy-on-divergence, the leak-free lifecycle, bit-identity of
the sharing-disabled default, suffix-only cost pricing, the prefix-affinity
dispatch signal, and the real-runtime cached-prefill speedup (the fig22
acceptance, asserted here too)."""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.prefixcache import (PrefixBlockManager, block_keys,
                                    chain_extend)
from repro.serving.kvcache import PagedKVCache

# NOTE: the hypothesis PROPERTY tests for the refcounted sharing invariants
# (free-list conservation under share/free interleavings, eviction never
# dropping pinned blocks) live in tests/test_property.py, which importorskips
# hypothesis module-wide; this module's tests are deterministic.


# --- hash chains -------------------------------------------------------------

def test_block_keys_prefix_property():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, 300)
    b = a.copy()
    b[290] += 1                       # diverge inside the last partial block
    assert block_keys(a, 128) == block_keys(b, 128)       # 2 full blocks
    c = a.copy()
    c[100] += 1                       # diverge inside block 0
    ka, kc = block_keys(a, 128), block_keys(c, 128)
    assert ka[0] != kc[0]
    # chain property: a later divergence changes every subsequent key
    d = a.copy()
    d[200] += 1                       # diverge inside block 1
    kd = block_keys(d, 128)
    assert kd[0] == ka[0] and kd[1] != ka[1]
    assert len(block_keys(a, 128)) == 2                   # partial tail: none


def test_chain_extend_deterministic_and_salted():
    base = chain_extend((), range(3), salt=7)
    assert base == chain_extend((), range(3), salt=7)
    assert base != chain_extend((), range(3), salt=8)
    ext = chain_extend(base, range(2), salt=99)
    assert ext[:3] == base


# --- PrefixBlockManager (deterministic; hypothesis properties in
# --- test_property.py) -------------------------------------------------------

CHAINS = [chain_extend((), range(6), salt=s) for s in range(4)]


def test_manager_trie_insert_probe_roundtrip():
    mgr = PrefixBlockManager(32)
    keys = CHAINS[0]
    mgr.acquire(1, (), 6)
    mgr.register(1, keys)
    blocks = mgr.blocks_of(1)
    assert mgr.probe(keys) == blocks                  # full-chain roundtrip
    assert mgr.probe(keys[:3]) == blocks[:3]          # any prefix
    assert mgr.probe(CHAINS[1]) == []                 # diverged chain: miss
    mixed = keys[:2] + CHAINS[1][2:]
    assert mgr.probe(mixed) == blocks[:2]             # stops at divergence
    mgr.release(1)
    assert mgr.probe(keys) == blocks                  # cached blocks still hit
    # a re-acquire pins the cached chain (hit) instead of fresh blocks
    hit = mgr.acquire(2, keys, 6)
    assert hit == 6 and mgr.blocks_of(2) == blocks
    mgr.check()


def test_manager_diverged_suffixes_share_no_fresh_blocks():
    """Two prompts sharing 2 blocks then diverging: the shared prefix is
    the SAME blocks, the diverged suffixes are disjoint."""
    mgr = PrefixBlockManager(32)
    a = chain_extend((), range(4), salt=1)
    b = chain_extend(a[:2], range(2), salt=2)         # diverges after 2
    mgr.acquire(1, a, 4)
    mgr.register(1, a)
    hit = mgr.acquire(2, b, 4)
    assert hit == 2
    ba, bb = mgr.blocks_of(1), mgr.blocks_of(2)
    assert ba[:2] == bb[:2]
    assert not set(ba[2:]) & set(bb[2:]), "diverged suffixes share a block"
    mgr.register(2, b)
    mgr.release(1)
    mgr.release(2)
    mgr.check()


def test_manager_commit_realigns_around_surviving_orphans():
    """A chain whose parent block was LRU-evicted while a child key stayed
    registered (the orphan case): a later commit of the same chain must
    register each key with ITS OWN block — a skipped middle key must not
    shift later keys onto the wrong block, and the re-knit chain probes at
    full length."""
    mgr = PrefixBlockManager(5)
    keys = CHAINS[0][:3]
    mgr.acquire(1, (), 3)
    mgr.register(1, keys)
    b1 = mgr.blocks_of(1)[1]
    mgr.release(1)                                 # all 3 cached, LRU order
    mgr._lru.move_to_end(b1)                       # make k1's block MRU
    # pressure: 2 free + 2 evictions (k0's and k2's blocks); k1's survives
    mgr.acquire(2, (), 4)
    assert mgr.probe(keys) == []                   # k0 gone: chain broken
    assert mgr._trie.get(keys[1]) == b1            # ...but k1 is an orphan
    mgr.commit(2, ())                              # free the pressure blocks
    # a new request re-runs the chain: lock misses, commit re-knits it
    mgr.lock_prefix(3, keys)
    added = mgr.commit(3, keys)
    assert added == 2                              # k0 and k2 only
    assert mgr._trie[keys[1]] == b1                # orphan kept, not shifted
    assert mgr.probe_len(keys) == 3                # contiguous again
    mgr.check()


def test_manager_make_private_cow_semantics():
    mgr = PrefixBlockManager(16)
    keys = CHAINS[0][:3]
    mgr.acquire(1, (), 3)
    mgr.register(1, keys)
    mgr.acquire(2, keys, 3)                           # full hit: shared
    shared = mgr.blocks_of(1)
    # seq 2 writes into shared block 1 -> gets a private copy
    nb, copied = mgr.make_private(2, 1)
    assert copied and nb not in shared
    assert mgr.blocks_of(1) == shared                 # owner 1 untouched
    # seq 1 (exclusive after 2's copy... block still shared? no: refcount
    # fell back to 1) writing into ITS registered block just unregisters it
    nb2, copied2 = mgr.make_private(1, 1)
    assert not copied2 and nb2 == shared[1]
    assert mgr.probe(keys) == shared[:1]              # chain truncated
    mgr.release(1)
    mgr.release(2)
    mgr.check()


# --- PagedKVCache share mode -------------------------------------------------

def _pool(**kw):
    return PagedKVCache(num_layers=2, num_blocks=16, block_size=4,
                        num_kv_heads=2, head_dim=4, **kw)


def _kv(T, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((2, T, 2, 4)), jnp.float32)
    return k, k + 1000


def test_pool_disabled_is_bit_identical_to_original_allocator():
    """prefix_share=False (the default) must keep the original LIFO free
    list and eager free byte-for-byte."""
    cache = _pool()
    t1 = cache.allocate(0, 10)                    # 3 blocks
    assert t1.blocks == [15, 14, 13]              # LIFO pops from the tail
    t2 = cache.allocate(1, 4)
    assert t2.blocks == [12]
    cache.free(0)
    assert cache.free_blocks == 15
    t3 = cache.allocate(2, 5)
    assert t3.blocks == [13, 14]                  # freed blocks, same order
    assert cache.cached_blocks == 0
    assert cache.probe(block_keys(np.arange(8), 4)) == 0


def test_pool_shared_prefix_data_roundtrip():
    """A second prompt with the same leading tokens reads the FIRST
    prompt's cached KV through its own table — no recompute, no copy."""
    cache = _pool(prefix_share=True)
    toks = np.arange(10)
    keys = block_keys(toks, 4)
    t1 = cache.allocate(0, 10, keys=keys)
    assert t1.prefix_blocks == 0 and t1.length == 0
    k, v = _kv(10)
    cache.write_prompt(0, k, v)
    cache.insert(0, keys)
    cache.free(0)
    assert cache.cached_blocks == 2               # 2 full blocks cached
    assert cache.free_blocks + cache.cached_blocks == 16

    # same 8-token prefix, longer prompt
    toks2 = np.concatenate([toks[:8], np.arange(100, 106)])
    keys2 = block_keys(toks2, 4)
    assert cache.probe(keys2) == 8
    t2 = cache.allocate(1, 14, keys=keys2)
    assert t2.prefix_blocks == 2 and t2.length == 8
    kg, vg, _ = cache.gather(1)
    np.testing.assert_array_equal(np.asarray(kg[:, :8]), np.asarray(k[:, :8]))
    np.testing.assert_array_equal(np.asarray(vg[:, :8]), np.asarray(v[:, :8]))
    # suffix write starts past the hit and never touches shared blocks
    k2, v2 = _kv(6, seed=1)
    cache.write_prompt(1, k2, v2, start=8)
    kg, _, _ = cache.gather(1)
    np.testing.assert_array_equal(np.asarray(kg[:, 8:14]), np.asarray(k2))
    cache.insert(1, keys2)
    cache.free(1)
    free, live, cached, total = cache.accounting()
    assert free + live + cached == total and live == 0


def test_pool_copy_on_divergence_preserves_sharers_data():
    cache = _pool(prefix_share=True)
    toks = np.arange(8)
    keys = block_keys(toks, 4)
    cache.allocate(0, 8, keys=keys)
    k, v = _kv(8)
    cache.write_prompt(0, k, v)
    cache.insert(0, keys)
    t1 = cache.allocate(1, 8, keys=keys)          # full 8-token hit, shared
    assert t1.prefix_blocks == 2
    # seq 1 diverges: writes into position 5 (inside shared block 1)
    import jax.numpy as jnp
    cache.write(1, 5, jnp.full((2, 2, 4), 7.0), jnp.full((2, 2, 4), 9.0))
    kg1, _, _ = cache.gather(1)
    np.testing.assert_array_equal(np.asarray(kg1[:, 5]), np.full((2, 2, 4), 7))
    # seq 0's data is untouched (COW gave seq 1 a private copy)
    kg0, _, _ = cache.gather(0)
    np.testing.assert_array_equal(np.asarray(kg0[:, :8]), np.asarray(k))
    # ...and the copied block carried the rest of its content over
    np.testing.assert_array_equal(np.asarray(kg1[:, 4]), np.asarray(k[:, 4]))
    assert cache.table(0).blocks[1] != cache.table(1).blocks[1]
    cache.free(0)
    cache.free(1)
    free, live, cached, total = cache.accounting()
    assert free + live + cached == total and live == 0


def test_pool_lru_eviction_under_pressure_spares_pins():
    cache = PagedKVCache(num_layers=1, num_blocks=8, block_size=4,
                         num_kv_heads=1, head_dim=2, prefix_share=True)
    chains = [chain_extend((), range(2), salt=s) for s in range(4)]
    for s in range(3):                            # fill 6 of 8 blocks, cache
        cache.allocate(s, 8, keys=chains[s])
        cache.insert(s, chains[s])
        cache.free(s)
    assert cache.cached_blocks == 6
    pinned = cache.allocate(10, 8, keys=chains[0])    # re-pin chain 0
    assert pinned.prefix_blocks == 2
    # a cold 16-token prompt needs 4 fresh blocks: 2 free + 2 evicted from
    # the LRU end (chain 1 — chain 0 is pinned and so not evictable)
    cache.allocate(11, 16, keys=chain_extend((), range(4), salt=9))
    assert cache.probe(chains[0]) == 8                # pinned chain survives
    assert cache.probe(chains[1]) == 0                # LRU victim evicted
    assert cache.probe(chains[2]) == 8                # MRU survivor intact
    cache.free(10)
    cache.free(11)
    free, live, cached, total = cache.accounting()
    assert free + live + cached == total and live == 0


def test_pool_extend_grows_geometrically_with_cap():
    cache = PagedKVCache(num_layers=1, num_blocks=4, block_size=4,
                         num_kv_heads=1, head_dim=2, max_blocks=16)
    cache.allocate(0, 16)                         # exhausts the pool
    assert cache.free_blocks == 0
    cache.extend(0, 17)                           # 5th block: grows, no raise
    assert cache.num_blocks == 8                  # doubled, not +1
    cache.extend(0, 64)                           # 16 blocks: up to the cap
    assert cache.num_blocks == 16
    assert cache.k_pool.shape[1] == 16
    with pytest.raises(MemoryError):
        cache.extend(0, 65)                       # past the explicit cap
    # share mode: eviction of cached blocks comes before growth
    c2 = PagedKVCache(num_layers=1, num_blocks=4, block_size=4,
                      num_kv_heads=1, head_dim=2, prefix_share=True)
    keys = chain_extend((), range(2), salt=0)
    c2.allocate(0, 8, keys=keys)
    c2.insert(0, keys)
    c2.free(0)
    c2.allocate(1, 8)                             # takes the 2 free blocks
    c2.extend(1, 16)                              # 2 more: evicts the cached
    assert c2.num_blocks == 4 and c2.cached_blocks == 0


# --- suffix-only cost pricing ------------------------------------------------

def test_costmodel_prefix_pricing():
    from repro.sim.costmodel import A800, LLAMA3_8B, PrefillCostModel
    cm = PrefillCostModel(LLAMA3_8B, A800)
    for tokens, chunk in [(4096, 512), (4096, 0), (1000, 512), (1, 0)]:
        # prefix=0 is the exact original path
        np.testing.assert_array_equal(cm.op_durations(tokens, chunk),
                                      cm.op_durations(tokens, chunk, 0))
        for prefix in (0, 256, 1024, tokens - 1, tokens):
            # vectorized == scalar reference, bit-identical
            np.testing.assert_array_equal(
                cm.op_durations(tokens, chunk, prefix),
                cm.op_durations_scalar(tokens, chunk, prefix))
    # a hit strictly cheapens the prefill, but attention still pays for
    # reading the cached prefix: pricier than a standalone suffix prefill
    full = cm.prefill_time(4096, 512)
    hit = cm.prefill_time(4096, 512, prefix=2048)
    assert hit < full
    assert hit > cm.prefill_time(2048, 512)
    # fully-cached clamps to one live token
    assert cm.prefill_time(4096, 512, prefix=4096) == \
        cm.prefill_time(4096, 512, prefix=4095)


# --- dispatch ----------------------------------------------------------------

def test_prefix_affinity_dispatch_scoring():
    from repro.core.dispatch import (InstanceLoad, PrefixAffinityDispatch,
                                     make_dispatch)
    from repro.core.request import Request
    pol = make_dispatch("prefix-affinity")
    assert isinstance(pol, PrefixAffinityDispatch)
    assert pol.needs_prefix and pol.needs_decode_pressure
    req = Request(num_tokens=1000, slo=1.0)
    # affinity wins when queues are equal
    loads = [InstanceLoad(instance_id=0, queued_tokens=500.0),
             InstanceLoad(instance_id=1, queued_tokens=500.0,
                          prefix_hit=900, ttft_saved=100.0)]
    assert pol.select(req, loads, 0.0) == 1
    # ...but a big enough backlog on the prefix holder deflects (the
    # affinity-vs-load tension): saving 100s never justifies 10000 tokens
    # of extra drain at capacity 1
    loads = [InstanceLoad(instance_id=0, queued_tokens=500.0),
             InstanceLoad(instance_id=1, queued_tokens=20500.0,
                          prefix_hit=900, ttft_saved=100.0)]
    assert pol.select(req, loads, 0.0) == 0
    # zero hits everywhere == capacity-weighted
    loads = [InstanceLoad(instance_id=0, queued_tokens=800.0),
             InstanceLoad(instance_id=1, queued_tokens=500.0)]
    assert pol.select(req, loads, 0.0) == 1


# --- traces ------------------------------------------------------------------

def test_shared_trace_respects_max_len():
    """max_len binds the total prompt even when a class template or a grown
    multi-turn history would exceed it (the fresh-conversation path used to
    skip the clamp)."""
    from repro.traces.qwentrace import TraceConfig, generate
    reqs = generate(TraceConfig(rate=12, duration=20, seed=0, max_len=1024,
                                shared_prefix_frac=0.25, multi_turn_prob=0.6))
    assert reqs and all(r.num_tokens <= 1024 for r in reqs)
    # hash chains never exceed the prompt's own full blocks
    assert all(len(r.prefix_hash) <= r.num_tokens // 128 for r in reqs)


# --- cluster sim -------------------------------------------------------------

def _shared_trace(rate=10, duration=12, seed=5):
    from repro.traces.qwentrace import TraceConfig, generate
    return generate(TraceConfig(rate=rate, duration=duration, seed=seed,
                                shared_prefix_frac=0.25,
                                multi_turn_prob=0.75))


def test_cluster_sharing_disabled_is_default_and_identical():
    """prefix_cache_blocks=0 (the default) leaves results bit-identical to
    an explicit no-sharing run even on a trace carrying prefix hashes."""
    from repro.sim.cluster import simulate_cluster
    reqs = _shared_trace()
    a = simulate_cluster("flowprefill", reqs, num_instances=2,
                         dispatch="capacity-weighted")
    b = simulate_cluster("flowprefill", reqs, num_instances=2,
                         dispatch="capacity-weighted", prefix_cache_blocks=0)
    assert [r.ttft for r in a.requests] == [r.ttft for r in b.requests]
    assert a.makespan == b.makespan
    assert a.prefix_hit_tokens == 0


def test_cluster_prefix_affinity_beats_blind_and_leaks_nothing():
    from repro.sim.cluster import ClusterSim, simulate_cluster
    from repro.sim.costmodel import A800, LLAMA3_8B, PrefillCostModel
    from repro.sim.policies import preset
    reqs = _shared_trace()
    blind = simulate_cluster("flowprefill", reqs, num_instances=4,
                             dispatch="capacity-weighted",
                             prefix_cache_blocks=2048)
    aff = simulate_cluster("flowprefill", reqs, num_instances=4,
                          dispatch="prefix-affinity",
                          prefix_cache_blocks=2048)
    assert aff.prefix_hit_rate > blind.prefix_hit_rate
    assert aff.prefix_hit_rate > 0.4
    assert aff.attainment >= blind.attainment
    # leak-free lifecycle: after the trace drains, every residency manager
    # conserves blocks with zero live references (all pins released)
    sim = ClusterSim(PrefillCostModel(LLAMA3_8B, A800),
                     preset("flowprefill"), num_instances=4,
                     dispatch="prefix-affinity", prefix_cache_blocks=256)
    import copy
    sim.run([copy.copy(r) for r in reqs])
    for mgr in sim.prefix_managers:
        mgr.check()
        assert mgr.live_blocks == 0
        assert mgr.free_blocks + mgr.cached_blocks == mgr.num_blocks


# --- real runtime ------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_instance():
    import jax

    from repro.configs.base import get_tiny_config
    from repro.core import SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.serving.prefill_instance import PrefillInstance
    cfg = dataclasses.replace(get_tiny_config("llama3_8b"), num_layers=2,
                              d_model=128, d_ff=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pred = TTFTPredictor(coeffs=np.array([1e-6, 0.0]), floor=0.0)
    inst = PrefillInstance(
        params, cfg, SchedulerCore(predictor=pred, enable_batching=False),
        max_seq=1024, chunk_tokens=256, prefix_share=True,
        prefix_cache_blocks=256)
    yield inst, cfg
    inst.shutdown()


def _run_once(inst, toks):
    from repro.core.request import Request
    req = Request(num_tokens=len(toks), slo=600.0, arrival=time.monotonic())
    t0 = time.monotonic()
    inst.submit_request(req, toks)
    assert inst.drain(600.0)
    return time.monotonic() - t0, req


def test_runtime_cached_prefix_hits_and_matches_cold_logits(tiny_instance):
    inst, cfg = tiny_instance
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, 700)
    _, cold = _run_once(inst, toks)
    assert cold.prefix_hit == 0
    _, warm = _run_once(inst, toks)
    # pool hit is block-aligned (5 x 128 = 640 of 700), capped below len
    assert warm.prefix_hit == 640
    lc = inst.completed_tasks[-2].prefill_task.logits
    lw = inst.completed_tasks[-1].prefill_task.logits
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lw),
                               rtol=2e-4, atol=2e-4)
    # leak-free: after draining, free + live + cached == num_blocks and
    # nothing is left pinned
    free, live, cached, total = inst.kv.accounting()
    assert free + live + cached == total
    assert live == 0


def test_runtime_fully_cached_prefix_speedup(tiny_instance):
    """The fig22 real-runtime acceptance: a fully-cached prefix prefills
    >= 3x faster than cold (suffix-only compute). Steady-state CPU measures
    20-40x, so 3x holds with a wide margin even on noisy CI runners."""
    inst, cfg = tiny_instance
    rng = np.random.default_rng(2)
    warmup = rng.integers(0, cfg.vocab_size, 1024)
    _run_once(inst, warmup)                    # compile cold shapes
    _run_once(inst, warmup)                    # compile warm (suffix) shapes
    colds, warms = [], []
    for _ in range(3):
        toks = rng.integers(0, cfg.vocab_size, 1024)
        c, _ = _run_once(inst, toks)
        w, wr = _run_once(inst, toks)
        assert wr.prefix_hit == 1023           # full blocks, capped at S-1
        colds.append(c)
        warms.append(w)
    speedup = float(np.median(colds) / np.median(warms))
    assert speedup >= 3.0, f"cached prefill only {speedup:.2f}x faster"


def test_runtime_proxy_prefix_affinity_routes_to_cache_holder():
    """End-to-end Proxy wiring: with prefix-affinity dispatch the follow-up
    prompt lands on the instance that cached its prefix."""
    import jax

    from repro.configs.base import get_tiny_config
    from repro.core import Request, SchedulerCore, TTFTPredictor
    from repro.models import init_params
    from repro.serving.prefill_instance import PrefillInstance
    from repro.serving.proxy import Proxy
    cfg = dataclasses.replace(get_tiny_config("llama3_8b"), num_layers=2,
                              d_model=64, d_ff=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pred = TTFTPredictor(coeffs=np.array([1e-5, 0.0]), floor=0.0)
    insts = [PrefillInstance(
        params, cfg, SchedulerCore(predictor=pred, enable_batching=False),
        max_seq=512, prefix_share=True, prefix_cache_blocks=64)
        for _ in range(2)]
    proxy = Proxy(insts, dispatch="prefix-affinity", predictor=pred,
                  capacities=[1e5, 1e5])
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, 256)
    try:
        req1 = Request(num_tokens=256, slo=60.0, arrival=time.monotonic())
        proxy.submit(req1, toks)
        assert proxy.drain(120.0)
        first = next(i for i, n in enumerate(proxy.dispatched) if n)
        # follow-up sharing the full prompt prefix + a new tail
        toks2 = np.concatenate([toks, rng.integers(0, cfg.vocab_size, 128)])
        req2 = Request(num_tokens=384, slo=60.0, arrival=time.monotonic())
        proxy.submit(req2, toks2)
        assert proxy.drain(120.0)
        assert proxy.dispatched[first] == 2, "follow-up left the cache holder"
        assert req2.prefix_hit == 256
        rep = proxy.report()
        assert rep["prefix_hits"] == 1
        assert rep["prefix_hit_tokens"] == 256
    finally:
        proxy.shutdown()
