"""End-to-end real-execution serving tests (threads + jitted segments on CPU).

Covers: the Fig. 7 signal/ACK protocol under the real Execution Pool, the
Fig. 8 two-request scenario (submit -> preempt -> submit -> resume), blocking
time bounded by one operator, event-driven round counting (<= 2 per request),
and FlowPrefill vs FCFS SLO attainment on a heterogeneous mini-trace.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_tiny_config
from repro.core import Request, RequestState, SchedulerCore, TTFTPredictor
from repro.models import init_params
from repro.models.segments import SegmentedPrefill
from repro.serving.decode_instance import DecodeInstance
from repro.serving.prefill_instance import PrefillInstance
from repro.serving.proxy import Proxy

# A model big enough that a long prefill takes O(seconds) on one CPU core,
# so preemption effects are unambiguous.
import dataclasses

CFG = dataclasses.replace(get_tiny_config("llama3_8b"),
                          num_layers=2, d_model=128, d_ff=256)
MAX_SEQ = 4096
LONG, SHORT = 4096, 128


@pytest.fixture(scope="module")
def served_model():
    params = init_params(CFG, jax.random.PRNGKey(0))
    # offline TTFT profile fit (the paper's predictor methodology, §6.4);
    # doubles as compile warm-up for the shapes the tests serve
    ex = SegmentedPrefill(params, CFG, max_seq=MAX_SEQ, granularity="op",
                          chunk_tokens=512)
    xs, ys = [], []
    for n in (128, 512, 1024, 2048, 4096):
        toks = jnp.zeros((1, n), jnp.int32)
        ex.run_all(ex.start(toks))          # warm compile
        t0 = time.monotonic()
        ex.run_all(ex.start(toks))
        xs.append(n)
        ys.append(time.monotonic() - t0)
    pred = TTFTPredictor.fit(xs, ys, degree=2)
    return params, pred, ex


def make_instance(params, pred, executor, policy="s-edf", **kw):
    core = SchedulerCore(predictor=pred, policy=policy,
                         batch_budget=kw.pop("batch_budget", 200),
                         enable_batching=kw.pop("enable_batching", False))
    return PrefillInstance(params, CFG, core, max_seq=MAX_SEQ,
                           attn_impl="xla", executor=executor)


def rand_tokens(n, seed=0):
    return np.random.default_rng(seed).integers(0, CFG.vocab_size, size=n)


def test_fig8_two_request_scenario(served_model):
    """Request A (long, relaxed SLO) starts; B (short, strict SLO) arrives
    mid-prefill; FlowPrefill must preempt A, serve B within its SLO, then
    resume and complete A.

    DEFLAKED: the bounds are calibrated from THIS machine's fitted prefill
    profile (the fixture's predictor) rather than hard-coded seconds — under
    full-suite CPU contention the old 1.0s/1.2s constants tripped even
    though the scheduling behaviour (B served operator-bounded, far before
    A's remaining prefill) was correct. The logical claims are unchanged:
    B's TTFT is its own compute plus operator-bounded blocking, NOT A's
    remaining prefill time."""
    params, pred, ex = served_model
    # machine-calibrated scale: the fitted uncontended 4096-token prefill
    # and the per-operator slice of it (blocking is bounded by in-flight
    # operators, so the tolerance must scale with operator cost)
    t_long = float(pred.predict(LONG))
    op_time = t_long / ex.start(jnp.zeros((1, LONG), jnp.int32)).total_segments
    # B's SLO: generous contention headroom over its own compute + a few
    # operators of blocking — but never looser than the paper's 1s scenario
    # on a fast machine
    slo_b = max(1.0, 6 * float(pred.predict(SHORT)) + 12 * op_time)
    inst = make_instance(params, pred, ex)
    try:
        A = Request(num_tokens=LONG, slo=60.0, arrival=time.monotonic(),
                    task_type="file")
        inst.submit_request(A, rand_tokens(LONG, 1))
        time.sleep(0.3)                      # let A start prefilling
        B = Request(num_tokens=SHORT, slo=slo_b, task_type="text",
                    arrival=time.monotonic())
        inst.submit_request(B, rand_tokens(SHORT, 2))
        assert inst.drain(120.0), "instance did not drain"

        b_ttft, a_ttft = B.ttft, A.ttft
        assert B.state == RequestState.DONE and A.state == RequestState.DONE
        assert b_ttft < slo_b, \
            f"B TTFT {b_ttft:.3f}s missed its {slo_b:.2f}s SLO"
        assert a_ttft > b_ttft, "A (preempted) must finish after B"
        # preemption actually happened and blocking was bounded
        assert len(inst.blocking_stats.samples) >= 1
        # bound: (dispatch_depth + 1) in-flight operators, with contention
        # headroom — scaled by the measured operator cost, floored at the
        # old absolute bound so a fast machine still enforces it
        assert inst.blocking_stats.max < max(1.2, 15 * op_time), \
            f"blocking {inst.blocking_stats.max:.3f}s not operator-bounded"
    finally:
        inst.shutdown()


def test_event_driven_round_count(served_model):
    """Scheduling rounds <= 2 per request (arrival + completion), regardless
    of operator granularity — the decoupling claim (§6.4)."""
    params, pred, ex = served_model
    inst = make_instance(params, pred, ex)
    try:
        n = 6
        for i in range(n):
            r = Request(num_tokens=SHORT, slo=30.0,
                        arrival=time.monotonic())
            inst.submit_request(r, rand_tokens(SHORT, i))
        assert inst.drain(120.0)
        # rounds = arrivals + completions; batching can only reduce completions
        assert inst.scheduling_rounds <= 2 * n
    finally:
        inst.shutdown()


def test_preempted_task_result_unchanged(served_model):
    """A preempted-and-resumed prefill must produce the same first-token
    logits as an uninterrupted run (through the full threaded runtime).

    DEFLAKED (the test_fig8 pattern): B's SLO and the warm-up wait are
    calibrated from THIS machine's fitted prefill profile instead of
    hard-coded (slo=1.0, sleep 0.3s). Under full-suite CPU contention the
    1.0s SLO could rank B as doomed — and a doomed B never preempts A, so
    the test silently stopped exercising the preempt-resume path it exists
    to pin. The logical claim is unchanged: A is interrupted mid-prefill
    and its resumed logits bit-match the uninterrupted reference."""
    params, pred, ex_shared = served_model
    toks = rand_tokens(LONG, 7)

    # uninterrupted reference via the bare executor
    want = ex_shared.run_all(ex_shared.start(jnp.asarray(toks[None], jnp.int32)))

    # machine-calibrated scale (see test_fig8): per-operator cost from the
    # fitted long-prefill latency, B's SLO generous over its own compute
    t_long = float(pred.predict(LONG))
    op_time = t_long \
        / ex_shared.start(jnp.zeros((1, LONG), jnp.int32)).total_segments
    slo_b = max(1.0, 6 * float(pred.predict(SHORT)) + 12 * op_time)

    inst = make_instance(params, pred, ex_shared)
    try:
        A = Request(num_tokens=LONG, slo=60.0, arrival=time.monotonic(),
                    task_type="file")
        inst.submit_request(A, toks)
        # wait until A is genuinely mid-prefill (state RUNNING plus a few
        # operators' worth of progress) so B's arrival forces a real
        # interruption — a fixed 0.3s could fall before A's first operator
        # under contention, turning this into an uninterrupted run
        deadline = time.monotonic() + 60.0
        while A.state != RequestState.RUNNING \
                and time.monotonic() < deadline:
            time.sleep(0.005)
        assert A.state == RequestState.RUNNING, "A never started prefilling"
        time.sleep(max(0.05, 4 * op_time))
        B = Request(num_tokens=SHORT, slo=slo_b, arrival=time.monotonic())
        inst.submit_request(B, rand_tokens(SHORT, 8))
        assert inst.drain(120.0)
        # B preempted A at an operator boundary: blocking was observed and
        # stayed operator-bounded (the test is vacuous without this)
        assert len(inst.blocking_stats.samples) >= 1
        assert inst.blocking_stats.max < max(1.2, 15 * op_time)
        done = {t.head.rid: t for t in inst.completed_tasks}
        got = done[A.rid].prefill_task.logits
        np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want)[0],
                                   rtol=1e-5, atol=1e-5)
    finally:
        inst.shutdown()


def test_flowprefill_beats_fcfs_on_heterogeneous_trace(served_model):
    """Mini QwenTrace-like mix: short/strict + long/relaxed. FlowPrefill
    (S-EDF + op preemption) must beat FCFS on strict-SLO attainment.

    DEFLAKED: the strict SLO is calibrated from THIS machine's fitted
    prefill profile (the test_fig8 pattern) instead of a hard-coded 1.0s —
    under full-suite CPU contention the constant tripped FlowPrefill's
    attainment even though preemption served every short request far ahead
    of the long prefill. The SLO must stay BELOW the long prefill's
    remaining time (or FCFS would trivially pass too, erasing the
    contrast), so it is capped at a fraction of the fitted long-prefill
    latency — the discrimination window the scenario is built around."""
    params, pred, ex = served_model
    t_long = float(pred.predict(LONG))
    op_time = t_long / ex.start(jnp.zeros((1, LONG), jnp.int32)).total_segments
    # headroom over the short request's own compute + operator-bounded
    # blocking; floored at the paper's 1s scenario, capped well inside the
    # long prefill so FCFS's head-of-line wait still violates it
    slo_text = min(max(1.0, 6 * float(pred.predict(SHORT)) + 12 * op_time),
                   0.6 * t_long)

    def run(policy):
        inst = make_instance(params, pred, ex, policy=policy)
        reqs = []
        try:
            # one long request, then a stream of short strict ones
            long_r = Request(num_tokens=LONG, slo=60.0, task_type="file",
                             arrival=time.monotonic())
            inst.submit_request(long_r, rand_tokens(LONG, 100))
            reqs.append(long_r)
            time.sleep(0.2)
            for i in range(4):
                r = Request(num_tokens=SHORT, slo=slo_text, task_type="text",
                            arrival=time.monotonic())
                inst.submit_request(r, rand_tokens(SHORT, 200 + i))
                reqs.append(r)
                time.sleep(0.05)
            assert inst.drain(180.0)
        finally:
            inst.shutdown()
        text = [r for r in reqs if r.task_type == "text"]
        return sum(r.slo_met for r in text) / len(text)

    att_flow = run("s-edf")
    att_fcfs = run("fcfs")
    assert att_flow > att_fcfs, (att_flow, att_fcfs)
    assert att_flow == 1.0, f"FlowPrefill text attainment {att_flow}"


def test_pd_pipeline_with_decode(served_model):
    """Full proxy -> prefill -> decode handoff produces finished requests."""
    params, pred, ex = served_model
    inst = make_instance(params, pred, ex)
    dec = DecodeInstance(params, CFG, decode_tokens=4)
    proxy = Proxy([inst], [dec])
    try:
        for i in range(3):
            r = Request(num_tokens=SHORT, slo=30.0, arrival=time.monotonic())
            proxy.submit(r, rand_tokens(SHORT, 300 + i))
        assert proxy.drain(120.0)
        # DEFLAKED (test_fig8 pattern: calibrate, don't hard-code): drain's
        # atomic decode-idle observation already implies the finish list is
        # complete, so the old fixed `time.sleep(1.0)` only added a flake
        # window under full-suite contention. Keep a machine-calibrated
        # grace loop for the cross-thread list append instead: bounded by
        # the fitted prefill profile, exits immediately when done.
        deadline = time.monotonic() + max(1.0, 10 * float(pred.predict(SHORT)))
        while len(dec.finished) < 3 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(dec.finished) == 3
        assert all(r.finish_time is not None for r in dec.finished)
        rep = proxy.report()
        assert rep["n_requests"] == 3
    finally:
        proxy.shutdown()
