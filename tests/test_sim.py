"""Simulator validation: invariants + reproduction of the paper's headline
claims (goodput ordering/ratios, policy ablation, batching ablation, blocking
times, MoE generality)."""
import numpy as np

from repro.core.metrics import max_goodput
from repro.sim.costmodel import A800, LLAMA3_8B, PrefillCostModel
from repro.sim.policies import simulate
from repro.traces.qwentrace import TABLE1, TraceConfig, generate

RATES = [0.25, 0.5, 1, 2, 4, 6, 8, 12]
MODEL_RATES = {
    "llama3-8b": RATES,
    "qwen3-30b-a3b": [1, 2, 4, 8, 16, 24, 32, 48, 64],
}


def goodput(system, seed=3, duration=60, model="llama3-8b", **ov):
    rates = MODEL_RATES.get(model, RATES)
    atts = []
    for rate in rates:
        reqs = generate(TraceConfig(rate=rate, duration=duration, seed=seed,
                                    model=model))
        atts.append(simulate(system, reqs, model=model, **ov).attainment)
    return max_goodput(rates, atts)


# --- invariants ----------------------------------------------------------------

def test_sim_conservation_and_causality():
    reqs = generate(TraceConfig(rate=4, duration=40, seed=0))
    res = simulate("flowprefill", reqs)
    assert len(res.requests) == len(reqs)
    cost = PrefillCostModel(LLAMA3_8B, A800)
    for r in res.requests:
        assert r.first_token_time is not None, "every request completes"
        assert r.first_token_time >= r.arrival, "causality"
        # can't finish faster than its own pure execution time
        assert r.ttft >= cost.prefill_time(r.num_tokens) * 0.3


def test_sim_blocking_bounded_by_granularity():
    """op-level blocking <= one (max) operator; layer-level <= one layer."""
    reqs = generate(TraceConfig(rate=6, duration=40, seed=1))
    res_op = simulate("flowprefill", reqs)
    res_layer = simulate("layer-level", reqs)
    assert res_op.preemptions > 0
    cost = PrefillCostModel(LLAMA3_8B, A800)
    durs = cost.op_durations(32768)
    assert max(res_op.blocking_times) <= durs.max() + 1e-6
    if res_layer.blocking_times:
        assert max(res_layer.blocking_times) >= max(res_op.blocking_times)


def test_sim_event_driven_round_count():
    reqs = generate(TraceConfig(rate=2, duration=40, seed=2))
    res = simulate("flowprefill", reqs)
    # arrival + completion per request; batching merges completions
    assert res.rounds <= 2 * len(reqs)


# --- paper claims ---------------------------------------------------------------

def test_fig9_goodput_ordering_and_ratios():
    """FlowPrefill sustains 4.7-5.6x DistServe (we assert a band of 3-9x to
    absorb trace/cost-model variance), beats CP2K and CP8K, with CP8K worse
    than CP2K (paper §6.2)."""
    g = {s: goodput(s) for s in
         ("distserve", "distserve-cp2k", "distserve-cp8k", "flowprefill")}
    assert g["flowprefill"] > g["distserve-cp2k"] > g["distserve-cp8k"] > 0
    assert g["distserve-cp2k"] > g["distserve"]
    ratio = g["flowprefill"] / g["distserve"]
    assert 3.0 <= ratio <= 9.0, f"goodput ratio {ratio:.1f} outside band"
    ratio8k = g["flowprefill"] / g["distserve-cp8k"]
    assert ratio8k >= 2.0


def test_fig10_sedf_beats_dedf_beats_edf():
    g_s = goodput("flowprefill")
    g_d = goodput("flowprefill-dedf")
    g_e = goodput("flowprefill-edf")
    assert g_s >= g_d >= g_e * 0.95
    assert g_s > g_e


def test_fig11_batching_throughput_and_budget_risk():
    """Fig. 11 right panel: no batching yields the lowest throughput, larger
    budgets improve it with diminishing returns (4K ~ 8K). Left panel: larger
    budgets increase SLO-violation risk (attainment ordering 2K >= 4K >= 8K).

    Known deviation (EXPERIMENTS.md §Sim-fidelity): at the goodput crossing
    point our calibration is blocking-limited, not throughput-limited, so
    no-batching attainment is competitive there — the paper's SLO-aware
    batching win shows up in throughput, which we assert."""
    def run(sys, rate=40, **kw):
        reqs = generate(TraceConfig(rate=rate, duration=60, seed=3))
        res = simulate(sys, reqs, **kw)
        return res.attainment, len(res.requests) / res.makespan

    att_none, thr_none = run("flowprefill-nobatch")
    att_2k, thr_2k = run("flowprefill", batch_budget=2048)
    att_4k, thr_4k = run("flowprefill", batch_budget=4096)
    att_8k, thr_8k = run("flowprefill", batch_budget=8192)
    # throughput: none lowest; diminishing returns 4K -> 8K
    assert thr_none < thr_2k * 1.02
    assert thr_none < max(thr_4k, thr_8k)
    assert abs(thr_8k - thr_4k) / thr_4k < 0.15, "4K ~ 8K (diminishing)"
    # risk ordering: bigger budgets can't improve attainment
    assert att_2k >= att_4k - 0.02 >= att_8k - 0.04


def test_fig12_op_vs_layer_blocking_ratio():
    """Operator-level preemption reduces mean blocking by ~3.5-4.2x vs
    layer-level (assert 2-8x band)."""
    reqs = generate(TraceConfig(rate=6, duration=60, seed=4))
    b_op = simulate("flowprefill", reqs).blocking_times
    # same policy, layer boundaries, no polling cost (isolate granularity)
    b_layer = simulate("flowprefill", reqs, granularity="layer").blocking_times
    assert b_op and b_layer
    ratio = np.mean(b_layer) / np.mean(b_op)
    assert 2.0 <= ratio <= 10.0, f"blocking ratio {ratio:.1f}"


def test_fig14_single_slo_no_overhead():
    """Single-SLO short-prompt workload: FlowPrefill matches chunked-prefill
    baseline throughput (preemption checks cost nothing when unused)."""
    from repro.traces.qwentrace import sharegpt_like
    reqs = sharegpt_like(n=300, rate=8.0, seed=5)
    r_flow = simulate("flowprefill", reqs)
    r_cp = simulate("distserve-cp2k", reqs)
    assert r_flow.makespan <= r_cp.makespan * 1.05
    assert r_flow.attainment >= r_cp.attainment - 0.02


def test_fig17_moe_generality():
    """Qwen3-30B-A3B (gate/experts operator boundaries): FlowPrefill still
    beats the CP baselines (paper: 1.6x goodput)."""
    g_flow = goodput("flowprefill", model="qwen3-30b-a3b")
    g_cp2k = goodput("distserve-cp2k", model="qwen3-30b-a3b")
    assert g_flow > g_cp2k
    assert g_flow / max(g_cp2k, 1e-9) >= 1.3


def test_trace_matches_table1():
    reqs = generate(TraceConfig(rate=20, duration=400, seed=1))
    for task, t in TABLE1.items():
        lens = np.asarray([r.num_tokens for r in reqs if r.task_type == task])
        assert abs(lens.mean() - t["mean"]) / t["mean"] < 0.15, task
        assert abs(np.percentile(lens, 99) - t["p99"]) / t["p99"] < 0.35, task
    ratios = {task: np.mean([r.task_type == task for r in reqs])
              for task in TABLE1}
    for task, t in TABLE1.items():
        assert abs(ratios[task] - t["ratio"]) < 0.05, task
