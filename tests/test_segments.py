"""Operator-segmented executor correctness: segmented == fused, chunked ==
unchunked, preempt/resume == uninterrupted (bit-exact), granularity variants
agree. These validate the mechanism that makes operator-level preemption safe.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_tiny_config
from repro.models import init_params, prefill
from repro.models.segments import SegmentedPrefill

B, S = 2, 48


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_tiny_config("llama3_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    return cfg, params, tokens


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_tiny_config("qwen3_30b_a3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    return cfg, params, tokens


def fused_logits(params, cfg, tokens):
    logits, _ = prefill(params, cfg, {"tokens": tokens}, max_seq=S,
                        cache_dtype=jnp.float32)
    return logits


@pytest.mark.parametrize("setup_name", ["dense_setup", "moe_setup"])
def test_segmented_matches_fused(setup_name, request):
    cfg, params, tokens = request.getfixturevalue(setup_name)
    ex = SegmentedPrefill(params, cfg, max_seq=S, granularity="op")
    task = ex.start(tokens)
    got = ex.run_all(task)
    want = fused_logits(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_matches_unchunked(dense_setup):
    cfg, params, tokens = dense_setup
    ex1 = SegmentedPrefill(params, cfg, max_seq=S, granularity="op")
    ex2 = SegmentedPrefill(params, cfg, max_seq=S, granularity="op",
                           chunk_tokens=16)
    l1 = ex1.run_all(ex1.start(tokens))
    l2 = ex2.run_all(ex2.start(tokens))
    assert ex2.segments_for(S) > ex1.segments_for(S)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gran", ["layer", "block2", "whole"])
def test_granularities_agree(dense_setup, gran):
    cfg, params, tokens = dense_setup
    ref = SegmentedPrefill(params, cfg, max_seq=S, granularity="op")
    alt = SegmentedPrefill(params, cfg, max_seq=S, granularity=gran)
    l_ref = ref.run_all(ref.start(tokens))
    l_alt = alt.run_all(alt.start(tokens))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_alt),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("setup_name", ["dense_setup", "moe_setup"])
@pytest.mark.parametrize("stop_frac", [0.2, 0.5, 0.8])
def test_suspend_resume_bit_exact(setup_name, stop_frac, request):
    """The core safety property of operator-level preemption: suspending at ANY
    operator boundary and resuming later is bit-identical to uninterrupted
    execution (state is preserved exactly; nothing is recomputed)."""
    cfg, params, tokens = request.getfixturevalue(setup_name)
    ex = SegmentedPrefill(params, cfg, max_seq=S, granularity="op",
                          chunk_tokens=16)

    t_full = ex.start(tokens)
    want = ex.run_all(t_full)

    t = ex.start(tokens)
    stop_at = int(t.total_segments * stop_frac)
    while t.cursor < stop_at:
        ex.step(t)
    # --- suspension point: state simply stays alive; nothing else happens ---
    jax.block_until_ready(jax.tree.leaves(t.state))
    # --- resume ---
    got = ex.run_all(t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_per_request_lens_head(dense_setup):
    """Padded batch: first-token logits must come from each request's own last
    position, not the pad tail."""
    cfg, params, _ = dense_setup
    t1 = jax.random.randint(jax.random.PRNGKey(5), (1, 20), 0, cfg.vocab_size)
    ex = SegmentedPrefill(params, cfg, max_seq=S, granularity="op")
    # solo run of the short request
    solo = ex.run_all(ex.start(t1))
    # padded batch: same request + a longer one
    t2 = jax.random.randint(jax.random.PRNGKey(6), (1, S), 0, cfg.vocab_size)
    toks = jnp.concatenate(
        [jnp.pad(t1, ((0, 0), (0, S - 20))), t2], axis=0)
    batched = ex.run_all(ex.start(toks, lens=jnp.asarray([20, S])))
    np.testing.assert_allclose(np.asarray(batched[0]), np.asarray(solo[0]),
                               rtol=2e-5, atol=2e-5)
