"""The trace determinism contract and the fitted-scenario suite
(docs/TRACES.md): same-seed traces are element-for-element identical,
moment-matching fits are exact, scenario shapes (diurnal modulation, Pareto
output splice, flood burst, length-aware SLO floor) actually hold, and the
prefix-adversary's hash chains collide for exactly the trunk blocks then
diverge — the property prefix caches and prefix-affinity dispatch key on."""
import math

import numpy as np
import pytest

from repro.traces.qwentrace import TABLE1, TABLE2_SLO, TraceConfig, generate
from repro.traces.scenarios import (ADVERSARY_FAMILIES,
                                    ADVERSARY_TRUNK_BLOCKS, CHAT_FIT,
                                    DEFAULT_OUTPUT_MEAN, FLOOD_WINDOW,
                                    HEAVY_TAIL_SCALE, SCENARIOS,
                                    TTFT_SLO_PER_TOKEN, fit_gamma,
                                    fit_lognormal, scenario_names)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def cfg_for(scenario, **kw):
    kw.setdefault("rate", 8.0)
    kw.setdefault("duration", 30.0)
    kw.setdefault("seed", 0)
    return TraceConfig(scenario=scenario, **kw)


def as_tuples(reqs):
    """Everything the determinism contract promises, per request."""
    return [(r.num_tokens, r.slo, r.arrival, r.task_type, r.output_tokens,
             r.tbt_slo, r.prefix_hash) for r in reqs]


# ------------------------------------------------------------- determinism

@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_same_seed_same_trace(scenario):
    a = generate(cfg_for(scenario))
    b = generate(cfg_for(scenario))
    assert len(a) > 0
    assert as_tuples(a) == as_tuples(b)


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_different_seed_different_trace(scenario):
    a = generate(cfg_for(scenario, seed=0))
    b = generate(cfg_for(scenario, seed=1))
    assert as_tuples(a) != as_tuples(b)


def test_flood_leaves_base_mixture_unchanged():
    """The flood tenant draws from a derived seed (cfg.seed + 0x5EED), so
    the base chat mixture is byte-identical with and without the flood."""
    base = generate(cfg_for("fitted-chat"))
    flood = generate(cfg_for("flood"))
    assert len(flood) > len(base)
    flood_set = set(as_tuples(flood))
    for t in as_tuples(base):
        assert t in flood_set


def test_unknown_scenario_is_an_error():
    with pytest.raises(ValueError, match="unknown scenario"):
        generate(cfg_for("nope"))
    assert scenario_names() == sorted(SCENARIOS)


def test_arrivals_sorted_and_within_horizon():
    for scenario in SCENARIOS:
        reqs = generate(cfg_for(scenario))
        arrivals = [r.arrival for r in reqs]
        assert arrivals == sorted(arrivals)
        assert all(0.0 <= a < 30.0 for a in arrivals)


# ------------------------------------------------------------------ fits

def test_fit_lognormal_moments_exact():
    for mean, std in [(590, 652), (220, 260), (160, 160), (5976, 3456)]:
        mu, sigma = fit_lognormal(mean, std)
        m = math.exp(mu + sigma * sigma / 2.0)
        v = (math.exp(sigma * sigma) - 1.0) * math.exp(2 * mu + sigma * sigma)
        assert m == pytest.approx(mean, rel=1e-9)
        assert math.sqrt(v) == pytest.approx(std, rel=1e-9)


def test_fit_gamma_moments_exact():
    for mean, cv in [(8.0, 1.4), (3.0, 1.0), (20.0, 0.5)]:
        shape, scale = fit_gamma(mean, cv)
        assert shape * scale == pytest.approx(mean, rel=1e-9)
        assert 1.0 / math.sqrt(shape) == pytest.approx(cv, rel=1e-9)
    # cv=1 degenerates to the exponential
    shape, scale = fit_gamma(5.0, 1.0)
    assert shape == pytest.approx(1.0)
    assert scale == pytest.approx(5.0)


def test_fitted_chat_trace_moments():
    """Trace-level sanity on the fitted generator: the request rate lands
    near cfg.rate (sessions arrive at rate/turns_mean, each contributing
    ~turns_mean turns), and output lengths track the fitted mean."""
    cfg = cfg_for("fitted-chat", rate=16.0, duration=60.0)
    reqs = generate(cfg)
    rate = len(reqs) / cfg.duration
    assert 0.5 * cfg.rate < rate < 1.6 * cfg.rate
    outs = [r.output_tokens for r in reqs]
    assert all(o >= 1 for o in outs)
    assert 0.5 * DEFAULT_OUTPUT_MEAN < np.mean(outs) < 2.5 * DEFAULT_OUTPUT_MEAN
    # per-class TBT SLOs applied (chat defaults)
    by_task = {r.task_type for r in reqs}
    assert "text" in by_task
    assert all(r.tbt_slo == 0.03 for r in reqs if r.task_type == "text")


def test_fitted_chat_multi_turn_chains_extend_parents():
    """Follow-up turns resubmit the conversation's full prompt: some chains
    are proper prefixes of later chains (genuine multi-turn reuse), and all
    requests of a class share its system-prompt template blocks."""
    reqs = generate(cfg_for("fitted-chat", rate=12.0, duration=40.0))
    chains = {r.prefix_hash for r in reqs}
    extended = sum(
        1 for r in reqs
        for k in range(1, len(r.prefix_hash))
        if r.prefix_hash[:k] in chains)
    assert extended > 0
    # the search-class template is 0.25 * 5976 tokens ~= 11 full blocks
    tpl_blocks = int(0.25 * TABLE1["search"]["mean"]) // 128
    assert tpl_blocks >= 2
    search = [r for r in reqs if r.task_type == "search"]
    assert len(search) >= 2
    assert len({r.prefix_hash[:tpl_blocks] for r in search}) == 1


# --------------------------------------------------------- scenario shapes

def test_diurnal_concentrates_arrivals_at_peaks():
    """rate_fn troughs at t=0 and peaks at t=period/2 (DIURNAL_CYCLES=2 ->
    peaks at 15s and 45s of a 60s trace). Thinning must concentrate
    arrivals there."""
    reqs = generate(cfg_for("diurnal", rate=16.0, duration=60.0))

    def count(lo, hi):
        return sum(1 for r in reqs if lo <= r.arrival < hi)

    peak = count(12, 18) + count(42, 48)
    trough = count(0, 3) + count(27, 33) + count(57, 60)
    assert peak > 2 * max(trough, 1)


def test_heavy_tail_splices_pareto_outputs():
    base = generate(cfg_for("fitted-chat", rate=16.0, duration=60.0))
    tail = generate(cfg_for("heavy-tail", rate=16.0, duration=60.0))
    frac = np.mean([r.output_tokens >= HEAVY_TAIL_SCALE for r in tail])
    base_frac = np.mean([r.output_tokens >= HEAVY_TAIL_SCALE for r in base])
    assert frac > base_frac + 0.03        # ~8% splice minus lognormal tail
    assert max(r.output_tokens for r in tail) > 2000
    assert all(r.output_tokens <= 8192 for r in tail)


def test_flood_burst_confined_to_window():
    cfg = cfg_for("flood", rate=8.0, duration=60.0)
    base = generate(cfg_for("fitted-chat", rate=8.0, duration=60.0))
    flood = generate(cfg)
    base_set = set(as_tuples(base))
    injected = [r for r, t in zip(flood, as_tuples(flood))
                if t not in base_set]
    assert injected
    lo, hi = FLOOD_WINDOW[0] * cfg.duration, FLOOD_WINDOW[1] * cfg.duration
    assert all(lo <= r.arrival < hi for r in injected)
    assert all(r.task_type == "text" for r in injected)
    # one shared 512-token template: 4 leading full blocks in common
    assert len({r.prefix_hash[:4] for r in injected}) == 1
    # the burst actually floods: ~6x the base rate inside the window
    in_window = sum(1 for r in flood if lo <= r.arrival < hi)
    base_in_window = sum(1 for r in base if lo <= r.arrival < hi)
    assert in_window > 3 * base_in_window


# ------------------------------------------------------ length-aware SLOs

@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_length_aware_slo_floor(scenario):
    """slo = max(class_slo, num_tokens * TTFT_SLO_PER_TOKEN) * slo_scale:
    every request is feasible unloaded, typical lengths keep the class SLO,
    and slo_scale multiplies through."""
    slos = TABLE2_SLO["llama3-8b"]
    reqs = generate(cfg_for(scenario))
    for r in reqs:
        expect = max(slos[r.task_type], r.num_tokens * TTFT_SLO_PER_TOKEN)
        assert r.slo == pytest.approx(expect)
    scaled = generate(cfg_for(scenario, slo_scale=2.0))
    assert [r.slo for r in scaled] == \
        pytest.approx([2.0 * r.slo for r in reqs])


# ------------------------------------------- prefix-adversary collide/diverge

def common_prefix_len(a, b):
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def check_collide_then_diverge(reqs):
    """Any two adversary chains share EXACTLY the trunk (same family) or
    nothing (different families) — never a partial trunk, never a shared
    tail block. This is what makes the trace adversarial: the trie gets
    trunk hits only, and every tail block is inserted exactly once."""
    assert all(len(r.prefix_hash) > ADVERSARY_TRUNK_BLOCKS for r in reqs)
    families = {}
    for r in reqs:
        families.setdefault(r.prefix_hash[0], []).append(r)
    assert len(families) <= ADVERSARY_FAMILIES
    chains = [r.prefix_hash for r in reqs]
    for i, a in enumerate(chains):
        for b in chains[i + 1:]:
            n = common_prefix_len(a, b)
            assert n == (ADVERSARY_TRUNK_BLOCKS if a[0] == b[0] else 0)
    return len(families)


def test_prefix_adversary_collides_for_trunk_then_diverges():
    reqs = generate(cfg_for("prefix-adversary", rate=4.0, duration=30.0))
    n_families = check_collide_then_diverge(reqs)
    assert n_families >= 5                # Zipf still spreads across trunks
    assert all(r.task_type == "search" for r in reqs)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1), rate=st.floats(0.5, 12.0))
    @settings(max_examples=20, deadline=None)
    def test_prefix_adversary_property(seed, rate):
        reqs = generate(TraceConfig(scenario="prefix-adversary", seed=seed,
                                    rate=rate, duration=20.0))
        if len(reqs) >= 2:
            check_collide_then_diverge(reqs)
else:                                                 # pragma: no cover
    @pytest.mark.parametrize("seed", [1, 2, 7, 13, 42])
    def test_prefix_adversary_property(seed):
        reqs = generate(TraceConfig(scenario="prefix-adversary", seed=seed,
                                    rate=6.0, duration=20.0))
        assert len(reqs) >= 2
        check_collide_then_diverge(reqs)


def test_session_fit_defaults_documented():
    """docs/TRACES.md quotes CHAT_FIT verbatim — keep them honest."""
    assert (CHAT_FIT.turns_mean, CHAT_FIT.turns_std, CHAT_FIT.max_turns) \
        == (3.2, 2.6, 12)
    assert (CHAT_FIT.think_mean, CHAT_FIT.think_cv) == (8.0, 1.4)
    assert (CHAT_FIT.growth_mean, CHAT_FIT.growth_std) == (220.0, 260.0)
