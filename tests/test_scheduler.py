"""Unit tests for the scheduler core: S-EDF priority (Eq. 3), SLO-aware
batching (Alg. 1), and the event-triggered round of Alg. 2."""
import numpy as np

from repro.core import (Action, Request, SchedulerCore, TTFTPredictor,
                        slo_aware_batching)

# a predictor with latency = 1e-4 * tokens (linear, easy arithmetic)
PRED = TTFTPredictor(coeffs=np.array([1e-4, 0.0]), floor=0.0)


def mk(tokens, slo, arrival=0.0, task="text"):
    return Request(num_tokens=tokens, slo=slo, arrival=arrival, task_type=task)


def core(**kw):
    kw.setdefault("predictor", PRED)
    return SchedulerCore(**kw)


# --- S-EDF priority ----------------------------------------------------------

def test_sedf_prefers_earliest_feasible_deadline():
    c = core()
    a = mk(100, slo=1.0)      # deadline 1.0, feasible (exec 0.01)
    b = mk(100, slo=2.0)      # deadline 2.0, feasible
    assert c.priority(a, 0.0) > c.priority(b, 0.0)


def test_sedf_deprioritizes_doomed_requests():
    c = core()
    doomed = mk(100000, slo=0.001)   # exec 10s >> slo
    ok = mk(100, slo=5.0)
    assert c.priority(ok, 0.0) > c.priority(doomed, 0.0)
    # doomed priority is negative (sgn(slack) = -1)
    assert c.priority(doomed, 0.0) < 0


def test_dedf_vs_sedf_distinction():
    """D-EDF only notices a miss after the deadline passes; S-EDF notices as
    soon as the predicted finish overshoots (foresight, §6.3)."""
    doomed = mk(100000, slo=0.5)     # exec 10s, deadline 0.5 not yet passed
    s = core(policy="s-edf")
    d = core(policy="d-edf")
    assert s.priority(doomed, now=0.0) < 0        # S-EDF: already infeasible
    assert d.priority(doomed, now=0.0) > 0        # D-EDF: still positive
    assert d.priority(doomed, now=1.0) < 0        # ... until time passes


# --- SLO-aware batching (Alg. 1) ----------------------------------------------

def test_batching_respects_token_budget():
    H = mk(1000, slo=10.0)
    cands = [mk(1000, slo=10.0) for _ in range(10)]
    H, batch = slo_aware_batching(H, cands, budget=3500, now=0.0,
                                  predict=PRED.predict)
    total = sum(r.num_tokens for r in batch)
    assert total < 3500
    assert H.batch_tokens == total
    assert len(batch) == 3          # 1000 + 1000 + 1000 (< 3500), next hits 4000


def test_batching_respects_deadline():
    H = mk(1000, slo=0.15)          # t_remain 0.15; own exec 0.1
    cands = [mk(1000, slo=10.0) for _ in range(5)]
    # adding one candidate -> 2000 tokens -> 0.2s > 0.15 remaining: reject all
    H, batch = slo_aware_batching(H, cands, budget=100000, now=0.0,
                                  predict=PRED.predict)
    assert batch == [H]


def test_batching_skips_then_admits_smaller():
    H = mk(1000, slo=0.25)          # t_remain 0.25
    big = mk(2000, slo=10.0)        # 3000 tok -> 0.3s: reject
    small = mk(400, slo=10.0)       # 1400 tok -> 0.14s: admit
    H, batch = slo_aware_batching(H, [big, small], budget=100000, now=0.0,
                                  predict=PRED.predict)
    assert small in batch and big not in batch


# --- Algorithm 2 rounds --------------------------------------------------------

def test_round_submits_when_idle():
    c = core()
    r = mk(100, slo=1.0)
    d = c.schedule_round(0.0, waiting=[r], preempted=[], running=None)
    assert d.action == Action.SUBMIT and d.target.rid == r.rid
    assert d.preempt is None


def test_round_preempts_lower_priority_running():
    c = core()
    low = mk(20000, slo=6.0, task="file")      # long, relaxed SLO
    high = mk(200, slo=0.25, task="text")      # short, strict SLO
    d = c.schedule_round(0.1, waiting=[high], preempted=[], running=low)
    assert d.action == Action.SUBMIT
    assert d.preempt is not None and d.preempt.rid == low.rid
    assert d.target.rid == high.rid


def test_round_resumes_preempted_after_completion():
    c = core()
    pre = mk(20000, slo=6.0)
    d = c.schedule_round(0.5, waiting=[], preempted=[pre], running=None)
    assert d.action == Action.RESUME and d.target.rid == pre.rid
    assert d.preempt is None


def test_round_noop_when_running_is_best():
    c = core()
    run = mk(200, slo=0.25)
    wait = mk(20000, slo=6.0)
    d = c.schedule_round(0.0, waiting=[wait], preempted=[], running=run)
    assert d.is_noop


def test_round_noop_when_empty():
    c = core()
    assert c.schedule_round(0.0, [], [], None).is_noop


def test_round_batches_compatible_waiting_requests():
    c = core(batch_budget=10000)
    h = mk(500, slo=1.0)
    w1 = mk(500, slo=2.0)
    w2 = mk(500, slo=3.0)
    d = c.schedule_round(0.0, waiting=[h, w1, w2], preempted=[], running=None)
    assert d.action == Action.SUBMIT
    assert {r.rid for r in d.batch} == {h.rid, w1.rid, w2.rid}


def test_preempted_requests_never_rebatch():
    """Alg. 2: C excludes Q_p — preempted tasks hold partial state."""
    c = core(batch_budget=10**9)
    h = mk(100, slo=1.0)
    pre = mk(100, slo=5.0)
    d = c.schedule_round(0.0, waiting=[h], preempted=[pre], running=None)
    assert d.action == Action.SUBMIT
    assert all(r.rid != pre.rid for r in d.batch)
